"""Paged serving subsystem: block allocator invariants, paged-vs-dense
decode equivalence, batched-prefill-vs-token-replay equivalence, and the
preemption round-trip."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.decode import decode_step
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_cache import cache_specs
from repro.serve.paged import ZERO_BLOCK, BlockAllocator, PagedKVCache
from repro.serve.prefill import batched_prefill


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, seed=0, lo=4, hi=24, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        Request(
            u,
            rng.integers(3, cfg.vocab_size, int(rng.integers(lo, hi))).tolist(),
            max_new_tokens=max_new,
        )
        for u in range(n)
    ]


def _run(cfg, params, reqs, serve, stagger=0):
    eng = ServeEngine(cfg, params, serve=serve)
    for r in reqs[: len(reqs) - stagger]:
        eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
    if stagger:
        for _ in range(4):
            eng.tick()
        for r in reqs[len(reqs) - stagger:]:
            eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
    out = eng.run()
    return out, eng


BASE = ServeConfig(max_lanes=2, max_seq=64, block_size=8)
DENSE = dataclasses.replace(BASE, paged=False, batched_prefill=False)


# ==========================================================================
# BlockAllocator
# ==========================================================================
class TestBlockAllocator:
    def test_alloc_free_reuse(self):
        a = BlockAllocator(9, 8)  # 8 usable (block 0 reserved)
        got = a.alloc(1, 3)
        assert got is not None and len(got) == 3
        assert ZERO_BLOCK not in got
        assert a.num_free == 5
        assert a.alloc(2, 6) is None  # over budget: no state change
        assert a.num_free == 5 and 2 not in a.tables
        freed = a.free(1)
        assert sorted(freed) == sorted(got)
        assert a.num_free == 8
        # freed blocks come back (LIFO) and are never double-issued
        again = a.alloc(3, 8)
        assert sorted(again) == list(range(1, 9))
        assert a.alloc(4, 1) is None

    def test_tables_are_per_request(self):
        a = BlockAllocator(9, 4)
        a.alloc(7, 2)
        a.alloc(8, 2)
        assert set(a.tables[7]).isdisjoint(a.tables[8])
        a.alloc(7, 1)
        assert len(a.tables[7]) == 3  # growth appends

    def test_stats_and_utilization(self):
        a = BlockAllocator(9, 4)
        a.alloc(1, 4)
        st = a.stats()
        assert st["blocks_used"] == 4 and st["blocks_free"] == 4
        assert st["utilization"] == pytest.approx(0.5)

    def test_defragment_compacts_and_remaps(self):
        a = BlockAllocator(17, 8)
        a.alloc(1, 3)
        a.alloc(2, 4)
        a.alloc(3, 2)
        a.free(2)  # hole in the middle
        mapping = a.defragment()
        live = sorted(b for t in a.tables.values() for b in t)
        assert live == list(range(1, 6))  # compact prefix, block 0 untouched
        assert ZERO_BLOCK not in mapping and ZERO_BLOCK not in mapping.values()
        assert a.num_free == 16 - 5


# ==========================================================================
# Paged storage
# ==========================================================================
def test_paged_gather_matches_dense_roundtrip(qwen):
    """write_prefill -> gather_views reconstructs exactly the dense cache
    batched_prefill produced (modulo zero-padding past the prompt)."""
    cfg, params = qwen
    serve = BASE
    kv = PagedKVCache(cfg, serve)
    alloc = BlockAllocator(serve.resolved_num_blocks, serve.block_size)
    rng = np.random.default_rng(0)
    n = 19
    tokens = np.zeros((1, 32), np.int32)
    tokens[0, :n] = rng.integers(3, cfg.vocab_size, n)
    _, pcache = batched_prefill(
        params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
        seq_max=serve.max_seq,
    )
    alloc.alloc(0, alloc.blocks_for_tokens(n))
    tables = np.full((serve.max_lanes, serve.blocks_per_lane), ZERO_BLOCK,
                     np.int32)
    row = alloc.tables[0]
    tables[0, : len(row)] = row
    kv.write_prefill(0, pcache, tables[0], n_tokens=n)
    view = kv.gather_views(tables)

    k_dense = np.asarray(pcache["layers"][0]["k"] if isinstance(
        pcache["layers"], list) else pcache["layers"]["k"][0])
    k_view = np.asarray(view["layers"][0]["k"][0] if isinstance(
        view["layers"], list) else view["layers"]["k"][0][0])
    np.testing.assert_allclose(k_view[..., :32, :], k_dense, atol=0)
    assert np.all(k_view[..., 32:, :] == 0)  # unallocated -> zero block
    assert int(view["pos"][0]) == n


# ==========================================================================
# Engine equivalence
# ==========================================================================
def test_paged_vs_dense_token_identical(qwen):
    """Mixed batch, staggered arrivals: the paged/batched-prefill engine
    produces token-identical greedy outputs to the seed-style dense engine."""
    cfg, params = qwen
    reqs = _requests(cfg, 6, seed=1)
    ref, _ = _run(cfg, params, reqs, DENSE, stagger=3)
    out, eng = _run(cfg, params, reqs, BASE, stagger=3)
    assert ref == out
    st = eng.stats()
    assert st["finished"] == 6
    assert st["mode"] == "paged+batched-prefill"


def test_batched_prefill_matches_token_replay(qwen):
    """Cache state + next-token logits after batched prefill equal those
    after feeding the prompt token-by-token through decode_step."""
    cfg, params = qwen
    s_max = 64
    rng = np.random.default_rng(3)
    n = 21
    prompt = rng.integers(3, cfg.vocab_size, n)

    cache = init_params(cache_specs(cfg, 1, s_max), jax.random.PRNGKey(1))
    step = jax.jit(lambda c, t: decode_step(params, cfg, c, t))
    for i in range(n):
        replay_logits, cache = step(
            cache, jnp.asarray(prompt[None, i: i + 1], jnp.int32)
        )

    n_pad = 32
    tokens = np.zeros((1, n_pad), np.int32)
    tokens[0, :n] = prompt
    logits, pcache = batched_prefill(
        params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
        seq_max=s_max,
    )
    np.testing.assert_allclose(
        np.asarray(logits[0, n - 1], np.float32),
        np.asarray(replay_logits[0, 0], np.float32), atol=2e-4, rtol=2e-4,
    )
    assert int(pcache["pos"]) == n == int(cache["pos"])
    ref_l, new_l = cache["layers"], pcache["layers"]
    get = (lambda t, k: t[k]) if not isinstance(ref_l, list) else (
        lambda t, k: jnp.stack([la[k] for la in t])
    )
    # Layer 0 is a pure accumulation path (no upstream attention): cumsum
    # must match sequential _lmk_add to fp epsilon.
    for key in ("q_lmk", "k_lmk"):
        np.testing.assert_allclose(
            np.asarray(get(new_l, key))[0], np.asarray(get(ref_l, key))[0],
            atol=1e-4, rtol=1e-4,
        )
    np.testing.assert_array_equal(
        np.asarray(get(new_l, "k"))[0],
        np.asarray(get(ref_l, "k"))[0][..., :n_pad, :],
    )
    # Deeper layers inherit fp-reassociation noise amplified through the
    # layer-0 pseudoinverse (vmapped vs sequential attention); greedy
    # outputs stay identical (test_paged_vs_dense_token_identical).
    for key in ("q_lmk", "k_lmk"):
        np.testing.assert_allclose(
            np.asarray(get(new_l, key)), np.asarray(get(ref_l, key)),
            atol=5e-2, rtol=5e-2,
        )
    np.testing.assert_allclose(
        np.asarray(get(new_l, "k")),
        np.asarray(get(ref_l, "k"))[..., :n_pad, :], atol=5e-2, rtol=5e-2,
    )


def test_preemption_roundtrip_identical(qwen):
    """A pool too small for all lanes forces preemption; the preempted
    request restarts from scratch and still finishes with identical
    greedy output."""
    cfg, params = qwen
    reqs = _requests(cfg, 4, seed=2, lo=20, hi=21, max_new=30)
    serve = dataclasses.replace(BASE, max_lanes=3, num_blocks=12)
    ref, _ = _run(cfg, params, reqs, dataclasses.replace(
        DENSE, max_lanes=3))
    out, eng = _run(cfg, params, reqs, serve)
    st = eng.stats()
    assert st["preemptions"] > 0, "pool should have forced preemption"
    assert st["finished"] == 4
    assert ref == out
    assert st["kv"]["blocks_used"] == 0  # everything released at the end


def test_scheduler_metrics_and_ttft(qwen):
    """Batched prefill: first token lands one tick after admission, and the
    engine surfaces latency/utilization counters."""
    cfg, params = qwen
    reqs = _requests(cfg, 1, seed=4, lo=30, hi=31, max_new=4)
    _, eng = _run(cfg, params, reqs, BASE)
    st = eng.stats()
    assert st["ttft_ticks_p50"] == 1.0  # one tick: prefill + first sample
    assert st["new_tokens"] == 4
    _, eng_d = _run(cfg, params, reqs, DENSE)
    # token replay pays one tick per prompt token before the first sample
    assert eng_d.stats()["ttft_ticks_p50"] == float(len(reqs[0].prompt))


def test_ssm_family_falls_back_dense():
    """xLSTM has no sequence-shaped cache: the engine runs lane-dense with
    no allocator, and outputs match the seed configuration."""
    cfg = reduced(get_config("xlstm-350m"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, 3, seed=5)
    ref, _ = _run(cfg, params, reqs, DENSE)
    out, eng = _run(cfg, params, reqs, BASE)
    assert ref == out
    assert eng.stats()["mode"] == "dense+replay-prefill"
    assert "kv" not in eng.stats()


def test_defragment_mid_stream_preserves_outputs(qwen):
    """engine.defragment() between ticks permutes pool storage + tables
    consistently: in-flight requests finish with unchanged output."""
    cfg, params = qwen
    reqs = _requests(cfg, 4, seed=8, max_new=12)
    ref, _ = _run(cfg, params, reqs, DENSE)
    eng = ServeEngine(cfg, params, serve=BASE)
    for r in reqs:
        eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
    moved_total = 0
    for _ in range(60):
        if eng.sched.idle:
            break
        eng.tick()
        moved_total += eng.defragment()  # compact while requests in flight
    out = eng.run()
    assert ref == out
    # retirements between staggered requests leave holes, so compaction
    # must actually have moved something for this test to mean anything
    assert moved_total > 0


def test_ss_fused_prefill_runs(qwen):
    """The Pallas-kernel prefill path (approximate prompt attention) serves
    a batch end-to-end and leaves exact landmark state behind."""
    cfg, params = qwen
    reqs = _requests(cfg, 3, seed=6)
    serve = dataclasses.replace(BASE, prefill_impl="ss_fused")
    out, eng = _run(cfg, params, reqs, serve)
    assert eng.stats()["finished"] == 3
    assert all(len(v) > 0 for v in out.values())


# ==========================================================================
# Bucketed ss_fused prefill (key-validity masked kernels)
# ==========================================================================
def test_ss_fused_prefill_padding_invariant(qwen):
    """Bucket-padded ss_fused prefill == unpadded ss_fused prefill: the
    dynamic kv_valid bound keeps padded zero-keys out of the softmax, so
    logits at valid positions and the cache state are identical."""
    cfg, params = qwen
    s_max = 64
    rng = np.random.default_rng(11)
    n = 21  # > num_landmarks (16): the masked fused path
    prompt = rng.integers(3, cfg.vocab_size, n)

    def run(n_pad):
        tokens = np.zeros((1, n_pad), np.int32)
        tokens[0, :n] = prompt
        return batched_prefill(
            params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
            seq_max=s_max, prefill_impl="ss_fused",
        )

    logits_u, cache_u = run(n)       # unpadded reference
    logits_p, cache_p = run(32)      # bucket-padded
    np.testing.assert_allclose(
        np.asarray(logits_p[0, :n], np.float32),
        np.asarray(logits_u[0], np.float32), atol=1e-4, rtol=1e-4,
    )
    assert int(np.argmax(logits_p[0, n - 1])) == int(np.argmax(logits_u[0, n - 1]))
    get = (lambda t, k: jnp.stack([la[k] for la in t])) if isinstance(
        cache_u["layers"], list) else (lambda t, k: t[k])
    for key in ("q_lmk", "k_lmk"):
        np.testing.assert_allclose(
            np.asarray(get(cache_p["layers"], key), np.float32),
            np.asarray(get(cache_u["layers"], key), np.float32),
            atol=1e-4, rtol=1e-4,
        )
    np.testing.assert_allclose(
        np.asarray(get(cache_p["layers"], "k"))[..., :n, :],
        np.asarray(get(cache_u["layers"], "k"))[..., :n, :],
        atol=1e-4, rtol=1e-4,
    )


def test_ss_fused_bucket_size_token_identical(qwen):
    """Greedy engine outputs are invariant to the bucket size in ss_fused
    mode — padding is invisible end to end (prompts > num_landmarks so the
    masked kernels, not the degenerate exact path, are exercised)."""
    cfg, params = qwen
    reqs = _requests(cfg, 4, seed=9, lo=18, hi=30)
    outs = []
    for bucket in (8, 32):
        serve = dataclasses.replace(
            BASE, prefill_impl="ss_fused", prefill_bucket=bucket)
        out, eng = _run(cfg, params, reqs, serve)
        assert eng.stats()["finished"] == 4
        outs.append(out)
    assert outs[0] == outs[1]


def test_ss_fused_degenerate_prompt_unpadded(qwen):
    """Prompts of <= num_landmarks tokens take the exact-attention path and
    still serve correctly (the engine slices them to exact length)."""
    cfg, params = qwen
    reqs = _requests(cfg, 3, seed=10, lo=4, hi=16)  # all <= 16 landmarks
    serve = dataclasses.replace(BASE, prefill_impl="ss_fused")
    out, eng = _run(cfg, params, reqs, serve)
    assert eng.stats()["finished"] == 3
    assert all(len(v) > 0 for v in out.values())


def test_engine_warms_decode_plan(qwen):
    """ServeEngine resolves the decode-shape dispatch key at construction
    and surfaces the plan in stats()."""
    from repro.kernels import dispatch

    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    assert eng.decode_plan.impl in ("jnp", "fused", "interpret", "sharded")
    key = dispatch.make_key(
        BASE.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
        cfg.compute_dtype, True, family="decode",
    )
    assert key.family == "decode"
    # The heuristic decode plan routes to the jnp decode math.
    assert eng.decode_plan.impl == "jnp"
    assert eng.stats()["decode_plan"].startswith("jnp/")


def test_ss_fused_degenerate_padded_prompt_exact(qwen):
    """Regression: a bucket-padded window of <= num_landmarks tokens takes
    the exact path WITH the key-validity mask applied — padded zero-keys
    must not shift the logits or the next token."""
    cfg, params = qwen
    rng = np.random.default_rng(13)
    n = 5  # << num_landmarks (16)
    prompt = rng.integers(3, cfg.vocab_size, n)

    def run(n_pad):
        tokens = np.zeros((1, n_pad), np.int32)
        tokens[0, :n] = prompt
        return batched_prefill(
            params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
            seq_max=64, prefill_impl="ss_fused",
        )

    logits_u, _ = run(n)
    logits_p, _ = run(8)
    np.testing.assert_allclose(
        np.asarray(logits_p[0, :n], np.float32),
        np.asarray(logits_u[0], np.float32), atol=1e-4, rtol=1e-4,
    )
    assert int(np.argmax(logits_p[0, n - 1])) == int(np.argmax(logits_u[0, n - 1]))


def test_engine_honors_autotune_cache_override(qwen, tmp_path):
    """Regression: ServeEngine's dispatch warm-up loads plans from
    ModelConfig.autotune_cache, like the Trainer does."""
    from repro.kernels import dispatch

    cfg, params = qwen
    cache = tmp_path / "tuned.json"
    key = dispatch.make_key(
        BASE.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
        cfg.compute_dtype, True, family="decode",
    )
    dispatch.clear_registry()
    dispatch.register_plan(
        key, dispatch.Plan(impl="jnp", block_n=64, source="autotuned"))
    dispatch.save_cache(str(cache))
    dispatch.clear_registry()
    try:
        eng = ServeEngine(
            dataclasses.replace(cfg, autotune_cache=str(cache)), params,
            serve=BASE,
        )
        assert eng.decode_plan.block_n == 64
        assert eng.decode_plan.source == "cache"
    finally:
        dispatch.clear_registry()  # drop the process-wide cache override


# ==========================================================================
# Streaming decode state (serve/decode_state.py)
# ==========================================================================
def _stats_logmass(lc):
    """Anchor-invariant total softmax mass per row: log(l) + m."""
    return np.log(np.maximum(np.asarray(lc["bv_l"], np.float64), 1e-300)) \
        + np.asarray(lc["bv_m"], np.float64)


def _layer0(cache):
    layers = cache["layers"]
    if isinstance(layers, list):
        return layers[0]
    return jax.tree.map(lambda a: a[0], layers)


class TestStreamingDecode:
    def test_exact_token_identical_dense_and_paged(self, qwen):
        """decode_streaming="exact" produces greedy outputs token-identical
        to the legacy recompute path, on both engines."""
        cfg, params = qwen
        reqs = _requests(cfg, 5, seed=21)
        outs = {}
        for mode in ("recompute", "exact"):
            mcfg = dataclasses.replace(cfg, decode_streaming=mode)
            ref, _ = _run(mcfg, params, reqs, DENSE, stagger=2)
            out, eng = _run(mcfg, params, reqs, BASE, stagger=2)
            assert ref == out, f"paged != dense under {mode}"
            assert eng.stats()["decode_streaming"] == mode
            outs[mode] = ref
        assert outs["recompute"] == outs["exact"]

    def test_exact_stats_match_recompute_invariant(self, qwen):
        """After token-by-token decode in exact mode, every reached row's
        (m, l, acc) equals the one-shot exact recompute (same softmax, fp
        reassociation only); unreached rows hold the zero state."""
        from repro.models.attention import _broadcast_kv
        from repro.serve.decode_state import (
            landmark_counts, landmark_means, recompute_stats, segment_len,
        )

        cfg, params = qwen
        s_max = 64
        rng = np.random.default_rng(22)
        n = 23
        prompt = rng.integers(3, cfg.vocab_size, n)
        cache = init_params(cache_specs(cfg, 1, s_max), jax.random.PRNGKey(1))
        step = jax.jit(lambda c, t: decode_step(params, cfg, c, t,
                                                seq_max=s_max))
        for i in range(n):
            _, cache = step(cache, jnp.asarray(prompt[None, i:i+1], jnp.int32))
        lc = _layer0(cache)
        pos = n - 1
        c = cfg.num_landmarks
        counts = landmark_counts(jnp.asarray(pos), s_max, c)
        q_l = landmark_means(lc["q_lmk"], counts)
        kb = _broadcast_kv(lc["k"], cfg.num_heads)
        vb = _broadcast_kv(lc["v"], cfg.num_heads)
        m, l, acc = recompute_stats(
            q_l, kb, vb, pos, cfg.resolved_head_dim ** -0.5,
            row_valid=counts > 0,
        )
        active = pos // segment_len(s_max, c)
        bv_ref = np.asarray(acc / jnp.maximum(l, 1e-30))
        bv_got = np.asarray(lc["bv_acc"] / jnp.maximum(lc["bv_l"], 1e-30))
        np.testing.assert_allclose(
            bv_got[..., : active + 1, :], bv_ref[..., : active + 1, :],
            atol=2e-4, rtol=2e-4,
        )
        # anchor-invariant mass agrees on reached rows
        mass_ref = np.log(np.maximum(np.asarray(l, np.float64), 1e-300)) \
            + np.asarray(m, np.float64)
        np.testing.assert_allclose(
            _stats_logmass(lc)[..., : active + 1, :],
            mass_ref[..., : active + 1, :], atol=1e-4, rtol=1e-4,
        )
        # unreached rows: exact zero state
        for name in ("bv_m", "bv_l", "bv_acc"):
            assert np.all(np.asarray(lc[name])[..., active + 1:, :] == 0)

    def test_prefill_rebuilds_streaming_state(self, qwen):
        """The preemption-recompute path (batched prefill on re-admission)
        rebuilds the same streaming stats token-by-token decode had
        accumulated: normalized BV and total mass agree row-for-row."""
        cfg, params = qwen
        s_max = 64
        rng = np.random.default_rng(23)
        n = 21
        prompt = rng.integers(3, cfg.vocab_size, n)
        cache = init_params(cache_specs(cfg, 1, s_max), jax.random.PRNGKey(1))
        step = jax.jit(lambda c, t: decode_step(params, cfg, c, t,
                                                seq_max=s_max))
        for i in range(n):
            _, cache = step(cache, jnp.asarray(prompt[None, i:i+1], jnp.int32))
        tokens = np.zeros((1, 32), np.int32)
        tokens[0, :n] = prompt
        _, pcache = batched_prefill(
            params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
            seq_max=s_max,
        )
        lc_d, lc_p = _layer0(cache), _layer0(pcache)
        bv_d = np.asarray(lc_d["bv_acc"] / jnp.maximum(lc_d["bv_l"], 1e-30))
        bv_p = np.asarray(lc_p["bv_acc"] / jnp.maximum(lc_p["bv_l"], 1e-30))
        np.testing.assert_allclose(bv_p, bv_d, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(
            _stats_logmass(lc_p), _stats_logmass(lc_d), atol=1e-4, rtol=1e-4,
        )

    def test_preemption_roundtrip_streaming_engine(self, qwen):
        """Pool pressure forces preemption; the preempted request recomputes
        through prefill and the streaming state survives the round trip. In
        exact mode that means token-identity with the dense reference; in
        frozen mode (approximate by design, and prefill-path dependent) the
        preempting run must be deterministic and complete."""
        cfg, params = qwen
        serve = dataclasses.replace(BASE, max_lanes=3, num_blocks=12)

        mcfg = dataclasses.replace(cfg, decode_streaming="exact")
        reqs = _requests(mcfg, 4, seed=24, lo=20, hi=21, max_new=30)
        ref, _ = _run(mcfg, params, reqs,
                      dataclasses.replace(DENSE, max_lanes=3))
        out, eng = _run(mcfg, params, reqs, serve)
        assert eng.stats()["preemptions"] > 0
        assert ref == out

        fcfg = dataclasses.replace(cfg, decode_streaming="frozen")
        out1, eng1 = _run(fcfg, params, reqs, serve)
        out2, eng2 = _run(fcfg, params, reqs, serve)
        assert eng1.stats()["preemptions"] > 0
        assert eng1.stats()["finished"] == 4
        assert out1 == out2

    def test_frozen_boundary_rebase_correctness(self, qwen):
        """Frozen mode with boundary rebases: every frozen row's stats equal
        the exact recompute (the drift its active phase accumulated is
        cleared by the lazy rebase); only the active row may drift."""
        from repro.models.attention import _broadcast_kv
        from repro.serve.decode_state import (
            landmark_counts, landmark_means, make_rebase_fn,
            recompute_stats, segment_len,
        )

        cfg, params = qwen
        mcfg = dataclasses.replace(cfg, decode_streaming="frozen")
        s_max = 48
        c = mcfg.num_landmarks
        seg = segment_len(s_max, c)  # 3 tokens per segment
        rng = np.random.default_rng(25)
        n = 20
        prompt = rng.integers(3, mcfg.vocab_size, n)
        cache = init_params(cache_specs(mcfg, 1, s_max), jax.random.PRNGKey(1))
        step = jax.jit(lambda ca, t: decode_step(params, mcfg, ca, t,
                                                 seq_max=s_max))
        rebase = jax.jit(make_rebase_fn(mcfg, s_max))
        for i in range(n):
            _, cache = step(cache, jnp.asarray(prompt[None, i:i+1], jnp.int32))
            if i > 0 and i % seg == 0:  # the engine's boundary trigger
                cache = rebase(cache, jnp.asarray(i))
        lc = _layer0(cache)
        pos = n - 1
        counts = landmark_counts(jnp.asarray(pos), s_max, c)
        q_l = landmark_means(lc["q_lmk"], counts)
        kb = _broadcast_kv(lc["k"], mcfg.num_heads)
        vb = _broadcast_kv(lc["v"], mcfg.num_heads)
        m, l, acc = recompute_stats(
            q_l, kb, vb, pos, mcfg.resolved_head_dim ** -0.5,
            row_valid=counts > 0,
        )
        active = pos // seg
        assert active >= 2, "test needs several frozen segments"
        bv_ref = np.asarray(acc / jnp.maximum(l, 1e-30))
        bv_got = np.asarray(lc["bv_acc"] / jnp.maximum(lc["bv_l"], 1e-30))
        np.testing.assert_allclose(  # frozen rows: exact after rebases
            bv_got[..., :active, :], bv_ref[..., :active, :],
            atol=2e-4, rtol=2e-4,
        )

    def test_frozen_engine_paged_matches_dense(self, qwen):
        """Frozen mode end to end: with the prefill strategy held fixed
        (frozen state is prefill-path dependent by design — batched prefill
        seeds exact stats, replay accumulates bounded drift), paged and
        dense storage agree token-for-token and rebases fire in both."""
        cfg, params = qwen
        mcfg = dataclasses.replace(cfg, decode_streaming="frozen")
        reqs = _requests(mcfg, 4, seed=26, max_new=16)
        dense_batched = dataclasses.replace(BASE, paged=False)
        ref, eng_d = _run(mcfg, params, reqs, dense_batched)
        out, eng_p = _run(mcfg, params, reqs, BASE)
        assert ref == out
        assert eng_p.stats()["rebases"] > 0
        assert eng_d.stats()["rebases"] > 0

    def test_ss_fused_prefill_stats_handoff(self, qwen):
        """ss_fused prefill hands the landmark_summary kernel's (m, l, BV)
        into the cache: equivalent to the jnp recompute on reached rows,
        zero elsewhere — and greedy decode continues identically from it."""
        from repro.models.attention import _broadcast_kv
        from repro.serve.decode_state import (
            landmark_counts, landmark_means, mask_stats_rows,
            recompute_stats, segment_len,
        )

        cfg, params = qwen
        s_max = 64
        rng = np.random.default_rng(27)
        n = 21  # > num_landmarks: the masked-kernel regime
        prompt = rng.integers(3, cfg.vocab_size, n)
        tokens = np.zeros((1, 32), np.int32)
        tokens[0, :n] = prompt
        _, pc = batched_prefill(
            params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
            seq_max=s_max, prefill_impl="ss_fused",
        )
        lc = _layer0(pc)
        c = cfg.num_landmarks
        counts = landmark_counts(jnp.asarray(n - 1), s_max, c)
        q_l = landmark_means(lc["q_lmk"], counts)
        kb = _broadcast_kv(lc["k"], cfg.num_heads)
        vb = _broadcast_kv(lc["v"], cfg.num_heads)
        keep = jnp.arange(c) <= (n - 1) // segment_len(s_max, c)
        m, l, acc = mask_stats_rows(
            recompute_stats(q_l, kb, vb, n - 1,
                            cfg.resolved_head_dim ** -0.5),
            keep,
        )
        bv_ref = np.asarray(acc / jnp.maximum(l, 1e-30))
        bv_got = np.asarray(lc["bv_acc"] / jnp.maximum(lc["bv_l"], 1e-30))
        np.testing.assert_allclose(bv_got, bv_ref, atol=1e-4, rtol=1e-4)
        for name in ("bv_m", "bv_l", "bv_acc"):
            assert np.all(
                np.asarray(lc[name])[..., int(np.sum(keep)):, :] == 0
            )
        # greedy continuation from the kernel-seeded cache == from the
        # jnp-recomputed stats (exact mode overwrites only the active row)
        lc_fix = dict(lc, bv_m=m, bv_l=l, bv_acc=acc)
        if isinstance(pc["layers"], list):
            pc_fix = dict(pc, layers=[lc_fix] + pc["layers"][1:])
        else:
            pc_fix = dict(pc, layers=jax.tree.map(
                lambda full, one: full.at[0].set(one), pc["layers"], lc_fix))
        step = jax.jit(lambda ca, t: decode_step(params, cfg, ca, t,
                                                 seq_max=s_max))
        tok = jnp.asarray([[prompt[-1]]], jnp.int32)
        outs = []
        for start in (pc, pc_fix):
            ca, t = start, tok
            toks = []
            for _ in range(6):
                lg, ca = step(ca, t)
                t = jnp.argmax(lg[:, :, : cfg.vocab_size], -1).astype(jnp.int32)
                toks.append(int(t[0, 0]))
            outs.append(toks)
        assert outs[0] == outs[1]

    def test_stream_append_chain_equals_recompute(self):
        """decode_state unit: a chain of flash-appends from the zero state
        equals the one-shot exact stats (the algebra the whole subsystem
        rests on), including the zeros-as-empty anchor convention."""
        from repro.serve.decode_state import recompute_stats, stream_append

        rng = np.random.default_rng(28)
        B, H, c, d, S = 1, 2, 4, 8, 12
        q_l = jnp.asarray(rng.normal(size=(B, H, c, d)), jnp.float32)
        ks = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
        vs = jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
        scale = 0.5
        stats = (jnp.zeros((B, H, c, 1)), jnp.zeros((B, H, c, 1)),
                 jnp.zeros((B, H, c, d)))
        for t in range(S):
            stats = stream_append(stats, q_l, ks[:, :, t], vs[:, :, t], scale)
        m_r, l_r, acc_r = recompute_stats(q_l, ks, vs, S - 1, scale)
        bv_stream = stats[2] / jnp.maximum(stats[1], 1e-30)
        bv_ref = acc_r / jnp.maximum(l_r, 1e-30)
        np.testing.assert_allclose(bv_stream, bv_ref, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(jnp.log(stats[1]) + stats[0]),
            np.asarray(jnp.log(l_r) + m_r), atol=1e-5, rtol=1e-5,
        )


# ==========================================================================
# Gather-free paged decode (ServeConfig.decode_impl="paged")
# ==========================================================================
PAGED_IMPL = dataclasses.replace(BASE, decode_impl="paged")


class TestPagedDecodeImpl:
    """The gather-free decode tick (block-table Pallas kernel + single-
    block scatter commit) against the gather route and the dense engine."""

    def test_token_identical_across_modes(self, qwen):
        """exact and frozen modes: paged impl == gather impl == dense
        engine on greedy, with staggered mixed batches (ragged prompt
        lengths exercise the ragged-last-block path)."""
        cfg, params = qwen
        reqs = _requests(cfg, 5, seed=31)
        for mode in ("exact", "frozen"):
            mcfg = dataclasses.replace(cfg, decode_streaming=mode)
            if mode == "exact":  # dense engine == paged storage invariant
                ref, _ = _run(mcfg, params, reqs, DENSE, stagger=2)
            else:
                # frozen is prefill-path dependent: hold batched prefill
                # fixed and take dense storage as the reference
                ref, _ = _run(mcfg, params, reqs,
                              dataclasses.replace(BASE, paged=False),
                              stagger=2)
            gat, _ = _run(mcfg, params, reqs, BASE, stagger=2)
            assert ref == gat, f"gather != dense reference under {mode}"
            out, eng = _run(mcfg, params, reqs, PAGED_IMPL, stagger=2)
            assert eng.stats()["decode_impl"] == "paged"
            assert gat == out, f"paged != gather under {mode}"

    def test_recompute_falls_back_to_gather(self, qwen):
        """decode_streaming="recompute" rebuilds the dense B matrix: the
        paged request falls back to the gather route (surfaced in stats)
        and stays token-identical."""
        cfg, params = qwen
        mcfg = dataclasses.replace(cfg, decode_streaming="recompute")
        reqs = _requests(mcfg, 3, seed=32)
        ref, _ = _run(mcfg, params, reqs, BASE)
        out, eng = _run(mcfg, params, reqs, PAGED_IMPL)
        assert eng.stats()["decode_impl"] == "gather"
        assert ref == out

    def test_full_attention_impl(self, qwen):
        """decode_attention_impl="full": the same kernel serves the exact-
        attention decode rows (acc / l), token-identical to the gather
        route."""
        cfg, params = qwen
        mcfg = dataclasses.replace(cfg, decode_attention_impl="full")
        reqs = _requests(mcfg, 3, seed=33)
        ref, _ = _run(mcfg, params, reqs, BASE)
        out, eng = _run(mcfg, params, reqs, PAGED_IMPL)
        assert eng.stats()["decode_impl"] == "paged"
        assert ref == out

    def test_preemption_requeue_roundtrip(self, qwen):
        """Pool pressure forces preemption under the paged impl; the
        preempted request recomputes through prefill and finishes with the
        dense engine's greedy output (exact mode)."""
        cfg, params = qwen
        reqs = _requests(cfg, 4, seed=34, lo=20, hi=21, max_new=30)
        serve = dataclasses.replace(
            PAGED_IMPL, max_lanes=3, num_blocks=12)
        ref, _ = _run(cfg, params, reqs,
                      dataclasses.replace(DENSE, max_lanes=3))
        out, eng = _run(cfg, params, reqs, serve)
        assert eng.stats()["preemptions"] > 0
        assert eng.stats()["decode_impl"] == "paged"
        assert ref == out

    def test_zero_block_stays_zero(self, qwen):
        """ZERO_BLOCK backs unallocated table slots; inactive-lane commits
        dump into it and are re-zeroed — after a full run every seq leaf's
        block 0 must be exactly zero."""
        cfg, params = qwen
        reqs = _requests(cfg, 4, seed=35)
        _, eng = _run(cfg, params, reqs, PAGED_IMPL)
        for arr, info in zip(eng.kv._storage, eng.kv.infos):
            if info.seq_axis is None:
                continue
            pre = (slice(None),) * info.seq_axis
            assert np.all(np.asarray(arr[(*pre, ZERO_BLOCK)]) == 0.0)

    def test_mla_paged_decode(self):
        """Absorbed MLA runs gather-free through the two-pool kernel
        (latent + rope), token-identical to the gather route."""
        cfg = dataclasses.replace(
            reduced(get_config("deepseek-v2-lite-16b")), capacity_factor=100.0
        )
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
        reqs = _requests(cfg, 3, seed=36)
        ref, _ = _run(cfg, params, reqs, BASE)
        out, eng = _run(cfg, params, reqs, PAGED_IMPL)
        assert eng.stats()["decode_impl"] == "paged"
        assert ref == out

    def test_hybrid_family_paged_decode(self):
        """Hybrid (attention + mamba) lanes: attention leaves page, SSM
        state stays dense; replay prefill feeds the paged tick from
        pos=0 (the kv_valid=0 empty-kernel edge)."""
        cfg = reduced(get_config("hymba-1.5b"))
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
        reqs = _requests(cfg, 2, seed=37, lo=4, hi=10, max_new=4)
        ref, _ = _run(cfg, params, reqs, BASE)
        out, eng = _run(cfg, params, reqs, PAGED_IMPL)
        assert eng.stats()["decode_impl"] == "paged"
        assert ref == out

    def test_defragment_mid_stream(self, qwen):
        """Block-table permutation (defragment) between ticks is invisible
        to the paged kernel route."""
        cfg, params = qwen
        reqs = _requests(cfg, 4, seed=38, max_new=12)
        ref, _ = _run(cfg, params, reqs, DENSE)
        eng = ServeEngine(cfg, params, serve=PAGED_IMPL)
        for r in reqs:
            eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
        moved = 0
        for _ in range(60):
            if eng.sched.idle:
                break
            eng.tick()
            moved += eng.defragment()
        out = eng.run()
        assert ref == out
        assert moved > 0


def test_engine_runs_measured_decode_autotune(qwen, tmp_path):
    """ModelConfig.autotune=True: ServeEngine's warm-up runs the measured
    decode sweep (gather vs paged across block_table) at the DEPLOYMENT's
    block size and registers the winner under the decode key."""
    import dataclasses as dc

    from repro.kernels import dispatch

    cfg, params = qwen
    cache = tmp_path / "tuned.json"
    dispatch.clear_registry()
    try:
        eng = ServeEngine(
            dc.replace(cfg, autotune=True, autotune_cache=str(cache)),
            params, serve=BASE,
        )
        assert eng.decode_plan.source == "autotuned"
        assert eng.decode_plan.impl in ("jnp", "paged")
        key = dispatch.make_key(
            BASE.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
            cfg.compute_dtype, True, family="decode",
        )
        got = dispatch.get_plan(key)  # registered: no re-sweep
        assert (got.impl, got.block_table) == (
            eng.decode_plan.impl, eng.decode_plan.block_table
        )
        assert cache.exists()  # winner persisted to the override path
    finally:
        dispatch.clear_registry()
