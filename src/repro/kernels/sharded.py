"""Context-parallel (sequence-sharded) driver for the fused spectral-shift
attention: shard_map around the single-device Pallas kernels.

Why this is cheap for *this* method: the only cross-shard state is landmark-
sized. A flash kernel would need a ring exchange of full K/V blocks, but the
spectral-shift factorization reduces every cross-device interaction to
(c, d)-shaped summaries:

    landmarks   Q~/K~ — masked per-shard segment sums, one (c, d) psum;
    B-side      BV = softmax(Q~ K^T) V — each shard streams its local keys
                with the existing ``landmark_summary`` kernel and emits its
                online-softmax partials (acc, m, l); the global softmax is
                the standard flash merge: m* = pmax(m), l* = psum(l e^{m-m*}),
                BV* = psum(acc e^{m-m*}) / l* — all (c, ·)-sized collectives;
    core        U_ss/delta — O(c^3) jnp on the replicated landmarks, computed
                identically on every device (no collective);
    F-side      out = softmax(Q K~^T) M + delta V — purely shard-local: the
                softmax axis (c) is resident, queries/values are the shard's
                own rows.

Gradients flow through ``jax.custom_vjp`` ops defined *inside* the shard_map
body: the forward saves the **global** (BV, m, l) statistics (tagged
``ss_bv``/``ss_stats`` so ``remat="ss_stats"`` keeps working under SP), and
the backward runs the existing flash-backward kernels per shard against
those global stats — reconstruction is exact. Collective accounting under
``check_rep=False`` (where psum transposes to psum): the B-side backward
psums the per-shard cotangents of the replicated BV* once, and every
cotangent of a replicated *input* (dQ~, dK~, dM, ddelta) is returned as the
shard's local partial — the transpose of the psum that replicated the
primal performs the cross-shard accumulation, so an explicit reduction
would double count.

Ragged shards: n is zero-padded to a multiple of the shard count and every
kernel takes the shard's global ``kv_offset``/``q_offset`` plus the true
sequence end as dynamic bounds (SMEM scalars, see ss_attention.py), so the
padded tail never enters a softmax and sliced-off query rows carry zero
cotangent.

Entry point: ``ss_attention_fused_sharded``; model code reaches it through
``kernels.dispatch.dispatch_ss_attention``, which resolves the active mesh /
sequence axes from ``distributed.sharding.active_seq_sharding()``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 moved shard_map out of experimental
    from jax.shard_map import shard_map
except ImportError:  # pragma: no cover - version-dependent import
    from jax.experimental.shard_map import shard_map

from repro.core.attention import SSConfig
from repro.core.landmarks import onehot_segment_sums, segment_counts
from repro.kernels.ops import _float0_like, flash_rescale, ss_core_factors
from repro.kernels.ss_attention import landmark_summary, query_side
from repro.kernels.ss_attention_bwd import landmark_summary_bwd, query_side_bwd


# --------------------------------------------------------------------------
# Sharded custom-VJP ops (used INSIDE the shard_map body).
# meta = (scale, block_n, causal, n_global, interpret, seq_axes)
# --------------------------------------------------------------------------
def _landmark_summary_sp_merge(meta, q_l, k, v, off):
    scale, block_n, causal, n_glob, interpret, axes = meta
    bv, m, l = landmark_summary(
        q_l, k, v, scale=scale, block_n=block_n, causal=causal,
        interpret=interpret, return_stats=True, kv_offset=off,
        kv_valid=n_glob, seq_len_k=n_glob,
    )
    # Flash merge of the per-shard online-softmax partials: re-anchor every
    # shard's (l, acc) to the global row max (shared ops.flash_rescale —
    # the same algebra the streaming decode state appends with), then psum.
    # ``bv`` is the locally-normalized numerator (acc / l), so acc = bv * l.
    m_g = jax.lax.pmax(m, axes)
    l_r, acc_r = flash_rescale(m, l, bv.astype(jnp.float32) * l, m_g)
    l_g = jax.lax.psum(l_r, axes)
    acc_g = jax.lax.psum(acc_r, axes)
    bv_g = (acc_g / jnp.maximum(l_g, 1e-30)).astype(v.dtype)
    return bv_g, m_g, l_g


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _landmark_summary_sp(meta, q_l, k, v, off):
    """Global BV over sequence-sharded keys. ``q_l`` replicated, ``k``/``v``
    the shard's local rows, ``off`` the shard's global key offset."""
    bv_g, _, _ = _landmark_summary_sp_merge(meta, q_l, k, v, off)
    return bv_g


def _landmark_summary_sp_fwd(meta, q_l, k, v, off):
    bv_g, m_g, l_g = _landmark_summary_sp_merge(meta, q_l, k, v, off)
    res = (
        q_l, k, v, off,
        checkpoint_name(bv_g, "ss_bv"),
        checkpoint_name(m_g, "ss_stats"),
        checkpoint_name(l_g, "ss_stats"),
    )
    return bv_g, res


def _landmark_summary_sp_bwd(meta, res, g):
    scale, block_n, causal, n_glob, interpret, axes = meta
    q_l, k, v, off, bv_g, m_g, l_g = res
    # The replicated output BV* is consumed independently by every shard's
    # downstream (each produces different out rows), so the TRUE cotangent
    # of BV* is the psum of the per-shard cotangents — reduce it once here.
    g = jax.lax.psum(g, axes)
    # Per-shard backward against the GLOBAL stats: P = exp(s - m*) / l* is
    # the exact global softmax factor restricted to local key columns, so
    # dK/dV are shard-complete and dQ~ is the shard's LOCAL partial. No
    # psum on dQ~: under ``check_rep=False`` the transpose of the psum that
    # replicated q_l is itself a psum, which accumulates the partials —
    # reducing here as well would double count.
    dq_l, dk, dv = landmark_summary_bwd(
        q_l, k, v, bv_g, m_g, l_g, g, scale=scale, block_n=block_n,
        causal=causal, interpret=interpret, kv_offset=off, kv_valid=n_glob,
        seq_len_k=n_glob,
    )
    return dq_l, dk, dv, _float0_like(off)


_landmark_summary_sp.defvjp(_landmark_summary_sp_fwd, _landmark_summary_sp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _query_side_sp(meta, q, k_l, m_mat, v, delta, off):
    """Shard-local F-side: out rows for the shard's queries at global
    offset ``off``. k_l / m_mat / delta are replicated."""
    scale, block_n, causal, n_glob, interpret, _ = meta
    return query_side(
        q, k_l, m_mat, v, delta, scale=scale, block_n=block_n, causal=causal,
        seq_len_k=n_glob, interpret=interpret, q_offset=off,
    )


def _query_side_sp_fwd(meta, q, k_l, m_mat, v, delta, off):
    return _query_side_sp(meta, q, k_l, m_mat, v, delta, off), (
        q, k_l, m_mat, v, delta, off,
    )


def _query_side_sp_bwd(meta, res, g):
    scale, block_n, causal, n_glob, interpret, axes = meta
    q, k_l, m_mat, v, delta, off = res
    # Purely shard-local op (the softmax axis c is resident): every
    # cotangent is the shard's local partial. dK~/dM/ddelta accumulate over
    # shards via the psum-transposes of the collectives that replicated
    # their primals — no explicit reduction here (see B-side note).
    dq, dkl, dm, dv, dd = query_side_bwd(
        q, k_l, m_mat, v, delta, g, scale=scale, block_n=block_n,
        causal=causal, seq_len_k=n_glob, interpret=interpret, q_offset=off,
    )
    return dq, dkl, dm, dv, dd, _float0_like(off)


_query_side_sp.defvjp(_query_side_sp_fwd, _query_side_sp_bwd)


# --------------------------------------------------------------------------
# Driver.
# --------------------------------------------------------------------------
def _shard_index(seq_axes, sizes):
    """Row-major flat shard index over (possibly multiple) mesh axes."""
    idx = jnp.int32(0)
    for ax, sz in zip(seq_axes, sizes):
        idx = idx * sz + jax.lax.axis_index(ax)
    return idx


def _masked_landmarks(x, c: int, pos, valid, seg_lm: int, n: int, axes):
    """Global segment-mean landmarks from a shard's rows: the shared
    ``onehot_segment_sums`` GEMM on GLOBAL positions, psum'd over the
    sequence axes, divided by the true global ``segment_counts`` —
    numerically the ``segment_means(via_matmul=True)`` formula."""
    oh = (
        ((pos // seg_lm)[None, :] == jnp.arange(c)[:, None])
        & valid[None, :]
    ).astype(x.dtype)                                   # (c, n_loc)
    sums = jax.lax.psum(onehot_segment_sums(x, oh), axes)  # (b, c, d)
    counts = segment_counts(n, c, seg_lm)
    return (sums / counts[:, None]).astype(x.dtype)


def ss_attention_fused_sharded(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig = SSConfig(),
    *,
    mesh: Mesh,
    seq_axes: tuple,
    lead_axes: tuple = (),
    scale: Optional[float] = None,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Sequence-sharded ``ss_attention_fused``: same math, Pallas kernels per
    shard, landmark-sized collectives. Shapes (..., n, d) with the n axis
    sharded over ``seq_axes``; leading dims flatten and shard over
    ``lead_axes`` (dropped automatically when indivisible). Differentiable
    (sharded custom-VJP ops) and segment-causal capable; self-attention only
    (n_q == n_k).
    """
    from repro.kernels.ops import ss_attention_fused

    *lead, n, d = q.shape
    n_k, dv = k.shape[-2], v.shape[-1]
    c = cfg.num_landmarks
    seq_axes = tuple(seq_axes)
    sizes = tuple(int(mesh.shape[a]) for a in seq_axes)
    n_shards = 1
    for s_ in sizes:
        n_shards *= s_
    if n != n_k:
        raise ValueError(
            "sequence-sharded fused attention is self-attention only "
            f"(n_q={n} != n_k={n_k}); route decode/cross shapes via jnp"
        )
    if n_shards <= 1 or n <= c:
        # No sharding to exploit / degenerate exact-attention regime: the
        # single-device program partitions fine under plain GSPMD.
        return ss_attention_fused(
            q, k, v, cfg, scale=scale, block_n=block_n, interpret=interpret
        )
    scale = scale if scale is not None else 1.0 / (d**0.5)
    b = 1
    for s_ in lead:
        b *= s_
    qf = q.reshape(b, n, d)
    kf = k.reshape(b, n, d)
    vf = v.reshape(b, n, dv)

    n_pad = -n % n_shards
    if n_pad:
        widths = ((0, 0), (0, n_pad), (0, 0))
        qf, kf, vf = (jnp.pad(x, widths) for x in (qf, kf, vf))
    n_loc = (n + n_pad) // n_shards
    seg_lm = -(-n // c)  # landmark segment length, from the TRUE length
    causal = cfg.causal
    meta = (scale, min(block_n, n_loc), causal, n, interpret, seq_axes)

    # Leading (batch*heads) dim keeps its sharding only when it divides.
    lead_axes = tuple(a for a in lead_axes if a in mesh.axis_names)
    lead_size = 1
    for a in lead_axes:
        lead_size *= int(mesh.shape[a])
    if lead_axes and b % lead_size:
        lead_axes = ()
    lead_spec = (lead_axes if len(lead_axes) > 1 else lead_axes[0]) if lead_axes else None
    seq_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    spec = P(lead_spec, seq_spec, None)

    def body(q_loc, k_loc, v_loc):
        b_loc = q_loc.shape[0]
        off = _shard_index(seq_axes, sizes) * n_loc
        pos = off + jnp.arange(n_loc)
        valid = pos < n

        q_l = _masked_landmarks(q_loc, c, pos, valid, seg_lm, n, seq_axes)
        k_l = _masked_landmarks(k_loc, c, pos, valid, seg_lm, n, seq_axes)

        # Replicated c x c core — identical jnp program on every device.
        u, delta_core = ss_core_factors(q_l, k_l, cfg, scale, n)

        bv = _landmark_summary_sp(meta, q_l, k_loc, v_loc, off)  # (b, c, dv)
        m_mat = jnp.matmul(
            u.astype(jnp.float32), bv.astype(jnp.float32)
        ).astype(v_loc.dtype)
        if cfg.include_shift_identity:
            delta = delta_core.astype(jnp.float32)
            v_q = v_loc
        else:
            delta = jnp.zeros((b_loc, 1, 1), jnp.float32)
            v_q = jnp.zeros_like(v_loc)
        return _query_side_sp(meta, q_loc, k_l, m_mat, v_q, delta, off)

    out = shard_map(
        body, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_rep=False,
    )(qf, kf, vf)
    if n_pad:
        out = out[:, :n]
    return out.reshape(*lead, n, dv)
