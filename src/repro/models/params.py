"""Parameter-spec infrastructure: one source of truth for shapes, logical
sharding axes, initialization, and abstract (dry-run) parameter trees.

A model declares a nested dict of ``ParamSpec``; from it we derive
 * ``init_params``      — real arrays (reduced configs, CPU smoke tests)
 * ``abstract_params``  — ShapeDtypeStructs (dry-run lowering, no allocation)
 * ``logical_axes``     — pytree of logical-axis tuples consumed by
                          ``repro.distributed.sharding`` to build PartitionSpecs.
"""
from __future__ import annotations

import hashlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones
    scale: Optional[float] = None    # stddev override (default: fan-in)
    dtype: Optional[Any] = None      # per-param dtype override


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=_is_spec)


def _fan_in_scale(spec: ParamSpec) -> float:
    if spec.scale is not None:
        return spec.scale
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    return fan_in**-0.5


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    """Materialize real parameters. Each leaf gets an independent stream
    derived from its tree path, so adding parameters never reshuffles
    existing initializations."""
    # jax.tree.flatten_with_path only exists on newer jax; use tree_util.
    paths_and_specs, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=_is_spec
    )
    leaves = []
    for path, spec in paths_and_specs:
        pdt = spec.dtype or dtype
        if spec.init == "zeros":
            leaves.append(jnp.zeros(spec.shape, pdt))
        elif spec.init == "ones":
            leaves.append(jnp.ones(spec.shape, pdt))
        else:
            digest = hashlib.md5(jax.tree_util.keystr(path).encode()).digest()
            sub = jax.random.fold_in(key, int.from_bytes(digest[:4], "little"))
            arr = jax.random.normal(sub, spec.shape, jnp.float32)
            leaves.append((arr * _fan_in_scale(spec)).astype(pdt))
    return jax.tree.unflatten(treedef, leaves)


def abstract_params(specs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — used by the dry-run; allocates nothing."""
    return _map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype), specs
    )


def logical_axes(specs):
    """Pytree of logical-axis tuples, aligned with the parameter tree."""
    return _map_specs(lambda s: s.axes, specs)


def stack_layer_specs(layer_specs, num_layers: int):
    """Prepend a scanned ``layers`` dimension to every spec in a layer tree."""
    return _map_specs(
        lambda s: ParamSpec(
            shape=(num_layers, *s.shape),
            axes=("layers", *s.axes),
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        ),
        layer_specs,
    )


def count_params(specs) -> int:
    total = 0
    for spec in jax.tree.leaves(specs, is_leaf=_is_spec):
        n = 1
        for s in spec.shape:
            n *= s
        total += n
    return total
