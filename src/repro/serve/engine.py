"""Continuous-batching serving engine: paged KV cache + two-phase scheduler
over the spectral-shift decode path.

vLLM-style serving on top of ``decode_step``:

* a fixed pool of ``max_lanes`` decode lanes share a **block-paged KV
  cache** (serve/paged.py): K/V lives in fixed-size token blocks handed out
  by a free-list allocator, so memory tracks the working set instead of
  ``max_lanes * max_seq``; landmark running sums — the paper-technique state
  — are a fixed ``(c, d)`` summary per layer and stay dense per lane;
* requests wait in a FCFS queue and are admitted when a lane AND enough
  blocks for their prompt are available (serve/scheduler.py). If decode
  growth exhausts the pool, the youngest request is preempted (blocks
  recycled, request requeued, recompute on re-admission);
* **batched prefill** (serve/prefill.py) pushes the whole prompt through
  the model in one jitted forward pass, writing K/V straight into the
  allocated blocks and seeding the landmark sums — first-token latency is
  one tick instead of O(prompt_len) ticks of token replay;
* every engine tick advances ALL decoding lanes with one jitted batched
  step — admission/retirement never stalls other lanes;
* decode attention state is **streamed** (serve/decode_state.py): the cache
  carries per-landmark online-softmax (m, l, BV) partials that prefill
  seeds in one shot and each decode tick extends in O(c*d), instead of
  rebuilding the landmark-to-key softmax over the whole horizon per token.
  ``ModelConfig.decode_streaming`` picks exact (token-identical, one-row
  recompute per tick) / frozen (fully streamed; the engine runs a lazy
  two-row rebase program when a lane crosses a segment boundary) /
  recompute (the legacy O(c*S*d) path, kept as baseline);
* with ``ServeConfig.decode_impl="paged"`` the decode tick is **gather-
  free**: K/V stream straight from the block pools through the
  block-table-aware Pallas kernel (kernels/paged_decode.py) and the new
  token commits via a single-block scatter — frozen-mode ticks touch
  O(c*d) state plus one block, independent of the horizon. ``"gather"``
  (default) keeps the legacy dense-view tick, which also serves
  ``decode_streaming="recompute"`` and the frozen boundary rebase.

* with ``ServeConfig.chunked_prefill=True`` the engine switches to a
  **continuous-batching tick** (``_tick_chunked``): prompts prefill in
  fixed-size chunks (serve/prefill.py ``chunk_prefill``) that ride INSIDE
  the decode tick, so a long prompt never freezes decoding lanes — each
  tick dispatches the batched decode step first, then runs up to
  ``prefill_token_budget`` worth of prompt chunks while the decode program
  executes on device, and syncs once at the sample boundary. Chunk K/V
  commits incrementally into the lane's blocks; the landmark streaming
  stats carry across chunks via the flash-merge algebra, so chunked
  prefill is greedy token-identical to whole-prompt replay prefill. A
  mid-prefill lane preempted for blocks is PARKED (committed blocks kept,
  dense carry snapshotted) and resumes at the completed-chunk boundary
  instead of recomputing. ``chunked_prefill=False`` (default) keeps the
  two-phase tick below, byte for byte.

* with ``ServeConfig.prefix_cache=True`` admissions first probe a
  **content-hash prefix index** (serve/paged.py ``PrefixCache``): prompts
  are hashed block-by-block (chained digests) and a hit maps the cached
  physical blocks into the request's table with refcounts — a full-prompt
  hit restores the cached dense landmark/streaming snapshot and emits its
  first token from the cached logits (TTFT ~ one host-side attach instead
  of a prefill pass); a partial hit resumes chunked prefill at the deepest
  cached block boundary. Divergent decode writes into a shared partial
  block copy-on-write (``BlockAllocator.cow`` + ``PagedKVCache.
  copy_block``); streaming stats attach via the canonical-segmentation
  passthrough or the ``prefix_attach="recompute"`` reseed program
  (serve/decode_state.py ``reseed_streaming``).

``ServeConfig(paged=False, batched_prefill=False)`` reproduces the seed
engine (dense per-lane caches, token-replay prefill) — kept as the
benchmark/equivalence baseline. Greedy outputs are token-identical between
the two modes; for MoE families this holds in the dropless capacity regime
(capacity dropping is sequence-length dependent, so whole-prompt prefill
and token-by-token replay legitimately route differently when tokens
overflow expert capacity — same caveat as tests/test_decode.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.serve.chaos import ChaosInjector, EngineStalled, FaultPlan
from repro.serve.decode import decode_step
from repro.serve.paged import BlockAllocator, PagedKVCache
from repro.serve.prefill import make_prefill_fn, prefill_supported
from repro.serve.scheduler import Scheduler
from repro.telemetry.metrics import TICK_BUCKETS


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    # streamed-token callback: on_token(uid, token) fires as each token is
    # sampled (inside the tick, right after the sample boundary) instead of
    # the caller polling ``finished`` after drain
    on_token: Optional[object] = None
    # tick budget from submission: past it the request is terminated with
    # outcome "deadline_expired" wherever it is (queued, parked, decoding)
    # and every resource it holds is released. 0 = no deadline.
    deadline_ticks: int = 0


@dataclasses.dataclass
class _Lane:
    req: Optional[Request] = None
    prompt_left: deque = dataclasses.field(default_factory=deque)
    generated: list[int] = dataclasses.field(default_factory=list)
    next_token: int = 0
    pos: int = 0          # cache position the next decode step writes to
    prefilled_tick: int = -1  # tick of batched prefill (skip decode that tick)
    # chunked-prefill progress (continuous batching)
    prefilling: bool = False  # mid-chunked-prefill: not a decode candidate
    prefill_pos: int = 0      # prompt tokens committed so far
    chunk_idx: int = 0        # next chunk ordinal (flight lifeline labels)
    # prefix caching: dense-state snapshots captured at block-aligned chunk
    # boundaries while this lane prefills (token count -> dense_snapshot);
    # attached to the PrefixCache entry when the prefill completes
    stat_points: dict = dataclasses.field(default_factory=dict)

    @property
    def free(self) -> bool:
        return self.req is None


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_lanes: Optional[int] = None,
        max_seq: Optional[int] = None,
        eos_id: Optional[int] = None,
        seed: Optional[int] = None,
        serve: Optional[ServeConfig] = None,
        telemetry=None,
        chaos: Optional[FaultPlan] = None,
    ):
        serve = serve or ServeConfig()
        overrides = {
            k: v
            for k, v in dict(max_lanes=max_lanes, max_seq=max_seq,
                             eos_id=eos_id, seed=seed).items()
            if v is not None
        }
        if overrides:
            serve = dataclasses.replace(serve, **overrides)
        self.cfg, self.params, self.serve = cfg, params, serve
        self.max_lanes, self.max_seq = serve.max_lanes, serve.max_seq
        self.eos_id = serve.eos_id
        self.lanes = [_Lane() for _ in range(self.max_lanes)]
        self.finished: dict[int, list[int]] = {}
        self._key = jax.random.PRNGKey(serve.seed)
        self._tick = 0

        # Telemetry: one shared registry + tracer behind ServeConfig.telemetry
        # (or an externally-owned Telemetry, e.g. a benchmark's). Disabled =>
        # no-op registry/tracer — instrumentation sites still call through,
        # but nothing is recorded and no extra device programs exist. The
        # scheduler always keeps a REAL registry (its latency percentiles are
        # part of the stats() contract); it only shares ours when enabled.
        from repro.telemetry import Telemetry

        if telemetry is None:
            telemetry = Telemetry(enabled=serve.telemetry)
        self.telemetry = telemetry
        self.telemetry.stamp_provenance(cfg, serve)

        self.kv = PagedKVCache(cfg, serve)
        alloc = (
            BlockAllocator(serve.resolved_num_blocks, serve.block_size)
            if self.kv.has_paged_leaves else None
        )
        # Prefix caching rides the continuous-batching tick (partial hits
        # resume into chunked prefill at the first non-matching block), so
        # enabling it implies the chunked machinery. Needs paged seq leaves
        # (the whole point is sharing physical blocks) and a family with
        # batched prefill; silently off otherwise.
        self._prefix_enabled = (
            serve.prefix_cache and self.kv.has_paged_leaves
            and prefill_supported(cfg)
        )
        # Continuous batching: chunk size rounded up to a block multiple so
        # every non-final chunk commits whole blocks (chunk starts stay
        # block-aligned). Families without batched prefill (hybrid/ssm)
        # fall back to the two-phase replay engine.
        self._chunked = (
            serve.chunked_prefill or self._prefix_enabled
        ) and prefill_supported(cfg)
        self._chunk = min(
            -(-serve.prefill_chunk_tokens // serve.block_size)
            * serve.block_size,
            self.max_seq,
        )
        self.sched = Scheduler(
            alloc, self.max_lanes, serve.blocks_per_lane,
            registry=self.telemetry.metrics if self.telemetry.enabled else None,
            flight=self.telemetry.flight if self.telemetry.enabled else None,
            chunk_tokens=self._chunk if self._chunked else 0,
            max_queue=serve.max_queue,
        )
        self.sched.requeue_cb = self._on_preempt
        if self._chunked:
            self.sched.park_cb = self._park_lane
            self.sched.park_drop_cb = self._drop_parked
        # parked mid-prefill state: uid -> dense-leaf snapshot + progress
        self._parked: dict[int, dict] = {}
        # Prefix cache: content-hash index over the block pool. It owns the
        # allocator's eviction hook; the scheduler charges shared blocks
        # against the pool once (prefix_probe) and breaks block sharing on
        # divergent decode writes (cow_cb -> device block copy).
        self.prefix = None
        if self._prefix_enabled:
            from repro.serve.paged import PrefixCache

            self.prefix = PrefixCache(
                alloc, max_blocks=serve.prefix_cache_blocks,
                registry=(self.telemetry.metrics
                          if self.telemetry.enabled else None),
            )
            self.sched.prefix_probe = self._prefix_probe
            self.sched.cow_cb = self.kv.copy_block
            # uid -> entry soft-pinned at probe time, released on attach
            # (see _prefix_probe); at most one pin per waiting request
            self._probe_pins: dict[int, object] = {}

        # Terminal-outcome ledger: every submitted uid ends in exactly ONE
        # of finished / cancelled / rejected / deadline_expired — the chaos
        # soak's core invariant. Numerics-guard and watchdog state rides
        # next to it; counters live on the scheduler's always-real registry
        # so the recovery ladder is observable without telemetry.
        self.outcomes: dict[int, str] = {}
        self._deadlines: dict[int, int] = {}       # uid -> expiry tick
        self._guard_trips: dict[int, int] = {}     # uid -> guard hits
        self._demoted: set[int] = set()            # uids pinned to exact mode
        self._exact_step = None                    # lazy exact-mode program
        self._guard = serve.numerics_guard
        self._progress = True
        self._stall_ticks = 0
        self._wd_interventions = 0
        self._wd_fired_tick: Optional[int] = None
        reg = self.sched.registry
        self._quarantines = reg.counter(
            "numerics_quarantines_total",
            help="lanes quarantined by the numerics guard (streaming stats "
                 "rebuilt in place from cached K/V)")
        self._demotions = reg.counter(
            "numerics_demotions_total",
            help="frozen-mode lanes demoted to the exact decode program "
                 "after repeated numerics-guard trips")
        self._wd_fires = reg.counter(
            "serve_watchdog_fires_total",
            help="no-progress watchdog escalations")
        self._recovery_h = reg.histogram(
            "serve_recovery_ticks",
            help="ticks from the first watchdog intervention to restored "
                 "progress",
            buckets=TICK_BUCKETS)

        # Chaos harness (serve/chaos.py): one injector shared by every hook
        # point, so per-tick ordinals — and therefore the whole injection
        # schedule — replay exactly from (plan.seed, tick).
        self.chaos = None
        if chaos is not None:
            self.chaos = ChaosInjector(chaos, flight=self.sched.flight,
                                       registry=self.sched.registry)
            self.sched.chaos = self.chaos
            if alloc is not None:
                alloc.chaos = self.chaos
            if self.prefix is not None:
                self.prefix.chaos = self.chaos
        if self.telemetry.enabled:
            reg = self.telemetry.metrics
            self._ticks_total = reg.counter(
                "serve_ticks_total", help="engine ticks executed")
            if alloc is not None:
                # fn-gauges: evaluated only when the registry is read, so
                # the tick loop never touches them.
                reg.gauge("pool_blocks_used", fn=lambda: float(alloc.num_used),
                          help="allocated KV blocks")
                reg.gauge("pool_blocks_free", fn=lambda: float(alloc.num_free),
                          help="free KV blocks")
                reg.gauge("pool_utilization",
                          fn=lambda: alloc.num_used / max(alloc.num_blocks - 1, 1),
                          help="allocated fraction of the usable pool")
                reg.gauge("pool_fragmentation", fn=alloc.fragmentation,
                          help="1 - longest contiguous free run / free blocks")

        # Decode-tick route: "paged" = gather-free (block-table Pallas
        # kernel + single-block scatter commit); "gather" = legacy dense
        # per-lane views. recompute-mode spectral shift rebuilds the dense
        # B matrix and is only served by the gather route, so a paged
        # request falls back (surfaced in stats()["decode_impl"]). The
        # route is an EXPLICIT ServeConfig choice by contract; the decode
        # plan warmed below steers kernel geometry (block_table view
        # bucketing) and surfaces the measured gather-vs-paged winner in
        # stats() for the operator — it does not override the route.
        paged_ok = self.kv.has_paged_leaves and not (
            cfg.decode_attention_impl == "spectral_shift"
            and cfg.decode_streaming == "recompute"
        )
        self.decode_impl = (
            "paged" if serve.decode_impl == "paged" and paged_ok else "gather"
        )
        # landmark horizon pinned to max_seq regardless of view length
        step = functools.partial(
            decode_step, self.params, cfg, seq_max=self.max_seq
        )
        # whole decode tick (read -> step -> commit) as one XLA program
        if self.decode_impl == "paged":
            pstep = functools.partial(
                step, paged_meta=(serve.block_size, cfg.kernels_interpret)
            )
            self._fused_step = self.kv.make_paged_step(
                lambda cache, tokens, table: pstep(
                    cache, tokens, paged_table=table
                )
            )
        else:
            self._fused_step = self.kv.make_fused_step(jax.vmap(step))
        self.batched = serve.batched_prefill and prefill_supported(cfg)

        # decode_streaming="frozen": the active landmark row streams with a
        # drifting mean and is rebased lazily when a lane's write position
        # crosses a segment boundary — a second jitted program (gather ->
        # two-row recompute -> commit dense stats leaves), run only on
        # boundary ticks (amortized O(c*d)/token; serve/decode_state.py).
        from repro.serve.decode_state import segment_len

        self._seg = segment_len(self.max_seq, cfg.num_landmarks)
        self._rebases = 0
        self._frozen_rebase = (
            cfg.decode_streaming == "frozen"
            and cfg.decode_attention_impl == "spectral_shift"
            and cfg.family != "ssm"
        )
        if self._frozen_rebase:
            from repro.serve.decode_state import make_rebase_fn

            self._rebase_step = self.kv.make_rebase_step(
                jax.vmap(make_rebase_fn(cfg, self.max_seq))
            )

        # Prefix-attach stat seeding. "reseg": cached stats are stored at
        # the canonical segmentation (this engine's own — every lane shares
        # segment_len(max_seq, c)), so the attach is a pure host-side
        # dense-state restore, bitwise the state a cold prefill would have
        # left; the re-segmentation program (decode_state.resegment_sums)
        # only runs when segmentations differ, which cannot happen within
        # one engine. "recompute": dispatch the reseed program on every
        # attach — re-derive all (m, l, acc) rows exactly from the shared
        # K/V blocks through the rebase-step plumbing (the correctness
        # fallback, token-identity-tested against cold prefill).
        self._reseed_step = None
        self._can_reseed = (
            cfg.decode_attention_impl == "spectral_shift"
            and cfg.decode_streaming in ("exact", "frozen")
            and cfg.family != "ssm"
        )
        if (self._prefix_enabled and serve.prefix_attach == "recompute"
                and self._can_reseed):
            from repro.serve.decode_state import make_reseed_fn

            self._reseed_step = self.kv.make_rebase_step(
                jax.vmap(make_reseed_fn(cfg, self.max_seq))
            )

        # Online approximation monitors (telemetry only): locate the
        # streaming-stat leaves in the flat storage once, then per-rebase
        # drift probes (pre/post leaf snapshot, O(c*d) host math) and a
        # landmark-mass spectrum EMA observed at rebases and retirements.
        # _stream_idx is needed beyond telemetry now: the numerics guard
        # scans (and the chaos nan_stats site poisons) the streaming-stat
        # leaves whenever the decode state streams; the monitors themselves
        # stay telemetry-gated.
        self._stream_idx = None
        self._drift_mon = self._spectrum_mon = None
        if self._can_reseed:  # exact/frozen spectral shift: stats stream
            from repro.serve.kv_cache import stream_leaf_indices

            idx = stream_leaf_indices(cfg, self.max_seq)
            if idx["bv_m"]:
                self._stream_idx = list(
                    zip(idx["bv_m"], idx["bv_l"], idx["bv_acc"])
                )
        if self.telemetry.enabled and self._stream_idx:
            from repro.telemetry import DriftMonitor, SpectrumMonitor

            self._spectrum_mon = SpectrumMonitor(self.telemetry.metrics)
            if self._frozen_rebase:
                self._drift_mon = DriftMonitor(self.telemetry.metrics)

        # Warm the dispatch registry for the serving shapes: the decode key
        # family (n=1 step against the max_seq cache horizon) plus, for
        # ss_fused prefill, the full-sequence key whose plan picks the
        # Pallas stream block size. Resolution loads the on-disk autotune
        # cache — honoring the ModelConfig.autotune_cache override, like
        # the Trainer does — so a tuned serving deployment skips the
        # heuristics; with ModelConfig.autotune=True an unseen decode key
        # runs the measured gather-vs-paged sweep here, once, and the tick
        # programs bake in the winner's block_table view bucketing.
        from repro.kernels import dispatch

        if self.telemetry.enabled:
            # Process-wide (like the plan registry): warmup below counts too.
            dispatch.set_metrics(self.telemetry.metrics)
        if cfg.autotune_cache:
            dispatch.set_cache_path(cfg.autotune_cache)
            dispatch.load_cache()
        def _tune_decode(key):
            # Measure at THIS deployment's block size (the kernel's key
            # block is the storage block); autotune_decode's default would
            # time a different grid geometry than the real tick runs.
            return dispatch.autotune_decode(
                key.n, key.c, key.d, dtype=key.dtype, backend=key.backend,
                block_size=serve.block_size,
            )

        self.decode_plan = dispatch.get_plan(dispatch.make_key(
            self.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
            cfg.compute_dtype, True, family="decode",
        ), autotune_enabled=cfg.autotune, tune_fn=_tune_decode)
        # View-slot bucketing quantum for paged tick programs (0 = the
        # power-of-two default in view_blocks_needed).
        self._view_quantum = (
            self.decode_plan.block_table if self.decode_impl == "paged" else 0
        )
        prefill_block = 512
        if self.batched and serve.prefill_impl == "ss_fused":
            plan = dispatch.get_plan(dispatch.make_key(
                self.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
                cfg.compute_dtype, False,
            ))
            prefill_block = plan.block_n
        if self.batched:
            self._prefill = make_prefill_fn(
                params, cfg, seq_max=self.max_seq,
                prefill_impl=serve.prefill_impl, block_n=prefill_block,
            )
        if self._chunked:
            from repro.serve.prefill import make_chunk_prefill_fn

            self._chunk_step = self.kv.make_chunk_step(
                make_chunk_prefill_fn(
                    params, cfg, seq_max=self.max_seq,
                    stats_impl=serve.prefill_impl, block_n=prefill_block,
                ),
                self._chunk,
            )
        # bucket rounded up to a block multiple so prefill writes whole blocks
        b = serve.prefill_bucket
        self._bucket = -(-b // serve.block_size) * serve.block_size

        # XLA program accounting (telemetry/accounting.py): the three
        # hot-loop programs are wrapped so every jit cache miss increments
        # xla_compiles_total{program=} — a steady-state engine must show
        # the counter FLAT across ticks (shape-bucket explosions show up
        # immediately). The jax.monitoring listener additionally attributes
        # backend compiles we don't wrap (autotune sweeps) to their tagged
        # region. Numerics probes are a separate knob: they force a host
        # sync, so ServeConfig.numerics_probe_every gates their cadence.
        from repro.telemetry import accounting as acct

        self._numerics = acct.NullNumericsProbe()
        if self.telemetry.enabled:
            acct.set_metrics(self.telemetry.metrics)
            acct.install_compile_listener()
            self._acct = acct.XLAAccounting(self.telemetry.metrics)
            self._fused_step = self._acct.wrap(self._fused_step, "decode_tick")
            if self.batched:
                self._prefill = self._acct.wrap(self._prefill, "prefill")
            if self._chunked:
                self._chunk_step = self._acct.wrap(
                    self._chunk_step, "prefill_chunk"
                )
            if self._frozen_rebase:
                self._rebase_step = self._acct.wrap(self._rebase_step, "rebase")
            if self._reseed_step is not None:
                self._reseed_step = self._acct.wrap(
                    self._reseed_step, "prefix_attach"
                )
            if serve.numerics_probe_every > 0:
                self._numerics = acct.NumericsProbe(self.telemetry.metrics)
        else:
            self._acct = None

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request. Returns False when the ``ServeConfig.max_queue``
        admission bound rejects it (outcome "rejected"; the flight event
        carries a retry-after hint) — callers without backpressure
        handling can ignore the return value, as max_queue=0 never
        rejects."""
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"prompt len {len(req.prompt)} >= max_seq {self.max_seq}"
            )
        if not self.sched.submit(req):
            self.outcomes[req.uid] = "rejected"
            return False
        self.outcomes.pop(req.uid, None)  # resubmit sheds a stale outcome
        if req.deadline_ticks > 0:
            self._deadlines[req.uid] = self._tick + req.deadline_ticks
        return True

    def cancel(self, uid: int) -> bool:
        """Client cancellation: terminate ``uid`` wherever it is — queued,
        parked mid-prefill, or decoding — releasing its blocks, prefix
        pins, and parked snapshots. Returns False for an unknown or
        already-terminal uid."""
        return self._terminalize(uid, "cancelled")

    def _expire_deadlines(self) -> None:
        if not self._deadlines:
            return
        expired = [u for u, d in self._deadlines.items() if self._tick > d]
        for uid in expired:
            self._terminalize(uid, "deadline_expired")

    def _terminalize(self, uid: int, outcome: str) -> bool:
        """Shared cancel/deadline exit path. Every resource class a request
        can hold is released here: waiting-queue slot, scheduler parked
        entry + allocator blocks (parked uids sit in BOTH — preemption
        parks the blocks and requeues the Request), engine parked snapshot,
        prefix probe pin, guard state, lane seat."""
        self._deadlines.pop(uid, None)
        if uid in self.outcomes or uid in self.finished:
            return False
        req = self.sched.remove_waiting(uid)
        if req is not None:
            self.sched.parked.pop(uid, None)
            self._parked.pop(uid, None)
            if self.sched.allocator is not None:
                self.sched.allocator.free(uid)
            if self.prefix is not None:
                pinned = self._probe_pins.pop(uid, None)
                if pinned is not None:
                    self.prefix.unpin(pinned)
            self.sched.mark_terminal(uid, outcome)
        else:
            seat = next(
                (i for i, l in enumerate(self.lanes)
                 if l.req is not None and l.req.uid == uid), None,
            )
            if seat is None:
                return False
            self.sched.discard(seat, outcome)
            self.lanes[seat] = _Lane()
        self.outcomes[uid] = outcome
        self._guard_trips.pop(uid, None)
        self._demoted.discard(uid)
        return True

    def run(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        """Drive until queue + lanes drain (or tick budget). Returns outputs."""
        for _ in range(max_ticks):
            if self.sched.idle:
                break
            self.tick()
        return self.finished

    # -- scheduling hooks ------------------------------------------------------
    def _on_preempt(self, lane_idx: int) -> Optional[Request]:
        lane = self.lanes[lane_idx]
        req = lane.req
        self.lanes[lane_idx] = _Lane()
        return req

    def _park_lane(self, lane_idx: int) -> bool:
        """Scheduler park hook: a preemption victim caught mid-chunked-
        prefill with committed chunks keeps its blocks; only the carried
        dense state (landmark sums, streaming stats) needs saving — host
        copies, so re-admission restores without recomputing the chunks.
        Lane-dense caches can't park (the lane's seq rows get reused), so
        they fall back to full recompute."""
        lane = self.lanes[lane_idx]
        if (lane.req is None or not lane.prefilling
                or lane.prefill_pos <= 0 or not self.kv.paged):
            return False
        self._parked[lane.req.uid] = {
            "snap": self.kv.dense_snapshot(lane_idx),
            "prefill_pos": lane.prefill_pos,
            "chunk_idx": lane.chunk_idx,
        }
        return True

    def _drop_parked(self, uid: int) -> None:
        """Scheduler reclaimed a parked request's blocks: drop the resume
        snapshot; re-admission recomputes from the first chunk."""
        self._parked.pop(uid, None)

    # -- prefix caching --------------------------------------------------------
    def _plan_attach(self, req: Request):
        """Match ``req.prompt`` against the prefix index and pick the attach
        point. Returns ``(entry, n_tokens, full)`` — share the blocks
        covering the first ``n_tokens`` prompt tokens; ``full`` means the
        whole prompt (cached logits emit the first token with zero prefill
        work), otherwise ``n_tokens`` is a block-aligned stat-point boundary
        and chunked prefill resumes there. None = no usable cached state (a
        match without a snapshot at a usable boundary is still a miss).
        Parked requests resume their own committed blocks instead."""
        if (self.prefix is None or req.uid in self.sched.parked
                or req.uid in self._parked):
            return None
        m = self.prefix.match(req.prompt)
        if m is None:
            return None
        entry, k = m
        bs = self.serve.block_size
        n = len(req.prompt)
        if self.prefix.is_full_hit(entry, req.prompt, k):
            if n in entry.stat_points:
                return entry, n, True
        # Partial hit: resume chunked prefill at the deepest block-aligned
        # snapshot within the matched span. Capped at n-1 so at least one
        # token remains to prefill (the resumed tail produces the
        # first-token logits; a boundary AT n without cached logits is
        # unusable as "full").
        cap = min(k * bs, n - 1)
        best = max(
            (p for p in entry.stat_points if 0 < p <= cap and p % bs == 0),
            default=0,
        )
        if best:
            return entry, best, False
        return None

    def _prefix_probe(self, req: Request) -> int:
        """Scheduler hook: leading prompt tokens a cached prefix will cover
        at admission (0 = cold), so admission charges the tail only.

        The matched entry is soft-pinned (LRU-bumped, last in eviction
        order) until ``_try_attach_prefix`` releases it: between probe and
        attach the entry is still cache-only (no table references it yet),
        so this admission's own tail alloc — or a later admission's in the
        same tick — could otherwise reclaim it, silently turning the
        tail-only-charged hit into a cold miss. The pin rides across ticks
        while the request waits at the queue head and is re-pointed if a
        re-probe matches a different entry."""
        plan = self._plan_attach(req)
        entry = plan[0] if plan is not None else None
        prev = self._probe_pins.pop(req.uid, None)
        if prev is not None and prev is not entry:
            self.prefix.unpin(prev)
        if entry is not None:
            if prev is entry:
                self.prefix.touch(entry)
            else:
                self.prefix.pin(entry)
            self._probe_pins[req.uid] = entry
        return plan[1] if plan is not None else 0

    def _try_attach_prefix(self, i: int, req: Request) -> bool:
        """Admission-time hit detection + attach. On a hit: map the shared
        blocks into the request's table (refcounted — the tail the
        scheduler allocated at admission stays appended after them),
        restore the cached dense snapshot into the lane, and either emit
        the first token straight from the cached logits (full hit: TTFT is
        one host-side attach, no prefill pass) or resume chunked prefill at
        the boundary (partial hit). Returns True when attached."""
        pinned = self._probe_pins.pop(req.uid, None)
        if pinned is not None:
            # The admission window is over; nothing can evict the entry
            # between here and attach_shared (pure host code, no allocs),
            # and the attach itself adds a table reference.
            self.prefix.unpin(pinned)
        plan = self._plan_attach(req)
        if plan is None:
            if self.prefix is not None:
                self.prefix.note_miss()
            return False
        entry, n_attach, full = plan
        bs = self.serve.block_size
        nb = -(-n_attach // bs) if full else n_attach // bs
        blocks = entry.blocks[:nb]
        self.sched.allocator.attach_shared(req.uid, blocks)
        self.kv.dense_restore(i, entry.stat_points[n_attach])
        lane = self.lanes[i]
        # Boundary snapshots up to the attach point are valid for this
        # prompt too (same tokens): carry them so this request's completed
        # prefill can cache a deeper entry without recapturing them.
        lane.stat_points = {
            p: s for p, s in entry.stat_points.items() if p <= n_attach
        }
        if full:
            lane.pos = n_attach
            lane.prefilled_tick = self._tick
        else:
            lane.prefill_pos = n_attach
            lane.prefilling = True
        self.prefix.note_hit(entry, len(blocks))
        self.sched.mark_prefix_hit(req.uid)
        self.telemetry.flight.record(
            req.uid, "prefix_attach", tick=self._tick, lane=i,
            blocks=len(blocks), tokens=n_attach,
            mode="full" if full else "partial",
        )
        if self._reseed_step is not None:
            # "recompute" attach: re-derive the streaming stats from the
            # shared K/V instead of trusting the snapshot's (m, l, acc).
            self._run_reseed(i, n_attach - 1)
        if full:
            self._emit_token(i, np.asarray(entry.logits, np.float32))
        return True

    def _run_reseed(self, i: int, last_pos: int) -> None:
        """Dispatch the attach-reseed program for one lane (gather shared
        blocks -> recompute every reached stats row -> commit dense)."""
        positions = np.zeros(self.max_lanes, np.int32)
        flags = np.zeros(self.max_lanes, bool)
        positions[i] = last_pos
        flags[i] = True
        tables = self.sched.tables()
        nb_view = self.kv.view_blocks_needed(positions, [i])
        self.kv._storage = list(self._reseed_step(
            self.kv._storage, jnp.asarray(tables), jnp.asarray(positions),
            jnp.asarray(flags), nb_view,
        ))

    def _maybe_cache_prefix(self, i: int, logits: np.ndarray) -> None:
        """Completed-prefill hook: capture the final stat point (the lane's
        dense state at exactly ``len(prompt)`` tokens, which a full hit
        restores) and insert the prompt into the prefix index. The entry
        takes its own block references, so retirement's ``free(uid)`` keeps
        the blocks resident for future hits. No-op when every boundary is
        already cached (first entry wins)."""
        lane = self.lanes[i]
        req = lane.req
        if (self.prefix is None or req is None
                or len(req.prompt) < self.serve.block_size):
            return
        lane.stat_points[len(req.prompt)] = self.kv.dense_snapshot(i)
        self.prefix.insert(
            req.prompt, self.sched.allocator.tables.get(req.uid, []),
            stat_points=lane.stat_points, logits=logits,
        )

    def _retire(self, i: int) -> None:
        lane = self.lanes[i]
        if self._spectrum_mon is not None and lane.pos > 0:
            # Final landmark-mass concentration of the finished request —
            # the online spectrum-decay proxy (telemetry only).
            stats = self._lane_stream_stats(i)
            self._spectrum_mon.observe(
                np.stack([g[0] for g in stats]),
                np.stack([g[1] for g in stats]),
                min((lane.pos - 1) // self._seg + 1, self.cfg.num_landmarks),
            )
        uid = lane.req.uid
        self.finished[uid] = list(lane.generated)
        self.outcomes[uid] = "finished"
        self._deadlines.pop(uid, None)
        self._guard_trips.pop(uid, None)
        self._demoted.discard(uid)
        self.sched.release(i)
        self.lanes[i] = _Lane()

    # -- prefill phase ---------------------------------------------------------
    def _run_prefill(self, i: int, req: Request) -> None:
        lane = self.lanes[i]
        n = len(req.prompt)
        if (self.serve.prefill_impl == "ss_fused"
                and n <= self.cfg.num_landmarks):
            # Degenerate tiny prompt: the exact-attention path has no
            # key-validity mask, so run unpadded (cheap recompiles; the
            # kernels assert-guard padded callers).
            n_pad = n
        else:
            # Bucketed padding in both modes; ss_fused masks the pad out of
            # the softmax via the dynamic kv_valid bound.
            n_pad = min(-(-n // self._bucket) * self._bucket, self.max_seq)
        tokens = np.zeros((1, n_pad), np.int32)
        tokens[0, :n] = req.prompt
        self.telemetry.flight.record(
            req.uid, "prefill_start", bucket=n_pad, lane=i, tick=self._tick
        )
        logits, pcache = self._prefill(
            jnp.asarray(tokens), jnp.asarray(n, jnp.int32)
        )
        self.kv.write_prefill(i, pcache, self.sched.table_row(i), n_tokens=n)
        lane.pos = n
        lane.prefilled_tick = self._tick
        lg = np.asarray(logits[0, n - 1, : self.cfg.vocab_size], np.float32)
        self.telemetry.flight.record(req.uid, "prefill_end", bucket=n_pad)
        self._emit_token(i, lg)

    # -- sampling / retirement -------------------------------------------------
    def _sample(self, lane: _Lane, lg: np.ndarray) -> int:
        if lane.req.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            gumbel = np.asarray(jax.random.gumbel(sub, lg.shape))
            return int(np.argmax(lg / lane.req.temperature + gumbel))
        return int(np.argmax(lg))

    def _emit_token(self, i: int, lg: np.ndarray) -> None:
        lane = self.lanes[i]
        tok = self._sample(lane, lg)
        lane.generated.append(tok)
        self._progress = True
        self.sched.note_token(lane.req.uid)
        if lane.req.on_token is not None:
            lane.req.on_token(lane.req.uid, tok)
            if self.lanes[i] is not lane:
                return  # the callback cancelled this very request
        done = (
            tok == self.eos_id
            or len(lane.generated) >= lane.req.max_new_tokens
            or lane.pos + 1 >= self.max_seq
        )
        if done:
            self._retire(i)
        else:
            lane.next_token = tok

    # -- decode dispatch (normal + demoted lanes) ------------------------------
    def _dispatch_decode(self, active: list[int]) -> list[tuple]:
        """Launch the decode program(s) for ``active`` without syncing.
        Lanes demoted by the numerics guard run on the lazily built
        exact-mode program as a second dispatch over the same (donated)
        storage; with no demotions this is exactly the single legacy call.
        Returns ``[(device_logits, lanes)]`` for ``_merge_logits``."""
        tables = self.sched.tables()
        if self._demoted:
            normal = [i for i in active
                      if self.lanes[i].req.uid not in self._demoted]
            demoted = [i for i in active
                       if self.lanes[i].req.uid in self._demoted]
        else:
            normal, demoted = active, []
        groups = [(self._fused_step, normal)]
        if demoted:
            self._ensure_exact_step()
            groups.append((self._exact_step, demoted))
        parts = []
        for step_fn, group in groups:
            if not group:
                continue
            tokens = np.zeros((self.max_lanes, 1, 1), np.int32)
            positions = np.zeros(self.max_lanes, np.int32)
            mask = np.zeros(self.max_lanes, bool)
            for i in group:
                tokens[i, 0, 0] = self.lanes[i].next_token
                positions[i] = self.lanes[i].pos
                mask[i] = True
            nb_view = self.kv.view_blocks_needed(
                positions, group, quantum=self._view_quantum
            )
            dev, new_storage = step_fn(
                self.kv._storage, jnp.asarray(tables), jnp.asarray(tokens),
                jnp.asarray(positions), jnp.asarray(mask), nb_view,
            )
            self.kv._storage = list(new_storage)
            parts.append((dev, group))
        return parts

    def _merge_logits(self, parts: list[tuple]) -> Optional[np.ndarray]:
        """Sync the dispatched decode parts to one (max_lanes, vocab) host
        array (the single-part fast path is byte-identical to the legacy
        sync). None when nothing decoded this tick."""
        if not parts:
            return None
        if len(parts) == 1:
            return np.asarray(parts[0][0][:, 0, 0], np.float32)
        out = None
        for dev, group in parts:
            host = np.asarray(dev[:, 0, 0], np.float32)
            if out is None:
                out = np.zeros_like(host)
            out[group] = host[group]
        return out

    def _ensure_exact_step(self) -> None:
        """Build the exact-mode decode program for demoted lanes. The
        storage layout is shared (exact and frozen stream the same (m, l,
        acc) leaves; exact recomputes the active row per tick instead of
        drifting it), so demoted and normal lanes ride the same pools."""
        if self._exact_step is not None:
            return
        cfg_e = dataclasses.replace(self.cfg, decode_streaming="exact")
        step = functools.partial(
            decode_step, self.params, cfg_e, seq_max=self.max_seq
        )
        if self.decode_impl == "paged":
            pstep = functools.partial(
                step,
                paged_meta=(self.serve.block_size, cfg_e.kernels_interpret),
            )
            fn = self.kv.make_paged_step(
                lambda cache, tokens, table: pstep(
                    cache, tokens, paged_table=table
                )
            )
        else:
            fn = self.kv.make_fused_step(jax.vmap(step))
        if self._acct is not None:
            fn = self._acct.wrap(fn, "decode_exact")
        self._exact_step = fn

    def _ensure_reseed_step(self) -> bool:
        """Lazily build the stats-reseed program for the numerics guard
        (shared with the prefix_attach="recompute" path when that already
        built it)."""
        if self._reseed_step is not None:
            return True
        if not self._can_reseed:
            return False
        from repro.serve.decode_state import make_reseed_fn

        fn = self.kv.make_rebase_step(
            jax.vmap(make_reseed_fn(self.cfg, self.max_seq))
        )
        if self._acct is not None:
            fn = self._acct.wrap(fn, "prefix_attach")
        self._reseed_step = fn
        return True

    # -- chaos application & numerics-guard escalation -------------------------
    def _apply_tick_chaos(self) -> None:
        """Tick-scoped chaos sites, evaluated once per tick at the top."""
        ch = self.chaos
        rule = ch.fire("tick_delay")
        if rule is not None:
            time.sleep(rule.param or 1e-3)
        rule = ch.fire("fragment")
        if rule is not None and self.sched.allocator is not None:
            self.sched.allocator.scramble_free(ch.plan.seed + self._tick)
        rule = ch.fire("evict_storm")
        if rule is not None and self.prefix is not None:
            for _ in range(int(rule.param) or 4):
                if not self.prefix.evict_one():
                    break

    def _apply_decode_chaos(self, active: list[int],
                            logits: np.ndarray) -> None:
        """Post-step corruption sites: poison a lane's streaming stats on
        device and/or its host logits row. Runs before the guard scan, so
        the same tick detects what it injected."""
        ch = self.chaos
        for i in active:
            if self.lanes[i].free:
                continue
            if (self._stream_idx
                    and ch.fire("nan_stats", lane=i) is not None):
                s = self.kv._storage
                for im, il, ia in self._stream_idx:
                    s[im] = s[im].at[i].set(jnp.nan)
                    s[il] = s[il].at[i].set(jnp.nan)
                    s[ia] = s[ia].at[i].set(jnp.nan)
            if ch.fire("nan_logits", lane=i) is not None:
                logits[i, : self.cfg.vocab_size] = np.nan

    def _post_decode_checks(self, active: list[int],
                            logits: Optional[np.ndarray]):
        """Post-sync, pre-emit: numerics probe cadence, chaos corruption
        injection, numerics-guard escalation. Returns the (possibly
        copied-for-writability) logits."""
        probe_every = self.serve.numerics_probe_every
        if (probe_every > 0 and self._tick % probe_every == 0
                and self.telemetry.enabled):
            if logits is not None:
                self._numerics.check("decode_logits", logits)
            if self._stream_idx:
                for i in active:
                    for m, l, _ in self._lane_stream_stats(i):
                        self._numerics.check("landmark_m", m)
                        self._numerics.check("landmark_l", l)
        if logits is None:
            return None
        if self.chaos is not None:
            if not logits.flags.writeable:
                logits = logits.copy()
            self._apply_decode_chaos(active, logits)
        if self._guard:
            self._guard_scan(active, logits)
        return logits

    def _guard_scan(self, active: list[int], logits: np.ndarray) -> None:
        """Numerics-guard escalation ladder (ServeConfig.numerics_guard).

        Detection is host-side and NaN-keyed for the stats (the online-
        softmax ``m`` legitimately holds -inf for unreached landmark rows);
        logits must be fully finite. Recovery: stats-only corruption (K/V
        and this tick's logits intact) quarantines the lane — every (m, l,
        acc) row is rebuilt exactly from cached K/V via the reseed program
        — and the emit proceeds; corrupted logits replay-preempt the lane
        (the per-tick landmark-sum updates make an in-place retry unsound,
        so recompute is the only exact recovery). After
        ``numerics_demote_after`` trips a frozen-mode request is demoted to
        the exact-mode decode program for the rest of its life."""
        for i in active:
            lane = self.lanes[i]
            if lane.free:
                continue
            uid = lane.req.uid
            row = logits[i, : self.cfg.vocab_size]
            bad_logits = not bool(np.isfinite(row).all())
            bad_stats = False
            if not bad_logits and self._stream_idx:
                for m, l, acc in self._lane_stream_stats(i):
                    if (np.isnan(m).any() or np.isnan(l).any()
                            or np.isnan(acc).any()):
                        bad_stats = True
                        break
            if not (bad_logits or bad_stats):
                continue
            trips = self._guard_trips.get(uid, 0) + 1
            self._guard_trips[uid] = trips
            if bad_stats and self._ensure_reseed_step():
                self._quarantines.inc()
                self.sched.flight.record(uid, "quarantine", tick=self._tick,
                                         lane=i, trips=trips)
                # lane.pos is still the position this tick's step wrote
                # (the emit loop increments it after the guard).
                self._run_reseed(i, lane.pos)
            else:
                self.sched.preempt(i)
            if (trips >= self.serve.numerics_demote_after
                    and self.cfg.decode_streaming == "frozen"
                    and uid not in self._demoted):
                self._demoted.add(uid)
                self._demotions.inc()
                self.sched.flight.record(uid, "demote", tick=self._tick,
                                         trips=trips)

    # -- no-progress watchdog --------------------------------------------------
    def _watchdog_check(self) -> None:
        """Generalized livelock defense (ServeConfig.watchdog_ticks): after
        N consecutive ticks with work pending but zero progress (no token,
        no chunk, no admission), escalate one rung per tick — reclaim
        parked blocks, then preempt the youngest lane (a parked victim's
        blocks fall to the next rung) — and raise a structured
        EngineStalled only when the ladder is exhausted."""
        wd = self.serve.watchdog_ticks
        if wd <= 0:
            return
        if self._progress or self.sched.idle:
            if self._wd_fired_tick is not None:
                self._recovery_h.observe(self._tick - self._wd_fired_tick)
                self._wd_fired_tick = None
            self._stall_ticks = 0
            self._wd_interventions = 0
            return
        self._stall_ticks += 1
        if self._stall_ticks < wd:
            return
        self._wd_fires.inc()
        self.sched.flight.record(-1, "watchdog", tick=self._tick,
                                 stall_ticks=self._stall_ticks,
                                 rung=self._wd_interventions)
        if self._wd_fired_tick is None:
            self._wd_fired_tick = self._tick
        self._wd_interventions += 1
        # Interventions are bounded: each one either frees blocks or
        # empties a lane, so needing more than one full sweep of both
        # ladders means the stall is structural — stop escalating and
        # report.
        if self._wd_interventions <= 2 * (self.max_lanes + 1):
            if self.sched.reclaim_parked():
                return
            victim = self.sched._youngest_lane()
            if victim is not None:
                self.sched.preempt(victim)
                return
        alloc = self.sched.allocator
        raise EngineStalled(
            tick=self._tick, stall_ticks=self._stall_ticks,
            waiting=len(self.sched.waiting),
            active_lanes=sum(u is not None for u in self.sched.lane_uid),
            parked=len(self.sched.parked),
            pool={} if alloc is None else alloc.stats(),
        )

    # -- one engine tick -------------------------------------------------------
    def tick(self) -> None:
        with self.telemetry.span("serve_tick"):
            self._progress = False
            self._tick_inner()
            self._watchdog_check()

    def _begin_tick(self) -> None:
        """Shared tick preamble: advance the clock, evaluate the tick-
        scoped chaos sites, expire deadlines."""
        self._tick += 1
        self.sched.tick_now = self._tick
        if self.chaos is not None:
            self.chaos.begin_tick(self._tick)
            self._apply_tick_chaos()
        self._expire_deadlines()

    def _tick_inner(self) -> None:
        if self._chunked:
            return self._tick_chunked()
        self._begin_tick()
        tel = self.telemetry
        if tel.enabled:
            self._ticks_total.inc()
            # Counter-track samples for the Perfetto export: one point per
            # tick into fixed-size deques (telemetry/flight.py).
            fl = tel.flight
            fl.counter_sample("queue_depth", len(self.sched.waiting))
            alloc = self.sched.allocator
            if alloc is not None:
                fl.counter_sample("pool_blocks_used", alloc.num_used)
                fl.counter_sample("pool_fragmentation", alloc.fragmentation())

        with tel.span("admit"):
            admissions = self.sched.admit()
        if admissions:
            self._progress = True
        for i, req in admissions:
            lane = self.lanes[i] = _Lane(req=req)
            if self.batched and req.prompt:
                # prefill overwrites every dense leaf for the lane; no
                # separate zeroing needed
                with tel.span("prefill", lane=i):
                    self._run_prefill(i, req)
            else:
                self.kv.zero_lane_dense(i)
                lane.prompt_left = deque(req.prompt)
                lane.generated = []
                lane.pos = 0
                lane.next_token = (
                    lane.prompt_left.popleft() if lane.prompt_left else 0
                )

        # decode phase: every occupied lane not prefilled this very tick
        candidates = [
            i for i, l in enumerate(self.lanes)
            if not l.free and l.prefilled_tick != self._tick
        ]
        # grow block tables (may preempt — youngest first); a lane whose own
        # request was preempted (or that cannot grow) drops out of the step
        active = []
        for i in candidates:
            if self.lanes[i].free:  # preempted as a victim earlier this loop
                continue
            if not self.sched.ensure_block(i, self.lanes[i].pos):
                continue
            active.append(i)
        active = [i for i in active if not self.lanes[i].free]
        if not active:
            return

        # The tick is ONE donated XLA program (gather -> step -> commit), so
        # host spans can only split dispatch from the device sync the logits
        # transfer forces; use Tracer(annotate=True) + jax.profiler for
        # phase-level device timing.
        with tel.span("decode_dispatch", lanes=len(active)):
            parts = self._dispatch_decode(active)
        with tel.span("device_sync"):
            logits = self._merge_logits(parts)

        logits = self._post_decode_checks(active, logits)

        with tel.span("sample_emit"):
            for i in active:
                lane = self.lanes[i]
                if lane.free:  # guard replay-preempted it after the sync
                    continue
                if (self.chaos is not None and
                        self.chaos.fire("drop_sample", lane=i) is not None):
                    # The sampled token is lost pre-commit; per-tick
                    # landmark-sum updates make an in-place retry unsound,
                    # so recovery is a full replay (recompute preemption).
                    self.sched.preempt(i)
                    continue
                lane.pos += 1
                tel.flight.record(
                    lane.req.uid, "decode", tick=self._tick, pos=lane.pos
                )
                if lane.prompt_left:  # replay prefill: ignore the sample
                    lane.next_token = lane.prompt_left.popleft()
                    continue
                self._emit_token(i, logits[i, : self.cfg.vocab_size])

        if self._frozen_rebase:
            # Lanes whose just-written position starts a new landmark
            # segment: rebase the newly-frozen row exactly and found the
            # new active row over the horizon (skips lanes retired above
            # and lanes demoted to the exact program, which has no drifting
            # active row to rebase).
            hits = [
                i for i in active
                if not self.lanes[i].free
                and self.lanes[i].req.uid not in self._demoted
                and (self.lanes[i].pos - 1) > 0
                and (self.lanes[i].pos - 1) % self._seg == 0
            ]
            if hits:
                with tel.span("rebase", lanes=len(hits)):
                    self._run_rebase(hits)

    # -- continuous-batching tick ----------------------------------------------
    def _tick_chunked(self) -> None:
        """One continuous-batching tick: decode dispatch FIRST (the device
        starts on it immediately), then admissions and a budget's worth of
        prompt chunks dispatched while the decode program runs, then ONE
        host sync at the sample boundary. Decode lanes advance every tick
        no matter how much prefill is pending (the never-starve invariant);
        prefill bandwidth is capped by ``prefill_token_budget`` per tick
        (0 = one chunk), so ITL stays flat under a long-prompt flood."""
        self._begin_tick()
        tel = self.telemetry
        if tel.enabled:
            self._ticks_total.inc()
            fl = tel.flight
            fl.counter_sample("queue_depth", len(self.sched.waiting))
            alloc = self.sched.allocator
            if alloc is not None:
                fl.counter_sample("pool_blocks_used", alloc.num_used)
                fl.counter_sample("pool_fragmentation", alloc.fragmentation())

        # ---- decode dispatch (no sync: chunks below overlap the compute) --
        candidates = [
            i for i, l in enumerate(self.lanes)
            if not l.free and not l.prefilling
            and l.prefilled_tick != self._tick
        ]
        active = []
        for i in candidates:
            if self.lanes[i].free:  # preempted as a victim earlier this loop
                continue
            if not self.sched.ensure_block(i, self.lanes[i].pos):
                continue
            active.append(i)
        active = [i for i in active if not self.lanes[i].free]
        parts: list = []
        if active:
            with tel.span("decode_dispatch", lanes=len(active)):
                parts = self._dispatch_decode(active)

        # ---- admissions: parked requests resume at their chunk boundary --
        with tel.span("admit"):
            admissions = self.sched.admit()
        if admissions:
            self._progress = True
        for i, req in admissions:
            lane = self.lanes[i] = _Lane(req=req)
            parked = self._parked.pop(req.uid, None)
            if parked is not None:
                self.kv.dense_restore(i, parked["snap"])
                lane.prefill_pos = parked["prefill_pos"]
                lane.chunk_idx = parked["chunk_idx"]
                lane.prefilling = True
            elif self._prefix_enabled and self._try_attach_prefix(i, req):
                pass  # lane state set by the attach (full or partial hit)
            else:
                self.kv.zero_lane_dense(i)
                if req.prompt:
                    lane.prefilling = True
                # empty prompt: straight to decode from pos 0, like replay

        # ---- budgeted chunk dispatch (FCFS by admission order) -----------
        budget = self.serve.prefill_token_budget or self._chunk
        max_chunks = max(1, budget // self._chunk)
        prefilling = sorted(
            (i for i, l in enumerate(self.lanes) if not l.free and l.prefilling),
            key=lambda i: self.sched.admit_order.get(
                self.lanes[i].req.uid, 0
            ),
        )
        pending_first: list[tuple[int, object, int]] = []
        launched = 0
        bs = self.serve.block_size
        dispatching = True
        while dispatching:
            dispatching = False
            for i in prefilling:
                if launched >= max_chunks:
                    break
                if self.lanes[i].free:
                    continue  # preempted by a deadlock break this tick
                lane = self.lanes[i]
                req = lane.req
                start = lane.prefill_pos
                cv = min(self._chunk, len(req.prompt) - start)
                if not self.sched.ensure_prefill_blocks(i, start + cv):
                    # pool dry: the chunk stalls, never evicts a decoder
                    continue
                ctoks = np.zeros((1, self._chunk), np.int32)
                ctoks[0, :cv] = req.prompt[start:start + cv]
                from repro.serve.paged import bucket_view_slots

                # the sliced row must span the committed prefix AND the
                # chunk's destination slots (the commit scatter reads its
                # block ids from this row; the wrapper's ZERO_BLOCK padding
                # is overrun guard only, not real slots)
                nbv = bucket_view_slots(
                    start // bs + self._chunk // bs, self.serve.blocks_per_lane
                )
                row = self.sched.table_row(i)[:nbv] if self.kv.paged else None
                with tel.span("prefill_chunk", lane=i, chunk=lane.chunk_idx):
                    lg, new_storage = self._chunk_step(
                        self.kv._storage, row, ctoks, i, start, cv
                    )
                    self.kv._storage = list(new_storage)
                tel.flight.record(
                    req.uid, "prefill_chunk", tick=self._tick,
                    chunk=lane.chunk_idx, tok0=start, tok1=start + cv, lane=i,
                )
                lane.prefill_pos = start + cv
                lane.chunk_idx += 1
                launched += 1
                if lane.prefill_pos >= len(req.prompt):
                    lane.prefilling = False
                    lane.pos = len(req.prompt)
                    lane.prefilled_tick = self._tick
                    pending_first.append((i, lg, cv))
                elif self._prefix_enabled and lane.prefill_pos % bs == 0:
                    # Block-aligned chunk boundary: snapshot the carried
                    # dense state as a partial-hit resume point. The host
                    # copy forces a device sync mid-tick — the documented
                    # cost of building cache entries, paid only while a
                    # prefill runs with the prefix cache on (the final
                    # boundary rides the sample-boundary sync instead).
                    lane.stat_points[lane.prefill_pos] = self.kv.dense_snapshot(i)

            # ---- all-prefill deadlock breaker ----------------------------
            # Every held lane stalled mid-prefill on a dry pool with no
            # decode lane left whose retirement could free blocks: the
            # chunk-stall rule ("a chunk never evicts a decoder") would
            # livelock here, because the stalled prefills hold each other's
            # growth room. Preempt the YOUNGEST stalled prefill and retry
            # dispatch WITHIN this tick, so the FCFS head's
            # ensure_prefill_blocks reclaims the victim's parked blocks
            # before the victim can re-admit (it requeues at the queue
            # front and would otherwise re-take the blocks next tick,
            # thrashing forever). Cascades at most one lane per pass until
            # the head launches. A single stalled lane is left alone: with
            # the whole pool to itself the stall is a sizing error, and
            # self-preemption would thrash instead of progress.
            if not launched:
                stalled = [i for i in prefilling if not self.lanes[i].free]
                decoding = any(
                    not l.free and not l.prefilling for l in self.lanes
                )
                if len(stalled) > 1 and not decoding and not self.sched.parked:
                    self.sched.preempt(stalled[-1])
                    dispatching = True

        if launched:
            self._progress = True

        # ---- ONE sync at the sample boundary -----------------------------
        with tel.span("device_sync"):
            logits = self._merge_logits(parts)
            firsts = [
                (i, np.asarray(
                    lg[0, cv - 1, : self.cfg.vocab_size], np.float32
                ))
                for i, lg, cv in pending_first
            ]

        logits = self._post_decode_checks(active, logits)

        with tel.span("sample_emit"):
            for i in active:
                lane = self.lanes[i]
                if lane.free:  # guard replay-preempted it after the sync
                    continue
                if (self.chaos is not None and
                        self.chaos.fire("drop_sample", lane=i) is not None):
                    self.sched.preempt(i)
                    continue
                lane.pos += 1
                tel.flight.record(
                    lane.req.uid, "decode", tick=self._tick, pos=lane.pos
                )
                self._emit_token(i, logits[i, : self.cfg.vocab_size])
            for i, lg in firsts:
                if self.lanes[i].free:  # cancelled mid-tick
                    continue
                if self._prefix_enabled:
                    # Cache the completed prefill BEFORE emitting (the emit
                    # may retire the lane; the entry's own block references
                    # keep the prefix resident past release).
                    self._maybe_cache_prefix(i, lg)
                self._emit_token(i, lg)

        if self._frozen_rebase:
            hits = [
                i for i in active
                if not self.lanes[i].free
                and self.lanes[i].req.uid not in self._demoted
                and (self.lanes[i].pos - 1) > 0
                and (self.lanes[i].pos - 1) % self._seg == 0
            ]
            if hits:
                with tel.span("rebase", lanes=len(hits)):
                    self._run_rebase(hits)

    def _run_rebase(self, hits: list[int]) -> None:
        """Frozen-mode segment-boundary rebase for the given lanes."""
        positions = np.zeros(self.max_lanes, np.int32)
        flags = np.zeros(self.max_lanes, bool)
        for i in hits:
            positions[i] = self.lanes[i].pos - 1
            flags[i] = True
        pre = (
            {i: self._lane_stream_stats(i) for i in hits}
            if self._drift_mon is not None else None
        )
        tables = self.sched.tables()  # fresh: retirements freed blocks
        nb_view = self.kv.view_blocks_needed(positions, hits)
        self.kv._storage = list(self._rebase_step(
            self.kv._storage, jnp.asarray(tables), jnp.asarray(positions),
            jnp.asarray(flags), nb_view,
        ))
        self._rebases += len(hits)
        self.telemetry.metrics.counter(
            "serve_rebases_total", help="frozen-mode boundary rebases"
        ).inc(len(hits))
        for i in hits:
            self.telemetry.flight.record(
                self.lanes[i].req.uid, "rebase", tick=self._tick,
                pos=int(positions[i]),
            )
        if pre is not None:
            self._probe_rebase_drift(hits, positions, pre)

    def _lane_stream_stats(self, lane: int) -> list[tuple]:
        """Host (m, l, acc) triples of one lane's streaming-stat leaves,
        one per attention layer group."""
        s = self.kv._storage
        return [
            (np.asarray(s[im][lane]), np.asarray(s[il][lane]),
             np.asarray(s[ia][lane]))
            for im, il, ia in self._stream_idx
        ]

    def _probe_rebase_drift(self, hits, positions, pre) -> None:
        """The free-residual probe: the rebase just recomputed the boundary
        rows exactly, so streamed(pre) vs exact(post) on those rows IS the
        frozen-mode drift bench_drift measures offline — same formula
        (monitors.bv_row_residual), O(c*d) host math per hit."""
        from repro.telemetry import bv_row_residual

        for i in hits:
            p = int(positions[i])
            j = p // self._seg  # new active row; j-1 just froze
            rows = [j - 1, j] if j > 0 else [j]
            post = self._lane_stream_stats(i)
            res = max(
                bv_row_residual((pl, pa), (ql, qa), rows)
                for (_, pl, pa), (_, ql, qa) in zip(pre[i], post)
            )
            self._drift_mon.observe(res)
            if self._spectrum_mon is not None:
                m = np.stack([g[0] for g in post])
                l = np.stack([g[1] for g in post])
                self._spectrum_mon.observe(
                    m, l, min(p // self._seg + 1, self.cfg.num_landmarks)
                )

    # -- maintenance -----------------------------------------------------------
    def defragment(self) -> int:
        """Compact live blocks onto the lowest pool ids (e.g. before
        shrinking or snapshotting the pool) and permute device storage to
        match. Safe between ticks; block tables stay valid. Returns the
        number of blocks moved."""
        if self.sched.allocator is None:
            return 0
        mapping = self.sched.allocator.defragment()
        self.kv.apply_mapping(mapping)
        return len(mapping)

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict:
        st = self.sched.stats()
        st["mode"] = (
            f"{'paged' if self.kv.has_paged_leaves else 'dense'}"
            f"+{'chunked' if self._chunked else 'batched' if self.batched else 'replay'}-prefill"
        )
        bt = self.decode_plan.block_table
        st["decode_plan"] = (
            f"{self.decode_plan.impl}/b{self.decode_plan.block_n}"
            + (f"/t{bt}" if bt else "")
            + f"/{self.decode_plan.source}"
        )
        st["decode_streaming"] = self.cfg.decode_streaming
        st["decode_impl"] = self.decode_impl
        if self._frozen_rebase:
            st["rebases"] = self._rebases
        st["quarantines"] = int(self._quarantines.value)
        st["demotions"] = int(self._demotions.value)
        st["watchdog_fires"] = int(self._wd_fires.value)
        if self.chaos is not None:
            st["chaos_injections"] = self.chaos.injections
        if self.prefix is not None:
            st["prefix"] = self.prefix.stats()
        if self.telemetry.enabled:
            st["telemetry"] = self.telemetry.tracer.summary()
            st["flight"] = self.telemetry.flight.summary()
            if self._acct is not None:
                st["xla_compiles"] = {
                    p: self._acct.compiles(p)
                    for p in ("prefill", "prefill_chunk", "decode_tick",
                              "rebase", "prefix_attach", "decode_exact")
                }
        return st
