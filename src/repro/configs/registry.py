"""Architecture registry + per-shape input specs (abstract or concrete)."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPE_PRESETS, ModelConfig, ShapeConfig
from repro.models.params import abstract_params
from repro.serve.kv_cache import cache_specs

ARCH_IDS = [
    "qwen2-72b",
    "qwen2-7b",
    "deepseek-67b",
    "granite-20b",
    "xlstm-350m",
    "whisper-base",
    "hymba-1.5b",
    "deepseek-v2-lite-16b",
    "kimi-k2-1t-a32b",
    "llava-next-34b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULES["paper-bert"] = "paper_bert"

ENCODER_SEQ = 1500  # whisper stub frame count


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """(ShapeDtypeStruct tree, logical-axes tree) for one (arch, shape) cell.

    train/prefill lower ``train_step``-style full-sequence inputs; decode
    lowers ``serve_step`` inputs: one new token + the full KV cache.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs: dict = {}
        axes: dict = {}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((b, ENCODER_SEQ, cfg.d_model), jnp.float32)
            axes["frames"] = ("batch", None, None)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            axes["tokens"] = ("batch", "seq")
        elif cfg.family == "vlm":
            p = min(cfg.num_patches, s // 2)
            specs["patches"] = jax.ShapeDtypeStruct((b, p, 1024), jnp.float32)
            axes["patches"] = ("batch", None, None)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - p), i32)
            axes["tokens"] = ("batch", "seq")
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            axes["tokens"] = ("batch", "seq")
        return specs, axes

    # decode: one new token against a seq_len cache
    from repro.models.params import logical_axes

    cspecs = cache_specs(cfg, b, s)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache": abstract_params(cspecs, dtype=jnp.dtype(cfg.compute_dtype)),
    }
    axes = {
        "tokens": ("cache_batch", None),
        "cache": logical_axes(cspecs),
    }
    return specs, axes


def shape_preset(name: str) -> ShapeConfig:
    return SHAPE_PRESETS[name]
