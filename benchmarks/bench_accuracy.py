"""Paper Theorem 1: approximation accuracy, SS vs the prototype (Nystrom)
model, across matrix regimes:

  (a) Lemma-1 matrices (flat-tail SPSD) — SS must be ~exact (Thm 1 setting);
  (b) softmax attention matrices from self-similar tokens (Q == K, the
      diagonally-dominant case attention actually exhibits);
  (c) the end-to-end attention OUTPUT error ||S V - S~ V|| through the
      linear-time path (what the transformer actually consumes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import (
    SSConfig,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)
from repro.core.matrix_approx import (
    approximate_spsd,
    flat_tail_spsd,
    sample_columns,
)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(a), 1e-30))


def run(csv_rows: list[str]) -> None:
    # (a) Lemma-1 regime.
    for theta in (0.1, 0.5, 1.0):
        K = flat_tail_spsd(192, 12, theta, seed=0)
        cols = sample_columns(192, 24)
        e_ny = _rel(K, approximate_spsd(K, cols, "prototype"))
        e_ss = _rel(K, approximate_spsd(K, cols, "modified_ss_shifted",
                                        target_rank=12))
        csv_rows.append(f"accuracy_lemma1,nystrom,theta={theta},{e_ny:.5f}")
        csv_rows.append(f"accuracy_lemma1,spectral_shift,theta={theta},{e_ss:.2e}")
        csv_rows.append(
            f"accuracy_lemma1,improvement,theta={theta},{e_ny / max(e_ss, 1e-12):.1e}"
        )

    # (b) softmax attention matrices (self-similar tokens).
    for c in (24, 48, 96):
        errs_ny, errs_ss = [], []
        for seed in range(5):
            key = jax.random.PRNGKey(seed)
            x = jax.random.normal(key, (192, 24)) * 0.8
            s = x @ x.T / np.sqrt(24)
            p = jnp.exp(s - s.max(-1, keepdims=True))
            attn = p / p.sum(-1, keepdims=True)
            cols = sample_columns(192, c)
            errs_ny.append(_rel(attn, approximate_spsd(attn, cols, "prototype")))
            errs_ss.append(_rel(attn, approximate_spsd(attn, cols, "modified_ss")))
        csv_rows.append(f"accuracy_attnmat,nystrom,c={c},{np.mean(errs_ny):.4f}")
        csv_rows.append(f"accuracy_attnmat,spectral_shift,c={c},{np.mean(errs_ss):.4f}")

    # (c) end-to-end attention output (the linear-time path).
    for c in (32, 64, 128):
        errs_ny, errs_ss = [], []
        for seed in range(5):
            key = jax.random.PRNGKey(seed)
            x = jax.random.normal(key, (1, 512, 32))
            v = jax.random.normal(jax.random.PRNGKey(seed + 50), (1, 512, 32))
            exact = full_attention(x, x, v)
            ss = spectral_shift_attention(
                x, x, v, SSConfig(num_landmarks=c, method="svd")
            )
            ny = nystrom_attention(x, x, v, num_landmarks=c)
            errs_ny.append(_rel(exact, ny))
            errs_ss.append(_rel(exact, ss))
        csv_rows.append(f"accuracy_output,nystrom,c={c},{np.mean(errs_ny):.4f}")
        csv_rows.append(f"accuracy_output,spectral_shift,c={c},{np.mean(errs_ss):.4f}")


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("\n".join(rows))
