"""repro — production-scale JAX/Pallas reproduction of Spectral Shifting.

Process-wide numerics configuration lives here (imported before any mesh or
jit is built):

* ``jax_threefry_partitionable=True`` — the legacy (non-partitionable)
  threefry lowering produces *different* random values for the same key
  depending on the output sharding GSPMD assigns, so jitted parameter init
  with sharded ``out_shardings`` diverged between mesh shapes (TP-4 vs
  single-device trained from different ``embed``/``lm_head`` weights).
  Partitionable threefry makes random bits a pure function of (key, shape),
  independent of partitioning, which is the documented contract every
  multi-mesh test and elastic-restart path in this repo relies on.
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
