"""Gradient correctness of the custom-VJP Pallas kernels (interpret mode).

Three layers of evidence:
* end-to-end ``jax.grad`` parity of ``ss_attention_fused`` against the jnp
  reference path, causal and non-causal, padded and unpadded;
* finite-difference spot checks (``jax.test_util.check_grads``) directly on
  the two custom-VJP ops;
* the ``remat="ss_stats"`` policy (save only BV + online-softmax stats)
  leaves gradients bit-compatible with no-remat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.test_util
import numpy as np
import pytest

from repro.core.attention import SSConfig, spectral_shift_attention
from repro.kernels.ops import (
    landmark_summary_op,
    query_side_op,
    ss_attention_fused,
)


def _qkv(b, n, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = (jax.random.normal(ks[0], (b, n, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, n, d)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (b, n, d)).astype(dtype)
    return q, k, v


def _max_rel_err(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-3)))


class TestFusedGradParity:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("n,c", [(256, 32), (300, 16)])  # 300: padded tail
    def test_grad_matches_jnp_path(self, causal, n, c):
        q, k, v = _qkv(2, n, 32)
        w = jax.random.normal(jax.random.PRNGKey(7), q.shape)
        cfg = SSConfig(num_landmarks=c, causal=causal)

        def loss_fused(q, k, v):
            return jnp.sum(ss_attention_fused(q, k, v, cfg, interpret=True) * w)

        def loss_jnp(q, k, v):
            return jnp.sum(spectral_shift_attention(q, k, v, cfg) * w)

        np.testing.assert_allclose(
            loss_fused(q, k, v), loss_jnp(q, k, v), rtol=1e-4
        )
        g_fused = jax.grad(loss_fused, argnums=(0, 1, 2))(q, k, v)
        g_jnp = jax.grad(loss_jnp, argnums=(0, 1, 2))(q, k, v)
        for name, a, b in zip("qkv", g_fused, g_jnp):
            rel = _max_rel_err(a, b)
            assert rel < 1e-2, f"d{name} rel err {rel} (causal={causal}, n={n})"

    def test_grad_multihead_lead_dims(self):
        key = jax.random.PRNGKey(5)
        q = jax.random.normal(key, (2, 4, 128, 16)) * 0.5
        cfg = SSConfig(num_landmarks=16, causal=True)

        def loss(q):
            return jnp.sum(ss_attention_fused(q, q, q, cfg, interpret=True) ** 2)

        def loss_ref(q):
            return jnp.sum(spectral_shift_attention(q, q, q, cfg) ** 2)

        rel = _max_rel_err(jax.grad(loss)(q), jax.grad(loss_ref)(q))
        assert rel < 1e-2, rel


class TestFiniteDifferences:
    """check_grads on the raw custom-VJP ops (small shapes, rev mode)."""

    @pytest.mark.parametrize("causal", [False, True])
    def test_landmark_summary_op(self, causal):
        b, c, n, d = 1, 8, 48, 16
        q_l = jax.random.normal(jax.random.PRNGKey(0), (b, c, d)) * 0.5
        k = jax.random.normal(jax.random.PRNGKey(1), (b, n, d)) * 0.5
        v = jax.random.normal(jax.random.PRNGKey(2), (b, n, d))
        # (scale, block_n, block_c, causal, interpret)
        meta = (d**-0.5, 16, 0, causal, True)
        jax.test_util.check_grads(
            lambda *a: landmark_summary_op(meta, *a),
            (q_l, k, v),
            order=1,
            modes=["rev"],
            atol=5e-2,
            rtol=5e-2,
        )

    @pytest.mark.parametrize("causal", [False, True])
    def test_query_side_op(self, causal):
        b, c, n, d = 1, 8, 48, 16
        q = jax.random.normal(jax.random.PRNGKey(3), (b, n, d)) * 0.5
        k_l = jax.random.normal(jax.random.PRNGKey(4), (b, c, d)) * 0.5
        m_mat = jax.random.normal(jax.random.PRNGKey(5), (b, c, d))
        v = jax.random.normal(jax.random.PRNGKey(6), (b, n, d))
        delta = jnp.full((b, 1, 1), 0.3, jnp.float32)
        meta = (d**-0.5, 16, causal, n, True)
        jax.test_util.check_grads(
            lambda *a: query_side_op(meta, *a),
            (q, k_l, m_mat, v, delta),
            order=1,
            modes=["rev"],
            atol=5e-2,
            rtol=5e-2,
        )


class TestSSStatsRemat:
    def test_policy_preserves_grads(self):
        q, k, v = _qkv(1, 192, 32, seed=3)
        cfg = SSConfig(num_landmarks=16, causal=True)

        def loss(q, k, v):
            return jnp.sum(ss_attention_fused(q, k, v, cfg, interpret=True) ** 2)

        remat_loss = jax.checkpoint(
            loss,
            policy=jax.checkpoint_policies.save_only_these_names(
                "ss_bv", "ss_stats"
            ),
        )
        g0 = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        g1 = jax.grad(remat_loss, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g0, g1):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_model_level_ss_stats_remat(self):
        """Full reduced decoder: remat='ss_stats' grads match remat='none'."""
        import dataclasses

        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.models.model import model_specs
        from repro.models.params import init_params
        from repro.train.train_step import make_grad_step

        base = reduced(
            get_config("qwen2-7b"),
            num_landmarks=8,
            attention_impl="spectral_shift_fused",
            attention_backend="interpret",
        )
        params = init_params(model_specs(base), jax.random.PRNGKey(0))
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (2, 64), 0, base.vocab_size
        )
        batch = {"tokens": tokens}
        grads = {}
        for remat in ("none", "ss_stats"):
            cfg = dataclasses.replace(base, remat=remat)
            loss, g = jax.jit(make_grad_step(cfg))(params, batch)
            assert bool(jnp.isfinite(loss))
            grads[remat] = g
        for a, b in zip(
            jax.tree.leaves(grads["none"]), jax.tree.leaves(grads["ss_stats"])
        ):
            # Remat re-fuses the recomputed forward, so float association
            # differs slightly from the no-remat program.
            np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-3)


class TestBF16GradParity:
    """bf16 backward sweep vs the fp32 jnp oracle on IDENTICAL bf16 inputs
    (isolates kernel-vs-oracle error from input quantization). Tolerances
    are pinned from measured maxima over 4 seeds (ROADMAP item "bf16 bwd
    tolerances unmeasured"): per-op max rel err <= 8e-3 for every cotangent
    (measured; floor 1e-2); pinned at 2e-2 for headroom. The end-to-end
    bound is looser because the jnp path casts intermediates (landmark
    means, softmax factors) through bf16 at different points than the
    kernels do."""

    @staticmethod
    def _rel(a, b):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-2)))

    @pytest.mark.parametrize("seed", [0, 1])
    def test_landmark_summary_op_bf16(self, seed):
        from repro.kernels.ref import ref_landmark_summary

        b, c, n, d = 2, 16, 256, 32
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q_l = (jax.random.normal(ks[0], (b, c, d)) * 0.5).astype(jnp.bfloat16)
        k = (jax.random.normal(ks[1], (b, n, d)) * 0.5).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, n, d)).astype(jnp.bfloat16)
        w = jax.random.normal(ks[3], (b, c, d))
        meta = (d**-0.5, 128, 0, False, True)
        g16 = jax.grad(
            lambda *a: jnp.sum(
                landmark_summary_op(meta, *a).astype(jnp.float32) * w
            ),
            argnums=(0, 1, 2),
        )(q_l, k, v)
        g32 = jax.grad(
            lambda *a: jnp.sum(
                ref_landmark_summary(*a, d**-0.5).astype(jnp.float32) * w
            ),
            argnums=(0, 1, 2),
        )(q_l, k, v)
        for name, a, b_ in zip(("dq_l", "dk", "dv"), g16, g32):
            r = self._rel(a, b_)
            assert r < 2e-2, f"{name} bf16 rel err {r} (measured max 8e-3)"

    @pytest.mark.parametrize("seed", [0, 1])
    def test_query_side_op_bf16(self, seed):
        from repro.kernels.ref import ref_query_side

        b, c, n, d = 2, 16, 256, 32
        ks = jax.random.split(jax.random.PRNGKey(seed + 10), 5)
        q = (jax.random.normal(ks[0], (b, n, d)) * 0.5).astype(jnp.bfloat16)
        k_l = (jax.random.normal(ks[1], (b, c, d)) * 0.5).astype(jnp.bfloat16)
        m_mat = jax.random.normal(ks[2], (b, c, d)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[3], (b, n, d)).astype(jnp.bfloat16)
        delta = jnp.full((b, 1, 1), 0.3, jnp.float32)
        w = jax.random.normal(ks[4], (b, n, d))
        meta = (d**-0.5, 128, False, n, True)
        g16 = jax.grad(
            lambda *a: jnp.sum(
                query_side_op(meta, *a, delta).astype(jnp.float32) * w
            ),
            argnums=(0, 1, 2, 3),
        )(q, k_l, m_mat, v)
        g32 = jax.grad(
            lambda *a: jnp.sum(
                ref_query_side(*a, delta, d**-0.5).astype(jnp.float32) * w
            ),
            argnums=(0, 1, 2, 3),
        )(q, k_l, m_mat, v)
        for name, a, b_ in zip(("dq", "dk_l", "dm", "dv"), g16, g32):
            r = self._rel(a, b_)
            assert r < 2e-2, f"{name} bf16 rel err {r} (measured max 8e-3)"

    @pytest.mark.parametrize("causal", [False, True])
    def test_fused_end_to_end_bf16(self, causal):
        b, c, n, d = 2, 16, 256, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = (jax.random.normal(ks[0], (b, n, d)) * 0.5).astype(jnp.bfloat16)
        k = (jax.random.normal(ks[1], (b, n, d)) * 0.5).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, n, d)).astype(jnp.bfloat16)
        w = jax.random.normal(ks[3], (b, n, d))
        cfg = SSConfig(num_landmarks=c, causal=causal)
        ge = jax.grad(
            lambda q, k, v: jnp.sum(
                ss_attention_fused(q, k, v, cfg, interpret=True).astype(
                    jnp.float32
                ) * w
            ),
            argnums=(0, 1, 2),
        )(q, k, v)
        gj = jax.grad(
            lambda q, k, v: jnp.sum(
                spectral_shift_attention(q, k, v, cfg).astype(jnp.float32) * w
            ),
            argnums=(0, 1, 2),
        )(q, k, v)
        for name, a, b_ in zip("qkv", ge, gj):
            r = self._rel(a.astype(jnp.float32), b_.astype(jnp.float32))
            # Measured maxima over seeds: 0.20 (bidir dq/dk), 0.18 (causal
            # dv); both paths re-quantize different intermediates to bf16.
            assert r < 0.35, f"d{name} bf16 e2e rel err {r} (causal={causal})"
        assert all(
            bool(jnp.all(jnp.isfinite(x.astype(jnp.float32)))) for x in ge
        )
