"""Benchmark harness entry point: one module per paper table/figure plus the
roofline table. Prints ``name,case,metric,value`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_accuracy,
    bench_complexity,
    bench_decode,
    bench_drift,
    bench_error_bound,
    bench_serve,
    bench_sharded_attn,
    bench_spectrum,
    bench_train_step,
    roofline,
)

SUITES = {
    "complexity": bench_complexity.run,      # paper Table 1
    "spectrum": bench_spectrum.run,          # paper Figure 2
    "accuracy": bench_accuracy.run,          # paper Theorem 1
    "error_bound": bench_error_bound.run,    # paper §7 eq. (12)
    "roofline": roofline.run,                # EXPERIMENTS.md §Roofline
    "serve": bench_serve.run,                # paged vs dense serving TTFT
    "decode": bench_decode.run,              # streaming/gather/paged decode
                                             # (also writes BENCH_decode.json)
    "drift": bench_drift.run,                # frozen-mode drift decomposition
    "train_step": bench_train_step.run,      # fused vs jnp fwd+bwd
    "sharded_attn": bench_sharded_attn.run,  # context-parallel fused vs jnp
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()

    rows: list[str] = []
    failures = 0
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn(rows)
            rows.append(f"suite,{name},elapsed_s,{time.time() - t0:.1f}")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            rows.append(f"suite,{name},ERROR,{type(e).__name__}: {e}")
    print("name,case,metric,value")
    print("\n".join(rows))
    if failures:
        print(f"# {failures} suite(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
