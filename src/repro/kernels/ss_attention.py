"""Pallas TPU kernels for spectral-shifting attention (DESIGN.md §3).

Two kernels cover the only O(n) GEMMs in the method; everything else is
O(c^2)-small and stays in jnp:

* ``landmark_summary``  (B-side): ``BV = softmax(Q~ K^T) @ V``. The c landmark
  queries are VMEM-resident; K/V stream HBM->VMEM in ``block_n`` chunks with
  the online-softmax (flash) recurrence, so no (c, n) intermediate ever
  exists. Grid = (batch, n_blocks), n innermost so the fp32 accumulators in
  VMEM scratch persist across the stream. ``return_stats=True`` additionally
  emits the per-row online-softmax statistics ``(m, l)`` — the residuals the
  custom-VJP backward kernel (ss_attention_bwd.py) uses to reconstruct the
  softmax factor exactly without a second reduction pass.

* ``query_side`` (F-side): ``out = softmax(Q K~^T) @ M + delta * V`` with
  ``M = U_ss (BV)`` (c x dv, VMEM-resident). Softmax axis is c (fully
  resident) so each Q/V block needs exactly one HBM read and one write —
  the (n, c) matrix F is never materialized.

Both kernels take ``seg`` (landmark segment length, 0 = bidirectional) for
the segment-causal variant: landmark row r only attends keys in segments
<= r (B-side), and query position p only attends landmark columns
<= segment_of(p) (F-side) — the same masks ``core.attention._ss_factors``
applies on the jnp path, evaluated inside the stream.

Block shapes default to MXU/VPU-aligned sizes (lane dim = head_dim, ideally
a multiple of 128; sublane blocks multiples of 8). Kernels are validated on
CPU in interpret mode against ``ref.py``; TPU is the compile target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _b_side_mask(shape, i, *, n_valid: int, block_n: int, seg: int):
    """Key-validity x segment-causal mask for one streamed B-side block
    (shape (c, bn) at block index ``i``), or None when nothing is masked.
    Shared by the forward step and the backward kernel so the two can never
    drift apart."""
    mask = None
    if n_valid % block_n:
        # Keys past the true sequence end (zero-padded tail block).
        kv_pos = i * block_n + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
        mask = kv_pos < n_valid
    if seg:
        # Segment-causal: landmark row r (the mean of segment r) attends
        # keys up to the end of its own segment only.
        kv_pos = i * block_n + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
        row = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
        cmask = kv_pos < (row + 1) * seg
        mask = cmask if mask is None else jnp.logical_and(mask, cmask)
    return mask


# --------------------------------------------------------------------------
# B-side: landmark summary with online softmax over the streamed n axis.
# --------------------------------------------------------------------------
def _landmark_summary_step(
    q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, *,
    scale: float, n_valid: int, block_n: int, seg: int,
):
    """One online-softmax step over key/value block ``i`` (shared by the
    plain and the stats-emitting kernel)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (c, d)
    k = k_ref[0].astype(jnp.float32)                      # (bn, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (c, bn)

    mask = _b_side_mask(s.shape, i, n_valid=n_valid, block_n=block_n, seg=seg)
    if mask is not None:
        s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                   # (c, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (c, bn)
    if mask is not None:
        # exp underflows to 0 for real scores, but a fully-masked row in the
        # first block has m_new == s == -inf => exp(0) == 1; zero explicitly.
        p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                        # (c, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (c, dv)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new


def _landmark_summary_kernel(
    q_ref,  # (1, c, d)    VMEM
    k_ref,  # (1, bn, d)   VMEM (streamed)
    v_ref,  # (1, bn, dv)  VMEM (streamed)
    o_ref,  # (1, c, dv)   VMEM
    m_scr,  # (c, 1)       fp32 scratch: running max
    l_scr,  # (c, 1)       fp32 scratch: running denominator
    acc_scr,  # (c, dv)    fp32 scratch: running numerator
    *,
    scale: float,
    n_valid: int,
    block_n: int,
    seg: int,
):
    _landmark_summary_step(
        q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
        scale=scale, n_valid=n_valid, block_n=block_n, seg=seg,
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _landmark_summary_stats_kernel(
    q_ref, k_ref, v_ref,
    o_ref,      # (1, c, dv)  VMEM
    mo_ref,     # (1, c, 1)   fp32: final row max
    lo_ref,     # (1, c, 1)   fp32: final row denominator
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    n_valid: int,
    block_n: int,
    seg: int,
):
    _landmark_summary_step(
        q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
        scale=scale, n_valid=n_valid, block_n=block_n, seg=seg,
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        mo_ref[0] = m_scr[...]
        lo_ref[0] = l_scr[...]


def landmark_summary(
    q_l: jnp.ndarray,  # (b, c, d)
    k: jnp.ndarray,    # (b, n, d)
    v: jnp.ndarray,    # (b, n, dv)
    *,
    scale: float,
    block_n: int = 512,
    causal: bool = False,
    interpret: bool = False,
    return_stats: bool = False,
):
    """BV = softmax(Q~ K^T * scale) @ V via a flash-style streamed kernel.

    ``causal=True`` applies the segment-causal B-mask (landmark r sees keys
    < (r+1)*seg with seg = ceil(n/c)). ``return_stats=True`` returns
    ``(bv, m, l)`` with ``m``/``l`` (b, c, 1) fp32 — the online-softmax max
    and denominator, saved as custom-VJP residuals.
    """
    b, c, d = q_l.shape
    n, dv = k.shape[1], v.shape[2]
    seg = -(-n // c) if causal else 0
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        k = jnp.pad(k, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    in_specs = [
        pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
    ]
    scratch_shapes = [
        pltpu.VMEM((c, 1), jnp.float32),
        pltpu.VMEM((c, 1), jnp.float32),
        pltpu.VMEM((c, dv), jnp.float32),
    ]
    common = dict(scale=scale, n_valid=n, block_n=block_n, seg=seg)
    if not return_stats:
        kernel = functools.partial(_landmark_summary_kernel, **common)
        return pl.pallas_call(
            kernel,
            grid=(b, n_blocks),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, c, dv), v.dtype),
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(q_l, k, v)

    kernel = functools.partial(_landmark_summary_stats_kernel, **common)
    stat_spec = pl.BlockSpec((1, c, 1), lambda bi, i: (bi, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
            stat_spec,
            stat_spec,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, c, dv), v.dtype),
            jax.ShapeDtypeStruct((b, c, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, c, 1), jnp.float32),
        ),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(q_l, k, v)


# --------------------------------------------------------------------------
# F-side: fused softmax(Q K~^T) @ M + delta * V over streamed Q/V blocks.
# --------------------------------------------------------------------------
def _query_side_probs(q_ref, kl_ref, *, scale, block_n, seg, pos_offset):
    """Block-resident softmax factor P (bn, c), with the segment-causal
    F-mask applied when ``seg`` is set. Shared with the backward kernel."""
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                      # (bn, d)
    kl = kl_ref[0].astype(jnp.float32)                    # (c, d)
    s = jax.lax.dot_general(
        q, kl, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (bn, c)
    mask = None
    if seg:
        # Query at position p attends landmark columns <= p // seg only.
        qpos = (
            i * block_n
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            + pos_offset
        )
        lseg = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = lseg <= qpos // seg
        s = jnp.where(mask, s, _NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    return p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)


def _query_side_kernel(
    q_ref,      # (1, bn, d)   VMEM (streamed)
    kl_ref,     # (1, c, d)    VMEM
    m_ref,      # (1, c, dv)   VMEM
    v_ref,      # (1, bn, dv)  VMEM (streamed)
    delta_ref,  # (1, 1, 1)    SMEM-ish scalar block
    o_ref,      # (1, bn, dv)  VMEM
    *,
    scale: float,
    block_n: int,
    seg: int,
    pos_offset: int,
):
    p = _query_side_probs(
        q_ref, kl_ref, scale=scale, block_n=block_n, seg=seg,
        pos_offset=pos_offset,
    )
    out = jax.lax.dot_general(
        p, m_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (bn, dv)
    out = out + delta_ref[0, 0, 0] * v_ref[0].astype(jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


def query_side(
    q: jnp.ndarray,      # (b, n, d)
    k_l: jnp.ndarray,    # (b, c, d)
    m_mat: jnp.ndarray,  # (b, c, dv)
    v: jnp.ndarray,      # (b, n, dv)
    delta: jnp.ndarray,  # (b, 1, 1)
    *,
    scale: float,
    block_n: int = 512,
    causal: bool = False,
    seq_len_k: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """out = softmax(Q K~^T * scale) @ M + delta * V, one HBM pass over Q/V.

    ``causal=True`` applies the segment-causal F-mask; ``seq_len_k`` is the
    key-sequence length the landmark segments were built from (defaults to
    n, i.e. self-attention; a longer context puts the queries at its tail,
    the decode convention).
    """
    b, n, d = q.shape
    c, dv = k_l.shape[1], v.shape[2]
    n_k = seq_len_k or n
    seg = -(-n_k // c) if causal else 0
    pos_offset = n_k - n if causal else 0
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        q = jnp.pad(q, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    kernel = functools.partial(
        _query_side_kernel, scale=scale, block_n=block_n, seg=seg,
        pos_offset=pos_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, i: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n + n_pad, dv), q.dtype),
        interpret=interpret,
    )(q, k_l, m_mat, v, delta.astype(jnp.float32))
    return out[:, :n] if n_pad else out
