"""Prefix caching: content-hash index + refcounted copy-on-write block
sharing (serve/paged.py PrefixCache), landmark-stat re-segmentation
(decode_state.resegment_sums), and the engine-level attach paths — full
hit, partial hit, COW divergence — against cold-prefill references."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import ZERO_BLOCK, BlockAllocator, PrefixCache


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


BASE = ServeConfig(max_lanes=2, max_seq=64, block_size=8)
# Small chunks so multi-chunk prefills leave intermediate stat points for
# partial-hit resume to land on.
PREFIX = dataclasses.replace(BASE, prefix_cache=True, prefill_chunk_tokens=16)
# Cold reference running the SAME chunked-prefill programs, no cache.
COLD = dataclasses.replace(PREFIX, prefix_cache=False, chunked_prefill=True)


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(3, cfg.vocab_size, n).tolist()


def _serve_seq(cfg, params, serve, prompts, max_new=8):
    """One engine; each prompt runs to completion before the next is
    submitted, so later prompts can hit earlier prompts' cached prefixes."""
    eng = ServeEngine(cfg, params, serve=serve)
    out = {}
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, list(p), max_new_tokens=max_new))
        out.update(eng.run())
    return out, eng


# ==========================================================================
# Allocator refcount invariants
# ==========================================================================
def _check_invariant(a: BlockAllocator):
    """Every non-zero block is exactly one of: free, or held at rc >= 1."""
    free, held = set(a._free), set(a.refcounts)
    assert not (free & held), "block simultaneously free and referenced"
    assert len(a._free) == len(free), "duplicate id on the free list"
    assert free | held | {ZERO_BLOCK} == set(range(a.num_blocks))
    assert all(rc >= 1 for rc in a.refcounts.values())


class TestRefcountedAllocator:
    def test_shared_block_survives_free(self):
        a = BlockAllocator(9, 8)
        got = a.alloc(1, 3)
        a.take_ref(got[1])  # simulate cache retention
        freed = a.free(1)
        assert got[1] not in freed and got[1] not in a._free
        assert a.refcount(got[1]) == 1
        _check_invariant(a)
        assert a.release_ref(got[1]) is True  # last holder frees it
        assert a.num_free == 8
        _check_invariant(a)

    def test_take_ref_on_free_block_raises(self):
        a = BlockAllocator(9, 8)
        with pytest.raises(ValueError):
            a.take_ref(3)

    def test_attach_shared_prepends_and_cow_breaks_sharing(self):
        a = BlockAllocator(17, 8)
        owner = a.alloc(1, 3)
        a.attach_shared(2, owner)
        assert a.tables[2] == owner
        assert [a.refcount(b) for b in owner] == [2, 2, 2]
        a.alloc(2, 1)  # tail grows past the shared span
        assert a.tables[2][:3] == owner and len(a.tables[2]) == 4
        old, new = a.cow(2, 1)
        assert (old, new) == (owner[1], a.tables[2][1])
        assert new != old and a.refcount(old) == 1 and a.refcount(new) == 1
        assert a.tables[1] == owner  # the other holder's view is untouched
        _check_invariant(a)
        a.free(2)
        a.free(1)
        assert a.num_free == 16
        _check_invariant(a)

    def test_defragment_pins_shared_blocks(self):
        a = BlockAllocator(17, 8)
        a.alloc(1, 3)  # blocks 1..3
        a.alloc(2, 4)  # blocks 4..7
        pinned = a.tables[2][3]  # block 7
        a.take_ref(pinned)  # rc 2: shared -> must not move
        a.free(1)  # hole at 1..3
        mapping = a.defragment()
        assert pinned not in mapping and pinned not in mapping.values()
        assert a.tables[2] == [1, 2, 3, pinned]
        assert a.refcount(pinned) == 2
        _check_invariant(a)

    def test_pool_pressure_evicts_cache_only_entries(self):
        a = BlockAllocator(9, 4)  # 8 usable
        pc = PrefixCache(a)
        a.alloc(0, 4)
        pc.insert(list(range(16)), a.tables[0], logits=np.zeros(4))
        # owner still maps the blocks (rc 2): not reclaimable, no progress
        assert a.alloc(1, 5) is None
        assert pc.stats()["evictions"] == 0 and a.num_free == 4
        a.free(0)  # cache becomes sole holder (rc 1): reclaimable
        assert a.can_alloc(6)
        got = a.alloc(1, 6)  # shortfall LRU-evicts the entry mid-alloc
        assert got is not None and len(got) == 6
        st = pc.stats()
        assert st["evictions"] == 1 and st["entries"] == 0
        _check_invariant(a)

    def test_overlapping_entries_cascade_evict_under_pressure(self):
        """Partial-hit completion inserts a longer entry whose leading
        blocks are an earlier entry's (rc 2 from the cache alone). Once no
        live table maps the chain it must still be reclaimable — evicting
        in cascade — or the blocks leak into frozen entries until
        admissions stall."""
        a = BlockAllocator(9, 4)  # 8 usable
        pc = PrefixCache(a)
        p = list(range(8))  # 2 full blocks
        a.alloc(0, 2)
        e1 = pc.insert(p, a.tables[0], logits=np.zeros(4))
        # uid 1 attaches the cached prefix, extends 2 blocks, completes
        a.attach_shared(1, e1.blocks)
        a.alloc(1, 2)
        e2 = pc.insert(p + list(range(50, 58)), a.tables[1],
                       logits=np.zeros(4))
        assert e2 is not None and e2.blocks[:2] == e1.blocks
        a.free(0)
        a.free(1)
        # cache-only chain: shared blocks rc 2 (two entries), tails rc 1
        assert [a.refcount(b) for b in e1.blocks] == [2, 2]
        assert pc.evictable_blocks() == 4  # distinct, not double-counted
        assert a.can_alloc(8)
        got = a.alloc(2, 8)  # shortfall cascades through both entries
        assert got is not None and len(got) == 8
        st = pc.stats()
        assert st["entries"] == 0 and st["evictions"] == 2
        assert pc._cache_refs == {}
        _check_invariant(a)

    def test_cascade_respects_live_extension_holder(self):
        """A live table mapping the longer entry keeps the WHOLE chain
        non-reclaimable: pressure must not free anything the table still
        reads, and the shortfall reports failure instead."""
        a = BlockAllocator(9, 4)
        pc = PrefixCache(a)
        p = list(range(8))
        a.alloc(0, 2)
        e1 = pc.insert(p, a.tables[0], logits=np.zeros(4))
        a.attach_shared(1, e1.blocks)
        a.alloc(1, 2)
        pc.insert(p + list(range(50, 58)), a.tables[1], logits=np.zeros(4))
        a.free(0)  # uid 1 still live and maps all four blocks
        assert pc.evictable_blocks() == 0
        assert a.alloc(2, 5) is None
        assert set(a.tables[1]).isdisjoint(a._free)
        assert pc.stats()["evictions"] == 0
        _check_invariant(a)

    def test_probe_pin_is_soft_and_deprioritized(self):
        """A soft-pinned entry (admission in flight between probe and
        attach) is evicted only after every unpinned candidate — but IS
        evicted when it is the only room left, so admission can't
        deadlock on its own pin."""
        a = BlockAllocator(9, 4)
        pc = PrefixCache(a)
        a.alloc(0, 2)
        e1 = pc.insert(list(range(8)), a.tables[0], logits=np.zeros(4))
        a.alloc(1, 2)
        e2 = pc.insert(list(range(50, 58)), a.tables[1], logits=np.zeros(4))
        a.free(0)
        a.free(1)
        pc.pin(e1)
        pc.touch(e2)  # e2 is now MRU: plain LRU would pick e1 first
        assert a.alloc(2, 6) is not None  # needs 2 evicted blocks
        assert e1 in pc._entries and e2 not in pc._entries
        assert a.alloc(3, 2) is not None  # only the pinned entry remains
        assert pc.stats()["entries"] == 0
        _check_invariant(a)


# ==========================================================================
# Content hashing + index
# ==========================================================================
class TestPrefixHashing:
    def test_chained_digests_fingerprint_whole_prefix(self):
        p = list(range(100, 120))  # 5 full blocks of 4
        h = PrefixCache.block_hashes(p, 4)
        assert len(h) == 5
        for i in range(5):
            assert h[i] == PrefixCache.block_hashes(p[: 4 * (i + 1)], 4)[-1]
        # flip one token in block 0: EVERY downstream digest changes
        p2 = [999] + p[1:]
        h2 = PrefixCache.block_hashes(p2, 4)
        assert all(x != y for x, y in zip(h, h2))
        assert PrefixCache.block_hashes(p[:3], 4) == []  # sub-block prompt

    def test_match_longest_and_full_hit(self):
        a = BlockAllocator(33, 4)
        pc = PrefixCache(a)
        p1 = list(range(100, 114))  # 14 tokens: 3 full blocks + tail of 2
        a.alloc(0, 4)
        e = pc.insert(p1, a.tables[0], stat_points={14: []},
                      logits=np.zeros(8))
        assert e is not None and [a.refcount(b) for b in e.blocks] == [2] * 4
        got = pc.match(p1[:12] + [7, 7, 7, 7])  # diverges after block 3
        assert got is not None and got[1] == 3
        assert not pc.is_full_hit(got[0], p1[:12] + [7, 7, 7, 7], 3)
        got = pc.match(p1)
        assert got[1] == 3 and pc.is_full_hit(got[0], p1, 3)
        assert pc.match([7] * 14) is None

    def test_insert_first_wins_without_ref_leak(self):
        a = BlockAllocator(33, 4)
        pc = PrefixCache(a)
        p = list(range(12))
        a.alloc(0, 3)
        e = pc.insert(p, a.tables[0])
        assert e is not None
        a.alloc(1, 3)
        # identical prompt from another request: every boundary already
        # indexed -> refused BEFORE taking any references
        assert pc.insert(p, a.tables[1]) is None
        assert [a.refcount(b) for b in a.tables[1]] == [1, 1, 1]
        assert pc.stats()["entries"] == 1

    def test_max_blocks_cap_evicts_lru(self):
        a = BlockAllocator(33, 4)
        pc = PrefixCache(a, max_blocks=4)
        a.alloc(0, 3)
        pc.insert(list(range(12)), a.tables[0])
        a.alloc(1, 3)
        pc.insert(list(range(50, 62)), a.tables[1])
        st = pc.stats()
        assert st["evictions"] == 1 and st["blocks"] <= 4
        _check_invariant(a)


# ==========================================================================
# Landmark-sum re-segmentation
# ==========================================================================
class TestResegmentSums:
    def test_fine_to_coarse_matches_direct_sums(self):
        from repro.serve.decode_state import resegment_sums

        rng = np.random.default_rng(60)
        B, H, c, d = 1, 2, 8, 4
        sums = jnp.asarray(rng.normal(size=(B, H, c, d)), jnp.float32)
        out = np.asarray(resegment_sums(sums, 2, 4))  # m=2 fine rows per row
        ref = np.zeros_like(out)
        ref[..., : c // 2, :] = np.asarray(sums).reshape(
            B, H, c // 2, 2, d).sum(3)
        np.testing.assert_allclose(out, ref, atol=1e-6, rtol=1e-6)

    def test_token_level_oracle(self):
        """Re-segmenting per-segment token sums == summing the tokens under
        the coarse segmentation directly."""
        from repro.serve.decode_state import resegment_sums

        rng = np.random.default_rng(61)
        c, d, seg_f, seg_c = 8, 4, 2, 8
        n = c * seg_f  # tokens fill every fine segment
        toks = rng.normal(size=(n, d)).astype(np.float32)
        fine = np.stack([toks[j * seg_f:(j + 1) * seg_f].sum(0)
                         for j in range(c)])
        coarse = np.zeros((c, d), np.float32)
        for j in range(-(-n // seg_c)):
            coarse[j] = toks[j * seg_c:(j + 1) * seg_c].sum(0)
        got = np.asarray(resegment_sums(
            jnp.asarray(fine)[None, None], seg_f, seg_c))[0, 0]
        np.testing.assert_allclose(got, coarse, atol=1e-5, rtol=1e-5)

    def test_identity_and_divisibility(self):
        from repro.serve.decode_state import resegment_sums

        sums = jnp.ones((1, 1, 4, 2))
        assert resegment_sums(sums, 4, 4) is sums
        with pytest.raises(ValueError):
            resegment_sums(sums, 3, 4)


# ==========================================================================
# Engine: attach paths vs cold prefill
# ==========================================================================
class TestEnginePrefixCache:
    def test_full_hit_aligned_token_identical(self, qwen):
        """Block-aligned full hit: warm request skips prefill entirely
        (first token from cached logits) and stays greedy-identical."""
        cfg, params = qwen
        p = _prompt(cfg, 40, seed=50)  # 5 full blocks, no partial tail
        ref, _ = _serve_seq(cfg, params, COLD, [p, p])
        out, eng = _serve_seq(cfg, params, PREFIX, [p, p])
        assert out == ref and out[0] == out[1]
        st = eng.stats()
        assert st["prefix"]["hits"] == 1 and st["prefix"]["misses"] == 1
        assert st["cow_copies"] == 0  # no shared partial block to break

    def test_full_hit_unaligned_cow_divergence(self, qwen):
        """Unaligned full hit shares the partial last block; both the owner
        and the warm request copy-on-write it before their first divergent
        decode write — outputs stay identical to cold."""
        cfg, params = qwen
        p = _prompt(cfg, 37, seed=51)  # 37 % 8 != 0: shared partial block
        ref, _ = _serve_seq(cfg, params, COLD, [p, p])
        out, eng = _serve_seq(cfg, params, PREFIX, [p, p])
        assert out == ref
        st = eng.stats()
        assert st["prefix"]["hits"] == 1
        assert st["cow_copies"] > 0

    def test_partial_hit_resumes_chunked_prefill(self, qwen):
        """Shared 40-token prefix, distinct tails: the warm request attaches
        the shared blocks + the deepest stat point and resumes chunked
        prefill over its tail only — token-identical to cold."""
        cfg, params = qwen
        shared = _prompt(cfg, 40, seed=52)
        pa = shared + _prompt(cfg, 13, seed=53)
        pb = shared + _prompt(cfg, 13, seed=54)
        ref, _ = _serve_seq(cfg, params, COLD, [pa, pb])
        out, eng = _serve_seq(cfg, params, PREFIX, [pa, pb])
        assert out == ref
        st = eng.stats()
        assert st["prefix"]["hits"] == 1
        assert st["prefix"]["entries"] == 2  # deeper prompt re-cached too

    def test_dense_engine_ignores_prefix_flag(self, qwen):
        """No paged leaves -> the flag is inert, outputs match the plain
        dense engine, no prefix stats are surfaced."""
        cfg, params = qwen
        dense = dataclasses.replace(
            PREFIX, paged=False, chunked_prefill=True)
        p = _prompt(cfg, 24, seed=55)
        ref, _ = _serve_seq(
            cfg, params, dataclasses.replace(COLD, paged=False), [p, p])
        out, eng = _serve_seq(cfg, params, dense, [p, p])
        assert out == ref
        assert "prefix" not in eng.stats()

    @pytest.mark.parametrize("attach", ["reseg", "recompute"])
    def test_streaming_modes_warm_equals_cold(self, qwen, attach):
        """Both attach strategies, exact + frozen streaming: a warm full
        hit reproduces the cold run's greedy tokens."""
        cfg, params = qwen
        p = _prompt(cfg, 37, seed=56)
        for mode in ("exact", "frozen"):
            mcfg = dataclasses.replace(cfg, decode_streaming=mode)
            serve = dataclasses.replace(PREFIX, prefix_attach=attach)
            ref, _ = _serve_seq(mcfg, params, COLD, [p, p])
            out, eng = _serve_seq(mcfg, params, serve, [p, p])
            assert out == ref, f"warm != cold under {mode}/{attach}"
            assert eng.stats()["prefix"]["hits"] == 1

    def test_preempt_requeue_prefix_stays_cached(self, qwen):
        """Pool pressure preempts a lane mid-decode; the shared prefix
        entry is held by the other lanes' tables (not reclaimable), so the
        requeued request re-attaches it instead of re-prefilling — and all
        outputs match the dense reference."""
        cfg, params = qwen
        p = _prompt(cfg, 20, seed=57)
        reqs = [Request(u, list(p), max_new_tokens=30) for u in range(4)]
        dense = dataclasses.replace(
            BASE, paged=False, batched_prefill=False, max_lanes=3)
        eng_d = ServeEngine(cfg, params, serve=dense)
        for r in reqs:
            eng_d.submit(Request(r.uid, list(p), r.max_new_tokens))
        ref = eng_d.run()
        serve = dataclasses.replace(
            PREFIX, max_lanes=3, num_blocks=12)
        eng = ServeEngine(cfg, params, serve=serve)
        for r in reqs:
            eng.submit(Request(r.uid, list(p), r.max_new_tokens))
        out = eng.run()
        st = eng.stats()
        assert st["preemptions"] > 0, "pool should have forced preemption"
        assert st["finished"] == 4
        assert st["prefix"]["hits"] >= 1  # incl. the post-preempt re-attach
        assert out == ref

    def test_telemetry_counters_and_trace(self, qwen):
        """Flight recorder carries prefix_attach + cow lifeline events and
        the Perfetto export renders them on a structurally valid trace."""
        from repro.telemetry.export import chrome_trace, validate_trace

        cfg, params = qwen
        serve = dataclasses.replace(PREFIX, telemetry=True)
        p = _prompt(cfg, 37, seed=58)  # unaligned: exercises cow events too
        _, eng = _serve_seq(cfg, params, serve, [p, p])
        st = eng.stats()
        assert st["prefix"]["hits"] == 1 and st["prefix"]["misses"] == 1
        assert st["cow_copies"] > 0
        kinds = eng.telemetry.flight.lifeline(1).kinds()
        assert "prefix_attach" in kinds and "cow" in kinds
        assert "prefill_start" not in kinds  # full hit: no prefill at all
        trace = chrome_trace(eng.telemetry)
        assert validate_trace(trace) == []
        names = {e.get("name") for e in trace["traceEvents"]}
        assert "prefix_attach" in names and "cow" in names
        # attach is accounted as its own XLA program family
        assert "prefix_attach" in st.get("xla_compiles", {})

    def test_warm_flag_never_detaches_itl_chain(self):
        """``mark_prefix_hit``'s one-shot warm flag is consumed by a
        standalone discard: the ITL elif stays chained to the requeue /
        first-token branches, so a post-requeue resume token lands in
        resume_ttft only — never additionally in itl."""
        from repro.serve.scheduler import Scheduler

        alloc = BlockAllocator(17, 8)
        sched = Scheduler(alloc, max_lanes=1, blocks_per_lane=8)
        req = Request(0, list(range(10)), max_new_tokens=4)
        sched.requeue_cb = lambda lane: req
        sched.submit(req)
        assert sched.admit()
        sched.mark_prefix_hit(0)
        sched.note_token(0)  # warm first token
        assert sched._ttft_s.count == 1 and sched._warm_ttft_s.count == 1
        assert 0 not in sched._warm_uids  # one-shot: spent at first token
        sched.note_token(0)
        assert sched._itl_s.count == 1
        sched.preempt(0)
        assert sched.admit()
        sched.note_token(0)  # resume token: resume_ttft only
        assert sched._resume_ttft_s.count == 1
        assert sched._itl_s.count == 1  # requeue gap never counted as ITL
        sched.note_token(0)  # steady cadence resumes
        assert sched._itl_s.count == 2
        assert sched._warm_ttft_s.count == 1
