"""Shared fixtures. Tests run on the single CPU device (dry-runs force 512
host devices in their own process only); multi-device tests spawn
subprocesses with XLA_FLAGS set — see ``run_subprocess``."""
from __future__ import annotations

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

# Deterministic, fail-fast numerics for the whole suite.
jax.config.update("jax_default_matmul_precision", "highest")

# Initialize the backend NOW (1 CPU device) so later imports that set
# XLA_FLAGS (repro.launch.dryrun does, for its own subprocess use) cannot
# change this process's device count mid-suite.
_ = jax.devices()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_subprocess(script: str, num_devices: int = 8, timeout: int = 600) -> str:
    """Run ``script`` in a fresh python with ``num_devices`` fake host devices.

    Returns stdout; raises with stderr on failure. Used by the multi-device
    integration tests (pipeline parallelism, elastic restart, shard_map)
    that cannot run in the 1-device test process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
