"""Block-paged KV cache: vLLM-style fixed-size token blocks over the
spectral-shift decode state.

Two pieces:

* ``BlockAllocator`` — host-side bookkeeping: a free list of fixed-size
  token blocks, per-request block tables, alloc/free/defragment and
  utilization stats. Block 0 is reserved as the permanently-zero block that
  backs unallocated block-table slots, so gathers never need a validity
  mask (the decode path's causal key mask already ignores positions past
  ``pos``).

* ``PagedKVCache`` — maps the ``cache_specs`` ParamSpec tree onto
  block-shaped device storage. Leaves with a ``cache_seq`` axis (attention
  K/V, MLA latents) live in shared block pools: the ``cache_seq`` axis of
  each spec is replaced *in place* by a ``(num_blocks, block_size)`` pair,
  so a stacked-layer leaf ``(L, B, H, S, D)`` pools as
  ``(L, B, H, num_blocks, block_size, D)`` — the layer axis stays leading
  and the tree remains ``lax.scan``-compatible without any per-tick
  transpose. Everything else (landmark running sums, streaming B-side
  stats, SSM states, ``pos``) is small and fixed-size, so it stays dense
  per lane exactly like the seed engine. ``write_prefill`` installs a
  batched prefill's result; ``gather_views`` assembles the lane-stacked
  dense tree for inspection/tests.

The memory win is at the pool: ``num_blocks`` is sized to the expected
working set, not ``max_lanes * max_seq``. Two decode-tick programs exist:

* ``make_fused_step`` — the legacy *gather* route: assemble transient
  dense per-lane views (O(S) HBM traffic per tick), run the batched decode
  step, scatter the touched block back. Kept as the ``recompute``-mode
  baseline; the frozen-mode boundary rebase (``make_rebase_step``) also
  reads through this gather.
* ``make_paged_step`` — the *gather-free* route
  (``ServeConfig.decode_impl="paged"``): the decode step reads K/V
  directly from the shared pools through the block-table-aware Pallas
  kernel (``kernels/paged_decode.py`` — the lane's block table rides into
  the kernel as a scalar-prefetch SMEM operand and selects pool blocks in
  the index map, so no dense view ever exists), and the new token's K/V
  commits via a single-block scatter. A ``decode_streaming="frozen"``
  tick therefore touches only the written block plus the dense stats
  leaves: O(c*d) + one block per token, independent of the horizon.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models.params import ParamSpec
from repro.serve.kv_cache import cache_leaf_layout

ZERO_BLOCK = 0  # reserved all-zero block id backing unallocated table slots


def bucket_view_slots(need: int, cap: int, quantum: int = 0) -> int:
    """Round a required block-table slot count up to a compile bucket:
    next power of two by default, or the next multiple of ``quantum``
    (a measured ``Plan.block_table``), capped at ``cap``. One compiled
    tick program exists per distinct result — shared by the engine's
    ``view_blocks_needed`` and the decode autotune harness so the sweep
    times exactly the grid shapes the engine runs."""
    if quantum > 0:
        return min(-(-need // quantum) * quantum, cap)
    nb = 1
    while nb < need:
        nb *= 2
    return min(nb, cap)


# ==========================================================================
# Host-side block bookkeeping
# ==========================================================================
class BlockAllocator:
    """Free-list allocator of fixed-size token blocks with per-request
    block tables. Pure host-side bookkeeping; device storage is owned by
    ``PagedKVCache``."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least one allocatable block past block 0")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list (recently freed blocks are reused first — they are
        # the ones most likely still resident in cache). Block 0 excluded.
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self.tables: dict[int, list[int]] = {}  # request uid -> block ids
        # Reference count per non-free block: one per block table holding
        # it plus one per PrefixCache entry retaining it. Invariant: every
        # id in 1..num_blocks-1 is either on the free list (absent here) or
        # present with count >= 1 — a block re-enters the free list only
        # when its count drops to zero, never while still referenced.
        self.refcounts: dict[int, int] = {}
        # Optional PrefixCache hook: when an allocation comes up short, LRU
        # cached prefixes whose blocks are otherwise unreferenced are
        # evicted to make room before the allocation fails.
        self.prefix_cache: Optional["PrefixCache"] = None
        # Optional ChaosInjector (serve/chaos.py): "alloc_fail" makes
        # _take_free report a shortfall even when blocks are free.
        self.chaos = None

    # -- queries ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_alloc(self, n_blocks: int) -> bool:
        avail = self.num_free
        if self.prefix_cache is not None:
            avail += self.prefix_cache.evictable_blocks()
        return n_blocks <= avail

    def refcount(self, block: int) -> int:
        return self.refcounts.get(block, 0)

    def fragmentation(self) -> float:
        """Free-list fragmentation in [0, 1]: 1 minus the longest
        contiguous run of free block ids over the free count. 0 when the
        free space is one contiguous range (or empty) — the regime where
        ``defragment()`` has nothing to do."""
        if not self._free:
            return 0.0
        ids = sorted(self._free)
        best = run = 1
        for a, b in zip(ids, ids[1:]):
            run = run + 1 if b == a + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(ids)

    def stats(self) -> dict:
        usable = self.num_blocks - 1
        return {
            "num_blocks": usable,
            "blocks_used": self.num_used,
            "blocks_free": self.num_free,
            "blocks_shared": sum(
                1 for rc in self.refcounts.values() if rc > 1
            ),
            "utilization": self.num_used / max(usable, 1),
            "fragmentation": self.fragmentation(),
            "requests": len(self.tables),
        }

    # -- mutation -----------------------------------------------------------
    def _take_free(self, n_blocks: int) -> Optional[list[int]]:
        """Pop ``n_blocks`` off the free list at refcount 1, LRU-evicting
        reclaimable prefix-cache entries to cover a shortfall. Returns None
        (no state change beyond evictions) if still short."""
        if self.chaos is not None and self.chaos.fire("alloc_fail"):
            return None
        while n_blocks > self.num_free:
            if self.prefix_cache is None or not self.prefix_cache.evict_one(
                reclaim_only=True
            ):
                return None
        got = [self._free.pop() for _ in range(n_blocks)]
        for b in got:
            self.refcounts[b] = 1
        return got

    def alloc(self, uid: int, n_blocks: int) -> Optional[list[int]]:
        """Append ``n_blocks`` fresh blocks to ``uid``'s table. Returns the
        new block ids, or None (no state change) if the pool is short."""
        got = self._take_free(n_blocks)
        if got is None:
            return None
        self.tables.setdefault(uid, []).extend(got)
        return got

    def free(self, uid: int) -> list[int]:
        """Drop ``uid``'s reference on every block in its table. Blocks
        whose refcount hits zero go back to the free list; blocks still
        retained elsewhere (a cached prefix, another table) stay resident.
        Returns the ids actually freed."""
        blocks = self.tables.pop(uid, [])
        freed = []
        for b in reversed(blocks):
            rc = self.refcounts[b] - 1
            if rc:
                self.refcounts[b] = rc
            else:
                del self.refcounts[b]
                self._free.append(b)
                freed.append(b)
        return freed

    def take_ref(self, block: int) -> None:
        """Add a reference to an already-resident block (PrefixCache
        retention, shared-prefix attach). Never valid on a free block."""
        if block not in self.refcounts:
            raise ValueError(f"take_ref on free block {block}")
        self.refcounts[block] += 1

    def release_ref(self, block: int) -> bool:
        """Drop one reference; returns True if the block was freed."""
        rc = self.refcounts[block] - 1
        if rc:
            self.refcounts[block] = rc
            return False
        del self.refcounts[block]
        self._free.append(block)
        return True

    def attach_shared(self, uid: int, blocks: list[int]) -> None:
        """Map already-resident blocks (a matched cached prefix) into the
        FRONT of ``uid``'s table, taking a reference on each — the prefix
        occupies table positions 0..len(blocks)-1 and is released through
        the normal ``free(uid)`` path. The blocks are charged against the
        budget exactly once pool-wide: admission only allocates the tail."""
        for b in blocks:
            self.take_ref(b)
        self.tables.setdefault(uid, [])[:0] = list(blocks)

    def cow(self, uid: int, slot: int) -> Optional[tuple[int, int]]:
        """Copy-on-write: break the sharing of ``uid``'s table ``slot``.
        Allocates a fresh block, points the table at it and drops one
        reference on the shared original (which stays resident for its
        other holders). Returns ``(old, new)`` so the caller can copy the
        device rows (``PagedKVCache.copy_block``), or None if the pool is
        short (caller falls back to its reclaim/preempt loop)."""
        old = self.tables[uid][slot]
        got = self._take_free(1)
        if got is None:
            return None
        new = got[0]
        self.tables[uid][slot] = new
        self.refcounts[old] -= 1  # > 1 before the call, so never frees
        return old, new

    def scramble_free(self, key: int) -> None:
        """Deterministically shuffle the free list (chaos "fragment" site):
        destroys the LIFO locality so subsequent allocations land on
        scattered block ids — the regime ``defragment()`` exists for.
        Pure reordering; allocator accounting is untouched."""
        rng = np.random.default_rng(key if key >= 0 else -key)
        perm = rng.permutation(len(self._free))
        self._free = [self._free[i] for i in perm]

    def defragment(self) -> dict[int, int]:
        """Compact movable live blocks onto the lowest ids. Blocks with
        refcount > 1 (shared between tables and/or a cached prefix) are
        PINNED in place — moving one would have to rewrite every holder's
        view mid-flight, so the compactor refuses and packs around them.
        Returns the {old: new} mapping (identity entries omitted); the
        caller must permute device storage with the same mapping
        (``PagedKVCache.apply_mapping``). Singly-referenced prefix-cache
        blocks DO move; their index entries are remapped here."""
        pinned = {b for b, rc in self.refcounts.items() if rc > 1}
        movable = sorted(b for b, rc in self.refcounts.items() if rc == 1)
        targets, cand = [], 1
        while len(targets) < len(movable):
            if cand not in pinned:
                targets.append(cand)
            cand += 1
        mapping = {
            old: new for old, new in zip(movable, targets) if old != new
        }
        if mapping:
            for blocks in self.tables.values():
                blocks[:] = [mapping.get(b, b) for b in blocks]
            self.refcounts = {
                mapping.get(b, b): rc for b, rc in self.refcounts.items()
            }
            if self.prefix_cache is not None:
                self.prefix_cache.remap(mapping)
            occupied = set(self.refcounts)
            self._free = [
                b for b in range(self.num_blocks - 1, 0, -1)
                if b not in occupied
            ]
        return mapping


# ==========================================================================
# Content-hash prefix index
# ==========================================================================
@dataclasses.dataclass
class PrefixEntry:
    """One cached prompt. ``blocks`` are the physical pool blocks covering
    ``n_tokens`` (``ceil(n_tokens / block_size)`` of them — the last one may
    be partial, shared via copy-on-write). ``stat_points`` maps block-aligned
    token boundaries to ``PagedKVCache.dense_snapshot`` host copies of the
    lane-dense landmark/streaming state captured at that boundary under the
    canonical (engine-horizon) segmentation; ``logits`` is the next-token
    logits row after the full prompt, enabling a zero-compute full hit."""

    blocks: list[int]
    n_tokens: int
    tail: list[int]             # prompt tokens past the last full block
    hashes: list[bytes]         # chained digest after each full block
    stat_points: dict[int, list]
    logits: Optional[np.ndarray]
    last_used: int = 0
    pins: int = 0  # in-flight admissions between probe and attach


class PrefixCache:
    """Content-hash index of cached prompt prefixes over the block pool.

    Hash scheme — chained, block-granular: digest ``i`` is
    ``sha1(digest[i-1] || int32-LE tokens of block i)`` with
    ``digest[-1] = b""``. Chaining makes digest ``i`` a fingerprint of
    tokens ``[0, (i+1)*block_size)``, so matching a prompt is one dict
    lookup per block boundary, longest first — no trie needed. Only full
    blocks are hashed; a ragged prompt tail is compared verbatim (an
    exact-full-prompt hit additionally shares the partial last block, which
    divergent decode writes then copy-on-write).

    The index holds one key per block boundary of each entry, first-wins on
    collision (an existing key's backing blocks stay authoritative; a later
    identical prefix simply isn't re-cached). Entries may OVERLAP: a
    partial-hit completion inserts a longer entry whose leading blocks are
    an earlier entry's — each entry takes its own allocator reference per
    block, tracked here in ``_cache_refs`` so eviction can tell cache-held
    references apart from live block tables. Eviction is LRU by last use;
    ``reclaim_only`` eviction considers entries no live table references
    (allocator refcount fully accounted for by cache entries), evicting
    overlapping chains in cascade — any single eviction may free nothing
    (its blocks still held by a longer entry), but each removes an entry,
    so the allocator's shortfall loop keeps making progress until the
    chain's blocks actually reach the free list. Entry blocks carry one
    allocator reference per holding entry, so a shared prefix never
    re-enters the free list while a live request still maps it — the
    allocator invariant the defragmenter and ``reclaim_parked`` rely
    on."""

    def __init__(self, allocator: BlockAllocator, max_blocks: int = 0,
                 registry=None):
        from repro.telemetry.metrics import MetricsRegistry, TICK_BUCKETS

        self.allocator = allocator
        self.block_size = allocator.block_size
        self.max_blocks = max_blocks
        self._index: dict[bytes, tuple[PrefixEntry, int]] = {}
        self._entries: list[PrefixEntry] = []
        # block id -> number of cache entries holding a reference on it
        # (overlapping entries share blocks; see the class docstring)
        self._cache_refs: dict[int, int] = {}
        self._clock = 0
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._hits = r.counter(
            "prefix_cache_hits_total",
            help="admissions attached to a cached prefix")
        self._misses = r.counter(
            "prefix_cache_misses_total",
            help="admissions that found no usable cached prefix")
        self._evictions = r.counter(
            "prefix_cache_evictions_total",
            help="cached prefixes dropped (LRU cap or pool pressure)")
        self._hit_blocks = r.histogram(
            "prefix_hit_blocks", help="shared blocks mapped per cache hit",
            buckets=TICK_BUCKETS)
        # Optional ChaosInjector: "hash_collision" perturbs lookup digests
        # so a warm prompt cold-misses (see match()).
        self.chaos = None
        allocator.prefix_cache = self

    # -- hashing -------------------------------------------------------------
    @staticmethod
    def block_hashes(prompt, block_size: int) -> list[bytes]:
        """Chained digest after each FULL block of ``prompt``."""
        out: list[bytes] = []
        d = b""
        for i in range(len(prompt) // block_size):
            blk = np.asarray(
                prompt[i * block_size:(i + 1) * block_size], np.int32
            ).tobytes()
            d = hashlib.sha1(d + blk).digest()
            out.append(d)
        return out

    # -- lookup --------------------------------------------------------------
    def match(self, prompt) -> Optional[tuple[PrefixEntry, int]]:
        """Longest cached prefix of ``prompt``: ``(entry, k)`` with ``k``
        matched full blocks, or None. Pure lookup — the caller decides
        whether the match is usable and accounts hit/miss accordingly."""
        hashes = self.block_hashes(prompt, self.block_size)
        if self.chaos is not None and self.chaos.fire("hash_collision"):
            # An injected "collision" perturbs the lookup digests so the
            # probe cold-misses. (Delivering WRONG blocks — a true
            # collision — would be undetectable by construction; the
            # injectable failure mode is the conservative one: lost reuse,
            # never lost correctness.)
            hashes = [hashlib.sha1(b"chaos" + d).digest() for d in hashes]
        for i in range(len(hashes) - 1, -1, -1):
            got = self._index.get(hashes[i])
            if got is not None and got[1] >= i + 1:
                return got[0], i + 1
        return None

    def is_full_hit(self, entry: PrefixEntry, prompt, k: int) -> bool:
        """True when ``(entry, k)`` covers ``prompt`` exactly: every full
        block matched, the ragged tails agree verbatim, and the entry
        carries the post-prompt logits row for the zero-compute emit."""
        bs = self.block_size
        return (
            k == len(prompt) // bs
            and entry.n_tokens == len(prompt)
            and entry.tail == list(prompt[k * bs:])
            and entry.logits is not None
        )

    def note_hit(self, entry: PrefixEntry, n_blocks: int) -> None:
        self._clock += 1
        entry.last_used = self._clock
        self._hits.inc()
        self._hit_blocks.observe(n_blocks)

    def note_miss(self) -> None:
        self._misses.inc()

    def pin(self, entry: PrefixEntry) -> None:
        """Soft-pin an entry across an admission window (probe -> attach),
        bumping its LRU stamp: pinned entries are the LAST reclaim
        candidates rather than excluded outright — a hard pin could
        deadlock admission when the pinned entry's own blocks are the only
        reclaimable room left, whereas evicting it merely downgrades the
        accounted hit to a cold miss (which the attach path re-detects)."""
        self.touch(entry)
        entry.pins += 1

    def unpin(self, entry: PrefixEntry) -> None:
        entry.pins = max(entry.pins - 1, 0)

    def touch(self, entry: PrefixEntry) -> None:
        """LRU-bump without pinning (re-probe of an already-pinned entry)."""
        self._clock += 1
        entry.last_used = self._clock

    # -- insertion / eviction -------------------------------------------------
    def insert(self, prompt, blocks, stat_points=None,
               logits=None) -> Optional[PrefixEntry]:
        """Cache a finished prefill: take a reference on the blocks covering
        the prompt and register the boundary digests. Returns the entry, or
        None when nothing was cached (sub-block prompt, or every boundary
        already indexed by an earlier entry — first wins)."""
        bs = self.block_size
        hashes = self.block_hashes(prompt, bs)
        if not hashes:
            return None
        nb = -(-len(prompt) // bs)
        blocks = list(blocks[:nb])
        if len(blocks) < nb:
            return None
        self._clock += 1
        entry = PrefixEntry(
            blocks=blocks, n_tokens=len(prompt),
            tail=list(prompt[len(hashes) * bs:]), hashes=hashes,
            stat_points=dict(stat_points or {}),
            logits=None if logits is None else np.asarray(logits),
            last_used=self._clock,
        )
        registered = False
        for i, d in enumerate(hashes):
            if d not in self._index:
                self._index[d] = (entry, i + 1)
                registered = True
        if not registered:
            return None
        for b in blocks:
            self.allocator.take_ref(b)
            self._cache_refs[b] = self._cache_refs.get(b, 0) + 1
        self._entries.append(entry)
        while (
            self.max_blocks > 0 and self.block_count() > self.max_blocks
            and self.evict_one()
        ):
            pass
        return entry

    def _reclaimable(self, entry: PrefixEntry) -> bool:
        """No live block table references any of the entry's blocks: the
        allocator refcount is fully accounted for by cache entries. Such
        entries are safe eviction fodder even when overlapping entries
        keep some blocks resident — the sweep cascades down the chain."""
        return all(
            self.allocator.refcount(b) == self._cache_refs.get(b, 0)
            for b in entry.blocks
        )

    def evictable_blocks(self) -> int:
        """Distinct blocks a full reclaim-only eviction sweep would return
        to the free list right now: blocks of cache-only entries, minus
        any also held by an entry some live table still references (those
        survive the sweep). Exact — ``can_alloc`` promises on it."""
        freeable: set[int] = set()
        held: set[int] = set()
        for e in self._entries:
            (freeable if self._reclaimable(e) else held).update(e.blocks)
        return len(freeable - held)

    def evict_one(self, reclaim_only: bool = False) -> bool:
        """Drop the LRU entry. ``reclaim_only`` restricts candidates to
        entries no live table references (allocator shortfall path):
        evicting those in LRU order cascades overlapping prefix chains —
        one eviction may free nothing (its blocks still held by a longer
        entry), but each removes an entry, so the shortfall loop either
        reaches the free list or runs out of candidates. Soft-pinned
        entries (an admission in flight between probe and attach) are
        taken only when no unpinned candidate remains."""
        cands = [
            e for e in self._entries
            if not reclaim_only or self._reclaimable(e)
        ]
        if not cands:
            return False
        unpinned = [e for e in cands if not e.pins]
        victim = min(unpinned or cands, key=lambda e: e.last_used)
        for d in victim.hashes:
            got = self._index.get(d)
            if got is not None and got[0] is victim:
                del self._index[d]
        self._entries.remove(victim)
        for b in victim.blocks:
            rc = self._cache_refs[b] - 1
            if rc:
                self._cache_refs[b] = rc
            else:
                del self._cache_refs[b]
            self.allocator.release_ref(b)
        self._evictions.inc()
        return True

    def remap(self, mapping: dict[int, int]) -> None:
        """Follow a defragmentation: entry block ids move with the pool.
        (Digests are content-addressed and don't change.)"""
        for e in self._entries:
            e.blocks = [mapping.get(b, b) for b in e.blocks]
        self._cache_refs = {
            mapping.get(b, b): rc for b, rc in self._cache_refs.items()
        }

    def block_count(self) -> int:
        return sum(len(e.blocks) for e in self._entries)

    def stats(self) -> dict:
        return {
            "entries": len(self._entries),
            "blocks": self.block_count(),
            "index_keys": len(self._index),
            "hits": int(self._hits.value),
            "misses": int(self._misses.value),
            "evictions": int(self._evictions.value),
        }


# ==========================================================================
# Device-side block-pool storage
# ==========================================================================
@dataclasses.dataclass
class _LeafInfo:
    spec: ParamSpec
    seq_axis: Optional[int]  # index of the cache_seq axis, None = dense leaf


def _leaf_infos(cfg: ModelConfig, max_seq: int) -> tuple[list[_LeafInfo], Any]:
    leaves, treedef = cache_leaf_layout(cfg, max_seq)
    return [_LeafInfo(spec, j) for spec, j in leaves], treedef


class PagedKVCache:
    """Block-pool device storage for one engine's decode state.

    With ``paged=False`` every leaf (including K/V) is stored lane-dense —
    bitwise the seed engine's layout — which is the comparison baseline for
    the paged path and the fallback when a model has no sequence-shaped
    cache at all (pure SSM stacks)."""

    def __init__(self, cfg: ModelConfig, serve: ServeConfig):
        self.cfg, self.serve = cfg, serve
        self.block_size = serve.block_size
        self.max_lanes, self.max_seq = serve.max_lanes, serve.max_seq
        self.num_blocks = serve.resolved_num_blocks
        self.infos, self.treedef = _leaf_infos(cfg, serve.max_seq)
        self.paged = serve.paged and any(
            i.seq_axis is not None for i in self.infos
        )
        self._storage: list[jnp.ndarray] = []
        for info in self.infos:
            dt = info.spec.dtype or jnp.float32
            if self.paged and info.seq_axis is not None:
                # Pool layout: the cache_seq axis splits IN PLACE into
                # (num_blocks, block_size), so leading layer/batch axes stay
                # leading (lax.scan over layers keeps working on pools).
                j = info.seq_axis
                shape = info.spec.shape
                self._storage.append(jnp.zeros(
                    (*shape[:j], self.num_blocks, self.block_size,
                     *shape[j + 1:]), dt,
                ))
            else:
                self._storage.append(
                    jnp.zeros((self.max_lanes, *info.spec.shape), dt)
                )

    @property
    def has_paged_leaves(self) -> bool:
        return self.paged

    def pool_tokens(self) -> int:
        """Capacity of the shared pool, in tokens (0 when not paged)."""
        return (self.num_blocks - 1) * self.block_size if self.paged else 0

    # -- assemble the dense view decode_step expects -------------------------
    def _gather_leaf(self, arr, info: _LeafInfo, tables) -> jnp.ndarray:
        """Pool (..., num_blocks, bs, ...) + tables (rows, nb) ->
        row-stacked view (rows, ..., nb*bs, ...). ``rows`` is usually
        ``max_lanes`` (decode tick) but can be 1 (a single lane's view for
        a chunked-prefill step)."""
        j = info.seq_axis
        shape = info.spec.shape
        # take with 2D indices at the block axis: (..., rows, nb, bs, ...)
        g = jnp.take(arr, tables, axis=j)
        g = jnp.moveaxis(g, j, 0)          # rows leading
        view_len = tables.shape[1] * self.block_size
        return g.reshape(tables.shape[0], *shape[:j], view_len,
                         *shape[j + 1:])

    def gather_views(self, tables: np.ndarray) -> Any:
        """tables (max_lanes, blocks_per_lane) int32, ZERO_BLOCK where
        unallocated. Returns the lane-stacked dense cache tree: every leaf
        (max_lanes, *spec.shape)."""
        tb = jnp.asarray(tables, jnp.int32)
        leaves = [
            arr if (not self.paged or info.seq_axis is None)
            else self._gather_leaf(arr, info, tb)
            for arr, info in zip(self._storage, self.infos)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- write paths ----------------------------------------------------------
    def write_prefill(
        self, lane: int, prefill_tree: Any, table_row: np.ndarray,
        n_tokens: int,
    ) -> None:
        """Install a batched-prefill result (a B=1 cache tree whose seq
        leaves are padded-prompt long, a block multiple) into ``lane``:
        the first ``ceil(n_tokens / block_size)`` blocks of each seq leaf go
        to the lane's allocated blocks (positions past ``n_tokens`` are
        zero-masked, matching what unallocated slots read as), dense leaves
        overwrite the lane's dense slots."""
        new_leaves = jax.tree_util.tree_leaves(prefill_tree)
        bs = self.block_size
        nb = -(-n_tokens // bs)
        for idx, info in enumerate(self.infos):
            j = info.seq_axis
            leaf = new_leaves[idx]
            if not self.paged or j is None:
                if j is not None and leaf.shape[j] != self.max_seq:
                    pad = [(0, 0)] * leaf.ndim
                    pad[j] = (0, self.max_seq - leaf.shape[j])
                    leaf = jnp.pad(leaf, pad)
                self._storage[idx] = self._storage[idx].at[lane].set(leaf)
                continue
            if leaf.shape[j] % bs:  # ss_fused runs unpadded prompt lengths
                pad = [(0, 0)] * leaf.ndim
                pad[j] = (0, -leaf.shape[j] % bs)
                leaf = jnp.pad(leaf, pad)
            shape = leaf.shape
            n_blocks_pad = shape[j] // bs
            split = leaf.reshape(
                *shape[:j], n_blocks_pad, bs, *shape[j + 1:]
            )
            pre = (slice(None),) * j
            ids = jnp.asarray(table_row[:nb], jnp.int32)
            self._storage[idx] = self._storage[idx].at[(*pre, ids)].set(
                split[(*pre, slice(0, nb))]
            )

    def make_fused_step(self, vmapped_decode_step):
        """One jitted XLA program for the whole decode tick:
        gather lane views from the pool -> batched decode step -> commit
        (dense leaves masked to active lanes; the touched K/V block of each
        active lane scattered back). Pool buffers are donated, so block
        writes update in place instead of copying the pool every tick.

        Views are gathered only ``n_view_blocks`` long — the engine passes
        the (bucketed) block count of the longest active sequence, so short
        working sets pay short gathers and short attention reads; the
        decode step's ``seq_max`` keeps landmark segmentation pinned to the
        full horizon regardless of view length.

        Returns ``fn(storage, tables, tokens, positions, active,
        n_view_blocks) -> (logits, new_storage)``; one XLA program compiles
        per distinct ``n_view_blocks``; the engine swaps its storage list
        for the returned one."""
        infos, treedef = self.infos, self.treedef
        paged, bs = self.paged, self.block_size
        n_lanes = self.max_lanes

        def fused(storage, tables, tokens, positions, active):
            views = [
                arr if (not paged or info.seq_axis is None)
                else self._gather_leaf(arr, info, tables)
                for arr, info in zip(storage, infos)
            ]
            cache = jax.tree_util.tree_unflatten(treedef, views)
            logits, new_cache = vmapped_decode_step(cache, tokens)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for arr, new, info in zip(storage, new_leaves, infos):
                if not paged or info.seq_axis is None:
                    mask = active.reshape((n_lanes,) + (1,) * (arr.ndim - 1))
                    out.append(jnp.where(mask, new.astype(arr.dtype), arr))
                    continue
                j = info.seq_axis

                def ext(per_lane, p, j=j):
                    return jax.lax.dynamic_slice_in_dim(
                        per_lane, (p // bs) * bs, bs, axis=j
                    )

                blocks = jax.vmap(ext)(new, positions)
                ids = tables[jnp.arange(n_lanes), positions // bs]
                # inactive lanes dump into the zero block, re-zeroed below
                ids = jnp.where(active, ids, ZERO_BLOCK)
                pre = (slice(None),) * j
                pool = arr.at[(*pre, ids)].set(
                    jnp.moveaxis(blocks, 0, j).astype(arr.dtype)
                )
                pool = pool.at[(*pre, ZERO_BLOCK)].set(
                    jnp.zeros_like(pool[(*pre, ZERO_BLOCK)])
                )
                out.append(pool)
            return logits, out

        jitted = jax.jit(fused, donate_argnums=(0,))

        def call(storage, tables, tokens, positions, active, n_view_blocks):
            if self.paged:
                tables = tables[:, :n_view_blocks]
            return jitted(storage, tables, tokens, positions, active)

        call._jitted = jitted  # jit-cache probe for telemetry/accounting.py
        return call

    def make_paged_step(self, decode_step_fn):
        """One jitted XLA program for the *gather-free* decode tick
        (``ServeConfig.decode_impl="paged"``): pool leaves are broadcast
        unbatched through the lane vmap, the per-lane block table rides
        along as a traced operand (reaching the Pallas decode kernel in
        ``kernels/paged_decode.py`` as a scalar-prefetch SMEM input that
        selects pool blocks in the index map), and every seq-shaped cache
        leaf comes back from the step as the lane's NEW TOKEN only —
        committed here with a single-block scatter. No dense view of the
        horizon is ever materialized: a ``decode_streaming="frozen"`` tick
        touches the dense stats leaves plus exactly one pool block per
        lane.

        ``decode_step_fn(cache, tokens, table) -> (logits, new_cache)``
        must be the paged-mode decode step (``serve/decode.py`` with
        ``paged_meta`` set): it never writes pool leaves and returns seq
        leaves with a length-1 seq axis holding the new token.

        Returns ``fn(storage, tables, tokens, positions, active,
        n_view_blocks) -> (logits, new_storage)``; like ``make_fused_step``
        one XLA program compiles per distinct (bucketed) ``n_view_blocks``
        and pool buffers are donated, so block writes update in place."""
        if not self.paged:
            raise ValueError(
                "make_paged_step needs paged seq leaves; use make_fused_step"
            )
        infos, treedef = self.infos, self.treedef
        bs = self.block_size
        n_lanes = self.max_lanes

        cache_axes = jax.tree_util.tree_unflatten(
            treedef, [None if i.seq_axis is not None else 0 for i in infos]
        )
        vstep = jax.vmap(decode_step_fn, in_axes=(cache_axes, 0, 0))

        def fused(storage, tables, tokens, positions, active):
            cache = jax.tree_util.tree_unflatten(treedef, storage)
            logits, new_cache = vstep(cache, tokens, tables)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            ids = tables[jnp.arange(n_lanes), positions // bs]
            # inactive lanes dump into the zero block, re-zeroed below
            ids = jnp.where(active, ids, ZERO_BLOCK)
            offs = positions % bs
            out = []
            for arr, new, info in zip(storage, new_leaves, infos):
                j = info.seq_axis
                if j is None:
                    mask = active.reshape((n_lanes,) + (1,) * (arr.ndim - 1))
                    out.append(jnp.where(mask, new.astype(arr.dtype), arr))
                    continue
                # new (lanes, *shape[:j], 1, *shape[j+1:]): the new token.
                # Adjacent advanced indices (ids, offs) land at the pool's
                # (block, in-block) axes, so the scatter touches one token
                # row per leaf per lane.
                pre = (slice(None),) * j
                vals = jnp.moveaxis(jnp.squeeze(new, axis=1 + j), 0, j)
                pool = arr.at[(*pre, ids, offs)].set(vals.astype(arr.dtype))
                pool = pool.at[(*pre, ZERO_BLOCK)].set(
                    jnp.zeros_like(pool[(*pre, ZERO_BLOCK)])
                )
                out.append(pool)
            return logits, out

        jitted = jax.jit(fused, donate_argnums=(0,))

        def call(storage, tables, tokens, positions, active, n_view_blocks):
            return jitted(storage, tables[:, :n_view_blocks], tokens,
                          positions, active)

        call._jitted = jitted  # jit-cache probe for telemetry/accounting.py
        return call

    def make_chunk_step(self, chunk_fn, chunk_pad: int):
        """One jitted XLA program for a chunked-prefill step of ONE lane:
        gather the lane's committed-prefix view from the pool (plus its
        carried dense landmark/streaming leaves) -> run ``chunk_fn`` (a
        ``make_chunk_prefill_fn`` closure: one fixed-size prompt chunk at
        global positions start..start+chunk_valid-1) -> commit the chunk's
        K/V into the lane's blocks and the carried-forward dense state into
        the lane's dense slots. Pool buffers are donated, so the commit
        updates in place — a chunk step touches ``chunk_pad / block_size``
        blocks plus the lane's dense leaves, independent of the horizon.

        ``chunk_pad`` must be a ``block_size`` multiple and chunk starts
        must be block-aligned (the engine rounds the chunk size up); the
        final ragged chunk rides with ``chunk_valid < chunk_pad`` and its
        partial block commits zero-masked, exactly like ``write_prefill``.

        Returns ``fn(storage, table_row, tokens, lane, start, chunk_valid)
        -> (logits, new_storage)`` with ``table_row`` the lane's block table
        sliced to the engine's bucketed view length (ignored when the cache
        is lane-dense), ``tokens`` (1, chunk_pad) int32 and ``lane`` /
        ``start`` / ``chunk_valid`` traced int32 scalars — one XLA program
        per distinct view bucket, not per chunk index. Next-token logits
        live at ``logits[0, chunk_valid - 1]``."""
        if chunk_pad % self.block_size:
            raise ValueError("chunk_pad must be a block_size multiple")
        infos, treedef = self.infos, self.treedef
        paged, bs = self.paged, self.block_size
        cb = chunk_pad // bs
        max_seq = self.max_seq

        def fused(storage, table_row, tokens, lane, start, chunk_valid):
            views = []
            for arr, info in zip(storage, infos):
                if paged and info.seq_axis is not None:
                    views.append(self._gather_leaf(arr, info, table_row)[0])
                else:
                    views.append(
                        jax.lax.dynamic_index_in_dim(arr, lane, 0, False)
                    )
            cache = jax.tree_util.tree_unflatten(treedef, views)
            logits, new_cache = chunk_fn(cache, tokens, start, chunk_valid)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for arr, new, view, info in zip(storage, new_leaves, views, infos):
                j = info.seq_axis
                if j is None:
                    out.append(jax.lax.dynamic_update_index_in_dim(
                        arr, new.astype(arr.dtype), lane, 0
                    ))
                    continue
                if not paged:
                    # Lane-dense seq leaf: merge the chunk into the lane's
                    # full row. A clamp-prone dynamic_update_slice would
                    # smear a tail chunk backwards over committed rows, so
                    # gather/where instead: row positions in
                    # [start, start + chunk_valid) take the chunk's rows.
                    idx = jnp.arange(max_seq)
                    gidx = jnp.clip(idx - start, 0, chunk_pad - 1)
                    moved = jnp.take(new, gidx, axis=j)
                    keep = (idx >= start) & (idx < start + chunk_valid)
                    keep = keep.reshape(
                        (1,) * j + (max_seq,) + (1,) * (new.ndim - j - 1)
                    )
                    merged = jnp.where(keep, moved, view).astype(arr.dtype)
                    out.append(jax.lax.dynamic_update_index_in_dim(
                        arr, merged, lane, 0
                    ))
                    continue
                # Pool leaf: the chunk's cb blocks scatter to the lane's
                # table slots start//bs .. start//bs + cb - 1. The wrapper
                # pads the sliced table row with cb ZERO_BLOCK columns, so
                # this dynamic_slice can never clamp backwards; slots past
                # the chunk's valid blocks are redirected to ZERO_BLOCK
                # (dumped, then re-zeroed) instead of clobbering pool data.
                shape = new.shape
                split = new.reshape(*shape[:j], cb, bs, *shape[j + 1:])
                ids = jax.lax.dynamic_slice(
                    table_row[0], (start // bs,), (cb,)
                )
                nvb = -(-chunk_valid // bs)  # traced ceil-div
                ids = jnp.where(jnp.arange(cb) < nvb, ids, ZERO_BLOCK)
                pre = (slice(None),) * j
                pool = arr.at[(*pre, ids)].set(split.astype(arr.dtype))
                pool = pool.at[(*pre, ZERO_BLOCK)].set(
                    jnp.zeros_like(pool[(*pre, ZERO_BLOCK)])
                )
                out.append(pool)
            return logits, out

        jitted = jax.jit(fused, donate_argnums=(0,))

        def call(storage, table_row, tokens, lane, start, chunk_valid):
            if paged:
                row = np.asarray(table_row, np.int32).reshape(1, -1)
                row = np.concatenate(
                    [row, np.full((1, cb), ZERO_BLOCK, np.int32)], axis=1
                )
            else:
                row = np.zeros((1, 1), np.int32)
            return jitted(
                storage, jnp.asarray(row), jnp.asarray(tokens, jnp.int32),
                jnp.asarray(lane, jnp.int32), jnp.asarray(start, jnp.int32),
                jnp.asarray(chunk_valid, jnp.int32),
            )

        call._jitted = jitted  # jit-cache probe for telemetry/accounting.py
        return call

    def dense_snapshot(self, lane: int) -> list[np.ndarray]:
        """Host copies of a lane's dense (non-pooled) leaves — the carried
        landmark/streaming prefill state of a lane being parked mid-chunked-
        prefill (its pool blocks stay allocated; only the dense carry needs
        saving because the lane's dense slots get reused)."""
        return [
            np.asarray(self._storage[idx][lane])
            for idx, info in enumerate(self.infos)
            if not (self.paged and info.seq_axis is not None)
        ]

    def dense_restore(self, lane: int, snap: list[np.ndarray]) -> None:
        """Reinstall a ``dense_snapshot`` into ``lane`` (resume a parked
        mid-prefill request at its completed-chunk boundary)."""
        it = iter(snap)
        for idx, info in enumerate(self.infos):
            if self.paged and info.seq_axis is not None:
                continue
            self._storage[idx] = self._storage[idx].at[lane].set(
                jnp.asarray(next(it))
            )

    def make_rebase_step(self, vmapped_rebase):
        """Jitted frozen-mode boundary rebase (serve/decode_state.py):
        gather lane views from the pool -> vmapped ``rebase_streaming`` ->
        commit the lane-dense streaming-stat leaves of flagged lanes. The
        paged K/V pool is read (the rebase recomputes two landmark rows over
        the horizon) but never written, so only dense leaves commit.

        Returns ``fn(storage, tables, positions, flags, n_view_blocks) ->
        new_storage``; like ``make_fused_step``, one XLA program compiles
        per distinct ``n_view_blocks`` and pool buffers are donated."""
        infos, treedef = self.infos, self.treedef
        paged = self.paged
        n_lanes = self.max_lanes

        def fused(storage, tables, positions, flags):
            views = [
                arr if (not paged or info.seq_axis is None)
                else self._gather_leaf(arr, info, tables)
                for arr, info in zip(storage, infos)
            ]
            cache = jax.tree_util.tree_unflatten(treedef, views)
            new_cache = vmapped_rebase(cache, positions)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for arr, new, info in zip(storage, new_leaves, infos):
                if not paged or info.seq_axis is None:
                    mask = flags.reshape((n_lanes,) + (1,) * (arr.ndim - 1))
                    out.append(jnp.where(mask, new.astype(arr.dtype), arr))
                else:
                    out.append(arr)
            return out

        jitted = jax.jit(fused, donate_argnums=(0,))

        def call(storage, tables, positions, flags, n_view_blocks):
            if self.paged:
                tables = tables[:, :n_view_blocks]
            return jitted(storage, tables, positions, flags)

        call._jitted = jitted  # jit-cache probe for telemetry/accounting.py
        return call

    def view_blocks_needed(self, positions, lanes, quantum: int = 0) -> int:
        """Bucketed block count covering the deepest active position — one
        compiled tick program per distinct result. ``quantum`` > 0 (a
        measured ``Plan.block_table``) rounds up to that multiple instead
        of the next power of two."""
        if not self.paged or not lanes:
            return self.max_seq // self.block_size
        need = max(int(positions[i]) // self.block_size + 1 for i in lanes)
        return bucket_view_slots(
            need, self.max_seq // self.block_size, quantum
        )

    def zero_lane_dense(self, lane: int) -> None:
        """Fresh-request reset of a lane's dense (non-paged) state."""
        for idx, info in enumerate(self.infos):
            if self.paged and info.seq_axis is not None:
                continue
            self._storage[idx] = self._storage[idx].at[lane].set(
                jnp.zeros_like(self._storage[idx][lane])
            )

    def copy_block(self, src: int, dst: int) -> None:
        """Copy one pool block's token rows in every pooled leaf — the
        device half of copy-on-write, run once when a shared block gets its
        first divergent write (``BlockAllocator.cow`` does the host half)."""
        if not self.paged:
            return
        for idx, info in enumerate(self.infos):
            j = info.seq_axis
            if j is None:
                continue
            arr = self._storage[idx]
            pre = (slice(None),) * j
            self._storage[idx] = arr.at[(*pre, dst)].set(arr[(*pre, src)])

    def apply_mapping(self, mapping: dict[int, int]) -> None:
        """Permute pool storage after ``BlockAllocator.defragment``."""
        if not mapping or not self.paged:
            return
        old = jnp.asarray(list(mapping.keys()), jnp.int32)
        new = jnp.asarray(list(mapping.values()), jnp.int32)
        for idx, info in enumerate(self.infos):
            if info.seq_axis is None:
                continue
            arr = self._storage[idx]
            pre = (slice(None),) * info.seq_axis
            self._storage[idx] = arr.at[(*pre, new)].set(arr[(*pre, old)])
