"""Attention implementations: exact softmax, Nystrom, and Spectral Shifting.

All functions take ``q`` (..., n_q, d), ``k``/``v`` (..., n_k, d) with
arbitrary shared leading batch/head dims and return (..., n_q, d_v).
Softmax always runs in fp32; outputs are cast back to the input dtype.

``spectral_shift_attention`` is the paper's contribution (eq. (10) plus the
``+ delta_ss I_n`` shifted-identity term, see DESIGN.md §2.2). With
``use_shift=False`` it reduces exactly to Nystromformer attention, which we
keep as the paper's main baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.landmarks import segment_means, segment_of
from repro.core.spectral_shift import ss_core


@dataclasses.dataclass(frozen=True)
class SSConfig:
    """Hyper-parameters of the spectral-shifting approximation."""

    num_landmarks: int = 64
    pinv_iters: int = 6
    method: str = "iterative"        # "iterative" (TPU) | "svd" (oracle)
    rank_tol: float = 1e-3
    use_shift: bool = True           # False => exact Nystromformer
    include_shift_identity: bool = True  # the + delta_ss * V output term
    variant: str = "closed_form"     # "closed_form" | "eq10_literal"
    causal: bool = False             # segment-causal masking (beyond-paper)
    landmark_via_matmul: bool = False  # GEMM segment-means (sharded-seq safe)
    delta_scale: str = "paper"       # "paper" | "corrected" (x c/n; see below)
    # "corrected" (beyond-paper): the paper fits delta on the c x c landmark
    # core A = L(Q~K~^T), whose row-softmax normalizes over c columns — its
    # entries (and hence its tail eigenvalues) sit at the 1/c scale, while
    # the n x n attention matrix being approximated normalizes over n
    # columns (1/n scale). Applying the core-fitted delta directly (the
    # paper's eq. 10) overestimates the shift by ~n/c; scaling by c/n puts
    # the shifted identity on the right spectral scale. Validated in
    # benchmarks/bench_accuracy.py (accuracy_output_corrected rows).


def _softmax(scores: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    out = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    if mask is not None:
        out = jnp.where(mask, out, 0.0)
    return out / jnp.maximum(jnp.sum(out, axis=-1, keepdims=True), 1e-30)


def full_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Exact O(n^2) softmax attention (paper §2.1)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    scores = jnp.einsum(
        "...qd,...kd->...qk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        n_q, n_k = q.shape[-2], k.shape[-2]
        # Queries are the last n_q positions of an n_k-long context.
        cmask = (
            jnp.arange(n_k)[None, :]
            <= (jnp.arange(n_q)[:, None] + (n_k - n_q))
        )
        mask = cmask if mask is None else jnp.logical_and(mask, cmask)
    attn = _softmax(scores, mask)
    return jnp.einsum("...qk,...kd->...qd", attn, v.astype(jnp.float32)).astype(q.dtype)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    block: int = 1024,
    scale: Optional[float] = None,
    unroll: bool = False,
) -> jnp.ndarray:
    """Exact softmax attention, computed blockwise over keys with the online
    softmax recurrence (flash-attention memory profile, pure jnp). This is
    the memory-feasible 'full attention' baseline for 32k+ sequences — the
    O(n^2) FLOPs remain; only the O(n^2) score matrix is never materialized.
    """
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    n_q, n_k = q.shape[-2], k.shape[-2]
    block = min(block, n_k)
    pad = -n_k % block
    if pad:
        widths = [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)]
        k, v = jnp.pad(k, widths), jnp.pad(v, widths)
    nb = (n_k + pad) // block
    q32 = q.astype(jnp.float32)
    qpos = jnp.arange(n_q) + (n_k - n_q)  # decode convention

    kb = jnp.moveaxis(k.reshape(*k.shape[:-2], nb, block, d), -3, 0)
    vb = jnp.moveaxis(v.reshape(*v.shape[:-2], nb, block, v.shape[-1]), -3, 0)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        i, kblk, vblk = xs
        s = jnp.einsum("...qd,...kd->...qk", q32, kblk.astype(jnp.float32)) * scale
        kpos = i * block + jnp.arange(block)
        mask = kpos[None, :] < n_k
        if causal:
            mask = jnp.logical_and(mask, kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "...qk,...kd->...qd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    lead = q.shape[:-2]
    m0 = jnp.full((*lead, n_q), -1e30, jnp.float32)
    l0 = jnp.zeros((*lead, n_q), jnp.float32)
    acc0 = jnp.zeros((*lead, n_q, v.shape[-1]), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nb), kb, vb),
        unroll=nb if unroll else 1,
    )
    return (acc / jnp.maximum(l_f, 1e-30)[..., None]).astype(q.dtype)


def _ss_factors(q, k, cfg: SSConfig, scale, q_landmarks=None, k_landmarks=None):
    """The three softmax factor matrices F (n_q,c), A (c,c), B (c,n_k)."""
    m = cfg.num_landmarks
    mm = cfg.landmark_via_matmul
    q_l = segment_means(q, m, via_matmul=mm) if q_landmarks is None else q_landmarks
    k_l = segment_means(k, m, via_matmul=mm) if k_landmarks is None else k_landmarks
    if q_l.shape[-2] != k_l.shape[-2]:
        raise ValueError(
            "spectral-shift attention needs matching landmark counts for Q~ "
            f"and K~, got {q_l.shape[-2]} vs {k_l.shape[-2]}. For decode "
            "(n_q=1) pass cached q_landmarks/k_landmarks explicitly."
        )
    f_mask = a_mask = b_mask = None
    if cfg.causal:
        n_q, n_k = q.shape[-2], k.shape[-2]
        c = k_l.shape[-2]
        qpos = jnp.arange(n_q) + (n_k - n_q)
        qseg = segment_of(qpos, n_k, m)[:, None]             # (n_q, 1)
        lseg = jnp.arange(c)[None, :]                        # (1, c)
        f_mask = lseg <= qseg                                # query i sees landmark seg <= its seg
        a_mask = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
        seg = -(-n_k // m)
        b_mask = jnp.arange(n_k)[None, :] < (jnp.arange(c)[:, None] + 1) * seg
    f = _softmax(jnp.einsum("...qd,...cd->...qc", q, k_l) * scale, f_mask)
    a = _softmax(jnp.einsum("...cd,...ed->...ce", q_l, k_l) * scale, a_mask)
    b = _softmax(jnp.einsum("...cd,...kd->...ck", q_l, k) * scale, b_mask)
    return f, a, b


def spectral_shift_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig = SSConfig(),
    *,
    scale: Optional[float] = None,
    q_landmarks: Optional[jnp.ndarray] = None,
    k_landmarks: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Linear-time attention via Modified Spectral Shifting (paper eq. (10)).

    out = F @ U_ss @ (B @ V) [+ delta_ss * V]   with U_ss = Z*(I - delta Z*).

    Cost: O(n c d + n c^2 + c^3) — linear in n (paper §8).

    ``q_landmarks``/``k_landmarks`` override segment-mean landmark selection;
    serving passes the incrementally-maintained landmark state here so a
    single decode query still has a full (c x c) core.
    """
    if (
        q.shape[-2] <= cfg.num_landmarks
        and k.shape[-2] <= cfg.num_landmarks
        and q_landmarks is None
    ):
        return full_attention(q, k, v, causal=cfg.causal, scale=scale)
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    f, a, b = _ss_factors(q, k, cfg, scale, q_landmarks, k_landmarks)
    core = ss_core(
        a,
        method=cfg.method,
        pinv_iters=cfg.pinv_iters,
        rank_tol=cfg.rank_tol,
        use_shift=cfg.use_shift,
    )
    if cfg.delta_scale == "corrected" and cfg.use_shift:
        # Beyond-paper: rescale the core-fitted shift to the n x n softmax
        # scale (core rows normalize over c entries, full rows over n).
        c_count = a.shape[-1]
        core = core._replace(
            delta=core.delta * (c_count / k.shape[-2]),
            u=jnp.matmul(
                core.z,
                jnp.eye(c_count, dtype=core.z.dtype)
                - (core.delta * (c_count / k.shape[-2])) * core.z,
            ),
        )
    if cfg.variant == "eq10_literal":
        # Literal paper eq. (10): U = A^+ (I - delta A)  [typo'd form, kept
        # for faithfulness comparison — see DESIGN.md §2.1].
        c = a.shape[-1]
        u = jnp.matmul(core.z, jnp.eye(c, dtype=a.dtype) - core.delta * a)
    else:
        u = core.u
    if cfg.causal:
        # The causally-masked core A is lower-triangular, so its exact
        # (pseudo)inverse — and hence U — is lower-triangular too. The
        # finite Newton–Schulz iteration starts from A^T and is not exactly
        # triangular; project U back so no future landmark channel leaks
        # into past queries.
        c = a.shape[-1]
        tril = jnp.tril(jnp.ones((c, c), bool))
        u = jnp.where(tril, u, 0.0)
    v32 = v.astype(jnp.float32)
    bv = jnp.einsum("...ck,...kd->...cd", b, v32)           # (..., c, d_v)
    out = jnp.einsum("...qc,...cd->...qd", f, jnp.matmul(u.astype(jnp.float32), bv))
    n_q, n_k = q.shape[-2], k.shape[-2]
    if cfg.include_shift_identity and n_q <= n_k:
        # + delta_ss * I_n maps to + delta_ss * V. Under the decode
        # convention (queries are the last n_q positions of the n_k context)
        # the diagonal picks out the trailing rows of V; for self-attention
        # (n_q == n_k) this is + delta_ss * V exactly.
        out = out + core.delta.astype(jnp.float32) * v32[..., n_k - n_q :, :]
    return out.astype(q.dtype)


def nystrom_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    num_landmarks: int = 64,
    pinv_iters: int = 6,
    causal: bool = False,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Nystromformer baseline (paper §2.4): F @ A^+ @ (B @ V)."""
    cfg = SSConfig(
        num_landmarks=num_landmarks,
        pinv_iters=pinv_iters,
        method="iterative",
        use_shift=False,
        include_shift_identity=False,
        causal=causal,
    )
    return spectral_shift_attention(q, k, v, cfg, scale=scale)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    impl: str = "full",
    *,
    causal: bool = False,
    ss_cfg: Optional[SSConfig] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Dispatch between attention implementations by name."""
    if impl == "full":
        return full_attention(q, k, v, causal=causal, scale=scale)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, scale=scale)
    if impl == "nystrom":
        cfg = ss_cfg or SSConfig()
        return nystrom_attention(
            q, k, v, num_landmarks=cfg.num_landmarks,
            pinv_iters=cfg.pinv_iters, causal=causal, scale=scale,
        )
    if impl == "spectral_shift":
        cfg = ss_cfg or SSConfig()
        if causal and not cfg.causal:
            cfg = dataclasses.replace(cfg, causal=True)
        return spectral_shift_attention(q, k, v, cfg, scale=scale)
    raise ValueError(f"unknown attention impl: {impl!r}")
