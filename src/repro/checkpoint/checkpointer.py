"""Fault-tolerant checkpointing: async save, atomic publish, retention,
mesh-agnostic restore (resharding on load).

Layout:  <dir>/step_<N>/
            manifest.json          {step, leaf paths, shapes, dtypes}
            <leaf-path>.npy        one file per pytree leaf

Save is atomic (write to ``step_<N>.tmp`` then rename) so a crash mid-save
never corrupts the latest checkpoint; ``latest_step`` only sees published
directories. Async mode hands the host copy to a worker thread so the train
loop continues. Restore takes a *target* sharding tree and device_puts each
leaf accordingly — checkpoints carry no mesh information, which is what
makes elastic re-scaling (restore onto a different mesh) work.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    # jax.tree.flatten_with_path needs newer jax; tree_util spelling works.
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True) -> None:
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        manifest = {"step": step, "leaves": {}}
        for key, leaf in flat.items():
            fname = re.sub(r"[^A-Za-z0-9_.:-]", "_", key) + ".npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(leaf).dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, sharding_tree=None):
        """Restore into the structure of ``target_tree``; if a sharding tree
        is given, leaves are placed with those shardings (any mesh)."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(sharding_tree) if sharding_tree is not None else {}
        restored = {}
        for key in flat_target:
            entry = manifest["leaves"][key]
            arr = np.load(os.path.join(d, entry["file"]))
            if key in flat_shard:
                restored[key] = jax.device_put(arr, flat_shard[key])
            else:
                restored[key] = jax.numpy.asarray(arr)
        # Rebuild the pytree in target order.
        leaves_in_order = [restored[k] for k in flat_target]
        treedef = jax.tree.structure(target_tree)
        return jax.tree.unflatten(treedef, leaves_in_order)
