"""Continuous-batching serving engine over the spectral-shift decode path.

vLLM-style lane scheduling on top of ``decode_step``:

* a fixed pool of ``max_lanes`` decode lanes, each with its own KV cache +
  landmark state and its own position counter (``decode_step`` is vmapped
  over lanes, so per-lane ``pos`` comes for free);
* requests queue up, are admitted into free lanes, prefill runs *inline*
  (prompt tokens are fed through the decode path one per engine tick —
  chunked prefill; a production deployment would batch-prefill with the
  Pallas kernels, see kernels/ops.py) and generation continues in the same
  lane until EOS / max_new_tokens;
* every engine tick advances ALL active lanes with one jitted batched step —
  admission/retirement never stalls other lanes (continuous batching).

The engine is deliberately synchronous and single-host; the multi-pod
serving story (TP-sharded lanes) reuses the same ``decode_step`` under pjit
— see launch/dryrun.py's decode cells, which lower exactly that.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import init_params
from repro.serve.decode import decode_step
from repro.serve.kv_cache import cache_specs


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy


@dataclasses.dataclass
class _Lane:
    req: Optional[Request] = None
    prompt_left: deque = dataclasses.field(default_factory=deque)
    generated: list[int] = dataclasses.field(default_factory=list)
    next_token: int = 0
    steps: int = 0

    @property
    def free(self) -> bool:
        return self.req is None


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_lanes: int = 4,
        max_seq: int = 512,
        eos_id: int = 2,
        seed: int = 0,
    ):
        self.cfg, self.params = cfg, params
        self.max_lanes, self.max_seq, self.eos_id = max_lanes, max_seq, eos_id
        self.queue: deque[Request] = deque()
        self.lanes = [_Lane() for _ in range(max_lanes)]
        self.finished: dict[int, list[int]] = {}
        self._key = jax.random.PRNGKey(seed)

        # Per-lane cache: cache_specs with B=1, stacked on a leading lane
        # axis; decode_step vmapped over that axis gives per-lane positions.
        specs = cache_specs(cfg, 1, max_seq)
        one = init_params(specs, jax.random.PRNGKey(0))  # zeros (init="zeros")
        self.cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (max_lanes, *x.shape)).copy(), one
        )
        step = functools.partial(decode_step, self.params, cfg)
        self._step = jax.jit(jax.vmap(step))

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        """Drive until queue + lanes drain (or tick budget). Returns outputs."""
        for _ in range(max_ticks):
            if not self.queue and all(l.free for l in self.lanes):
                break
            self.tick()
        return self.finished

    # -- scheduling ------------------------------------------------------------
    def _admit(self) -> None:
        for i, lane in enumerate(self.lanes):
            if lane.free and self.queue:
                req = self.queue.popleft()
                lane.req = req
                lane.prompt_left = deque(req.prompt)
                lane.generated = []
                lane.steps = 0
                lane.next_token = lane.prompt_left.popleft()
                # Zero this lane's cache (fresh request).
                self.cache = jax.tree.map(
                    lambda c: c.at[i].set(jnp.zeros_like(c[i])), self.cache
                )

    def _retire(self, i: int) -> None:
        lane = self.lanes[i]
        self.finished[lane.req.uid] = list(lane.generated)
        self.lanes[i] = _Lane()

    # -- one engine tick -------------------------------------------------------
    def tick(self) -> None:
        self._admit()
        active = [i for i, l in enumerate(self.lanes) if not l.free]
        if not active:
            return
        tokens = np.zeros((self.max_lanes, 1, 1), np.int32)
        for i in active:
            tokens[i, 0, 0] = self.lanes[i].next_token
        logits, self.cache = self._step(self.cache, jnp.asarray(tokens))
        logits = np.asarray(logits[:, 0, 0])  # (lanes, V)

        self._key, sub = jax.random.split(self._key)
        gumbel = np.asarray(
            jax.random.gumbel(sub, (self.max_lanes, logits.shape[-1]))
        )
        for i in active:
            lane = self.lanes[i]
            lane.steps += 1
            if lane.prompt_left:  # still prefilling: ignore the sample
                lane.next_token = lane.prompt_left.popleft()
                continue
            lg = logits[i, : self.cfg.vocab_size]
            if lane.req.temperature > 0:
                tok = int(np.argmax(lg / lane.req.temperature + gumbel[i, : lg.shape[0]]))
            else:
                tok = int(np.argmax(lg))
            lane.generated.append(tok)
            done = (
                tok == self.eos_id
                or len(lane.generated) >= lane.req.max_new_tokens
                or lane.steps >= self.max_seq - 1
            )
            if done:
                self._retire(i)
            else:
                lane.next_token = tok

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict:
        return {
            "queued": len(self.queue),
            "active": sum(not l.free for l in self.lanes),
            "finished": len(self.finished),
        }
