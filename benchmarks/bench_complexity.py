"""Paper Table 1: time complexity. Measures wall-clock per call vs sequence
length for exact O(n^2), Nystrom O(n) and Spectral-Shift O(n) attention, and
fits the empirical scaling exponent ``t ~ n^alpha``.

Expected: alpha(full) ~ 2, alpha(nystrom) ~ 1, alpha(spectral_shift) ~ 1.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import (
    SSConfig,
    chunked_attention,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)

NS = (512, 1024, 2048, 4096)
C = 64
D = 64


def _time(fn, *args, reps=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _fit_alpha(ns, ts) -> float:
    return float(np.polyfit(np.log(ns), np.log(ts), 1)[0])


def run(csv_rows: list[str]) -> None:
    key = jax.random.PRNGKey(0)
    impls = {
        "full": jax.jit(lambda q, k, v: full_attention(q, k, v)),
        "nystrom": jax.jit(
            lambda q, k, v: nystrom_attention(q, k, v, num_landmarks=C)
        ),
        "spectral_shift": jax.jit(
            lambda q, k, v: spectral_shift_attention(
                q, k, v, SSConfig(num_landmarks=C)
            )
        ),
    }
    times: dict[str, list[float]] = {k: [] for k in impls}
    for n in NS:
        kq, kk, kv, key = jax.random.split(key, 4)
        q = jax.random.normal(kq, (1, n, D)) * 0.5
        k = jax.random.normal(kk, (1, n, D)) * 0.5
        v = jax.random.normal(kv, (1, n, D))
        for name, fn in impls.items():
            us = _time(fn, q, k, v)
            times[name].append(us)
            csv_rows.append(f"complexity,{name},n={n},{us:.1f}")
    for name in impls:
        alpha = _fit_alpha(NS, times[name])
        csv_rows.append(f"complexity_exponent,{name},alpha,{alpha:.2f}")
    # Table-1 verdict: linear methods must scale with alpha well below full's.
    a_full = _fit_alpha(NS, times["full"])
    a_ss = _fit_alpha(NS, times["spectral_shift"])
    csv_rows.append(
        f"complexity_verdict,ss_vs_full,alpha_gap,{a_full - a_ss:.2f}"
    )


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("\n".join(rows))
