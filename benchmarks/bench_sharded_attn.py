"""Context-parallel attention benchmark: shard_map fused kernels vs the
jnp-GSPMD route on a sequence-sharded mesh.

The test process owns a single CPU device, so the measurement runs in a
subprocess with ``--xla_force_host_platform_device_count=4`` (the same
mechanism as the multi-device tests) and reports per cell:

    fwdbwd_ms    best wall-clock of a jitted value_and_grad call
    residual_mb  bytes of the saved VJP residuals (jax.vjp closure) — the
                 fused-sharded path saves the (c, dv)/(c, 1) landmark
                 summaries + online-softmax stats, the jnp path the (n, c)
                 softmax factors

plus jnp/sharded ratio rows. On CPU the kernels run in interpret mode, so
wall-clock measures interpreter overhead (the dispatch heuristic routes CPU
to jnp for exactly this reason); ``residual_mb`` is the backend-independent
evidence. TPU is the compile target. ``REPRO_BENCH_SMOKE=1`` shrinks the
sweep to one tiny cell for CI.
"""
from __future__ import annotations

import os
import subprocess
import sys

_SCRIPT = """
import time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.attention import SSConfig, spectral_shift_attention
from repro.kernels.sharded import ss_attention_fused_sharded

SIZES = {sizes}
REPS = {reps}
mesh = jax.make_mesh((4,), ("data",))
interpret = jax.default_backend() == "cpu"

def measure_ms(fn, args):
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3

def residual_mb(loss, args):
    _, vjp_fn = jax.vjp(loss, *args)
    return sum(x.nbytes for x in jax.tree.leaves(vjp_fn)
               if hasattr(x, "nbytes")) / 2**20

for n in SIZES:
    c, d, b = 32, 64, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, n, d)) * 0.5
    k = jax.random.normal(ks[1], (b, n, d)) * 0.5
    v = jax.random.normal(ks[2], (b, n, d))
    cfg = SSConfig(num_landmarks=c, causal=True, landmark_via_matmul=True)
    sh = NamedSharding(mesh, P(None, "data", None))
    args = tuple(jax.device_put(x, sh) for x in (q, k, v))

    losses = {{
        "jnp": lambda q, k, v: jnp.sum(
            spectral_shift_attention(q, k, v, cfg) ** 2),
        "sharded": lambda q, k, v: jnp.sum(ss_attention_fused_sharded(
            q, k, v, cfg, mesh=mesh, seq_axes=("data",),
            interpret=interpret) ** 2),
    }}
    ms, res = {{}}, {{}}
    for name, loss in losses.items():
        fn = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)),
                     in_shardings=(sh, sh, sh))
        ms[name] = measure_ms(fn, args)
        res[name] = residual_mb(loss, args)
        print(f"sharded_attn,n{{n}}_sp4_{{name}},fwdbwd_ms,{{ms[name]:.2f}}")
        print(f"sharded_attn,n{{n}}_sp4_{{name}},residual_mb,{{res[name]:.2f}}")
    print(f"sharded_attn,n{{n}}_sp4,jnp_over_sharded_time,"
          f"{{ms['jnp'] / ms['sharded']:.3f}}")
    print(f"sharded_attn,n{{n}}_sp4,jnp_over_sharded_residual_mem,"
          f"{{res['jnp'] / res['sharded']:.3f}}")
"""


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def run(rows: list[str]) -> None:
    sizes, reps = ((512,), 1) if _smoke() else ((2048, 8192), 3)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(sizes=sizes, reps=reps)],
        capture_output=True, text=True, timeout=1800, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded_attn subprocess failed:\n{proc.stderr[-2000:]}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith("sharded_attn,"):
            rows.append(line)
