"""Deterministic chaos harness for the serving engine.

``distributed/fault_tolerance.FailureInjector`` covers training-side chaos
(host deaths on a step schedule); this module is the serving counterpart.
A ``FaultPlan`` is a seed plus a set of ``FaultRule``s, each naming an
injection *site* threaded through the engine:

========== ====================================================================
site        effect
========== ====================================================================
alloc_fail       ``BlockAllocator._take_free`` returns None (allocation
                 shortfall) even when blocks are free — exercises admission
                 backoff, ``ensure_block`` preemption, and chunk stalls.
fragment         the allocator free-list is deterministically shuffled,
                 destroying LIFO locality — exercises ``defragment`` and
                 gather-route block scatter.
tick_delay       the engine sleeps ``param`` seconds (default 1 ms) at the
                 top of the tick — exercises wall-clock-sensitive paths
                 (deadlines are tick-domain, so outputs are unaffected).
drop_sample      a sampled token is discarded before commit; the lane is
                 replay-preempted (the per-tick landmark-sum updates make
                 in-place retry unsound, so recovery is a full recompute).
nan_stats        the lane's streaming landmark ``(m, l, acc)`` rows are set
                 to NaN *after* the decode dispatch — the silent-corruption
                 repro the numerics guard exists for.
nan_logits       the lane's sampled logits row is set to NaN on the host —
                 forces the guard's replay-preempt rung.
admission_stall  ``Scheduler.admit`` admits nothing this tick — exercises
                 queue growth, backpressure, and the watchdog.
hash_collision   prefix-cache lookups perturb their block digests, forcing
                 a cold miss. (A *true* collision would silently deliver
                 wrong K/V — undetectable by construction — so the injected
                 failure mode is the conservative one: lost reuse, never
                 lost correctness.)
evict_storm      ``param`` (default 4) prefix-cache entries are force-
                 evicted at the top of the tick — exercises pin accounting
                 and re-insertion.
========== ====================================================================

Every firing decision derives from ``(plan.seed, site, tick, ordinal,
lane)`` through a fresh ``numpy`` Philox stream, so a failing soak seed
replays exactly — no global RNG state, no ordering sensitivity beyond the
engine's own (deterministic) call order. Firings are recorded as flight-
recorder ``chaos`` events and counted in ``chaos_injections_total{site=}``.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np

SITES = (
    "alloc_fail",
    "fragment",
    "tick_delay",
    "drop_sample",
    "nan_stats",
    "nan_logits",
    "admission_stall",
    "hash_collision",
    "evict_storm",
)


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injection site with an optional tick window / lane / rate.

    ``rate`` is the per-opportunity firing probability (1.0 = always).
    ``start_tick``/``end_tick`` bound the window (end 0 = open-ended).
    ``lane`` restricts lane-scoped sites to one lane (-1 = any).
    ``param`` is site-specific: sleep seconds for tick_delay, eviction
    count for evict_storm.
    """

    site: str
    rate: float = 1.0
    start_tick: int = 0
    end_tick: int = 0
    lane: int = -1
    param: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown chaos site {self.site!r}; "
                             f"known: {', '.join(SITES)}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed plus the rules it drives. Hashable, printable, replayable."""

    seed: int = 0
    rules: tuple[FaultRule, ...] = ()

    def sites(self) -> set[str]:
        return {r.site for r in self.rules}


class EngineStalled(RuntimeError):
    """Raised by the no-progress watchdog after the escalation ladder
    (reclaim parked -> preempt youngest) fails to restore progress.

    Carries enough structure to diagnose the wedge without a debugger.
    """

    def __init__(self, tick: int, stall_ticks: int, waiting: int,
                 active_lanes: int, parked: int, pool: dict):
        self.tick = tick
        self.stall_ticks = stall_ticks
        self.waiting = waiting
        self.active_lanes = active_lanes
        self.parked = parked
        self.pool = pool
        super().__init__(
            f"engine made no progress for {stall_ticks} ticks at tick "
            f"{tick} (waiting={waiting} active_lanes={active_lanes} "
            f"parked={parked} pool={pool})"
        )


class ChaosInjector:
    """Evaluates a FaultPlan at each hook point, deterministically.

    ``fire(site, lane)`` returns the matching FaultRule if the injection
    fires this call, else None. Multiple calls to the same site within one
    tick get distinct ordinals, so ``rate`` applies per opportunity but the
    whole schedule still replays from ``(seed, tick)``.
    """

    def __init__(self, plan: FaultPlan, flight=None, registry=None):
        self.plan = plan
        self.flight = flight
        self.tick = 0
        self._ordinals: dict[str, int] = {}
        self._by_site: dict[str, list[FaultRule]] = {}
        for r in plan.rules:
            self._by_site.setdefault(r.site, []).append(r)
        self.injections = 0
        self._counter = None
        if registry is not None:
            self._counter = registry.counter(
                "chaos_injections_total",
                help="fault injections fired by the chaos harness",
                labels=("site",),
            )

    def begin_tick(self, tick: int):
        self.tick = tick
        self._ordinals.clear()

    def fire(self, site: str, lane: Optional[int] = None,
             detail: str = "") -> Optional[FaultRule]:
        rules = self._by_site.get(site)
        if not rules:
            return None
        ordinal = self._ordinals.get(site, 0)
        self._ordinals[site] = ordinal + 1
        for rule in rules:
            if self.tick < rule.start_tick:
                continue
            if rule.end_tick and self.tick > rule.end_tick:
                continue
            if rule.lane >= 0 and lane is not None and lane != rule.lane:
                continue
            if rule.rate < 1.0:
                # SeedSequence entropy must be non-negative ints; lane -1
                # (site not lane-scoped) maps to 0.
                rng = np.random.default_rng([
                    self.plan.seed,
                    zlib.crc32(site.encode()),
                    self.tick,
                    ordinal,
                    (lane if lane is not None else -1) + 1,
                ])
                if rng.random() >= rule.rate:
                    continue
            self.injections += 1
            if self._counter is not None:
                self._counter.labels(site=site).inc()
            if self.flight is not None:
                self.flight.record(
                    -1, "chaos", tick=self.tick, site=site,
                    lane=-1 if lane is None else lane, ordinal=ordinal,
                    detail=detail,
                )
            return rule
        return None
