"""Batched prompt prefill: one jitted forward pass seeds the decode state.

The seed engine replayed prompts token-by-token through ``decode_step`` —
O(prompt_len) engine ticks (each a host round-trip) before the first
generated token. The spectral-shifting method makes whole-prompt prefill
cheap: the per-layer landmark state is just a fixed ``(c, d)`` running-sum
summary, so the entire prompt can be pushed through the model at once and
the cache seeded directly:

* K/V (or MLA latent/rope) for all prompt positions in one projection;
* ``q_lmk``/``k_lmk`` running sums as masked segment sums over the prompt
  (exactly what per-token ``_lmk_add`` would have accumulated);
* the streaming B-side decode state ``bv_m``/``bv_l``/``bv_acc``
  (serve/decode_state.py), seeded exactly for every reached landmark row:
  ``ss_fused`` streams the prompt through the ``landmark_summary`` kernel
  once and its online-softmax (m, l, BV) land directly in the cache;
  ``replay`` uses the jnp recompute. Decode then *appends* to this state
  instead of rebuilding B over the horizon each tick — and because
  scheduler preemption recomputes through this same prefill path on
  re-admission, a preempted request's streaming state is rebuilt exactly;
* per-position attention outputs, three ways (``prefill_impl``):
    - ``replay``  — the decode-path attention math vmapped over positions
      (per-position landmark prefixes), numerically equivalent to feeding
      tokens one at a time; honors ``cfg.decode_attention_impl``. MoE
      caveat: expert capacity is computed over the whole prompt here but
      per token in replay, so equivalence for moe families holds only in
      the dropless regime (large ``capacity_factor``);
    - ``ss_fused`` — the Pallas ``landmark_summary``/``query_side`` kernels
      (kernels/ss_attention.py) over the whole prompt: the O(n) streamed
      formulation, approximate for causal prompts (landmarks see the full
      prompt) but the cache it leaves behind is still exact.

Both modes right-pad prompts to a bucket multiple so only a handful of XLA
programs ever compile; all padded positions are masked out of cache writes
and landmark sums. In ``ss_fused`` mode the prompt length rides into the
kernels as a dynamic key-validity bound (``kv_valid``), so padded zero-keys
never enter the softmax normalization or the landmark means — the bucketed
program is numerically the unpadded one. The only exception is degenerate
prompts of <= num_landmarks tokens: they hit the exact-attention path, which
carries no key mask, so the engine slices them to exact length (tiny
programs, cheap recompiles; ``ss_attention_fused`` assert-guards padded
callers).

Supported for the attention-cache families (dense / moe / vlm, GQA or MLA).
Hybrid and SSM stacks keep token replay (their recurrent state is inherently
sequential); the engine falls back automatically.

Prefix caching (serve/paged.py) rides on the chunked variant of this path:
a partial hit attaches the shared blocks plus the dense snapshot captured
at the deepest block-aligned chunk boundary, then *resumes* chunked prefill
from that boundary — chunk starts are always block-aligned, so a resumed
prefill runs the exact same chunk programs a cold prefill would have run
from that offset, and the resulting cache is bitwise the cold one. A full
hit skips this module entirely (first-token logits come from the cache
entry).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import _broadcast_kv, ss_config_from
from repro.models.layers import apply_rotary, mlp_forward, rms_norm, rotary_angles
from repro.models.model import _embed_tokens, _unembed, working_params
from repro.models.moe import moe_forward
from repro.models.params import ParamSpec
from repro.serve.decode import (
    _segment_len,
    full_decode_attention,
    ss_decode_attention,
)
from repro.serve.decode_state import (
    STREAM_LEAVES,
    landmark_counts,
    landmark_means,
    mask_stats_rows,
    rebase_span,
    recompute_stats,
    segment_len,
)
from repro.serve.kv_cache import cache_specs


def prefill_supported(cfg: ModelConfig) -> bool:
    """Families whose whole decode state is derivable in one forward pass."""
    return cfg.family in ("dense", "moe", "vlm")


def _zero_cache(cfg: ModelConfig, seq_len: int) -> Any:
    specs = cache_specs(cfg, 1, seq_len)
    is_spec = lambda x: isinstance(x, ParamSpec)  # noqa: E731
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype or jnp.float32), specs,
        is_leaf=is_spec,
    )


def _routing(n: int, n_valid, seq_max: int, c: int):
    """Segment routing for a prompt window: (t_mask (n,), onehot (n, c))
    with positions >= n_valid zeroed out."""
    t = jnp.arange(n)
    t_mask = t < n_valid
    seg = t // _segment_len(seq_max, c)
    oh = jax.nn.one_hot(seg, c, dtype=jnp.float32) * t_mask[:, None]
    return t_mask, oh


def _prefix_sums(oh: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Per-position inclusive landmark prefix sums.

    oh (n, c) masked routing; x (B, H, n, d). Returns (n, B, H, c, d) where
    entry t equals the running sums ``_lmk_add`` would hold after feeding
    tokens 0..t — the state the decode path sees at position t."""
    contrib = oh[None, None, :, :, None] * x[:, :, :, None, :]  # (B,H,n,c,d)
    cum = jnp.cumsum(contrib.astype(jnp.float32), axis=2)
    return jnp.moveaxis(cum, 2, 0)


def _attend_prefill(
    cfg: ModelConfig, impl: str, prefill_impl: str,
    q, k_b, v_b, q_sums, k_sums_b, scale, seq_max: int, t_mask,
    n_valid=None, block_n: int = 512, pos0=0,
):
    """Per-position attention over the prompt window.

    q (B,H,n,d); k_b/v_b kv-broadcast and pad-masked keys/values — prompt-
    window long for whole-prompt prefill, or an assembled prefix+chunk view
    (longer than n) for chunked prefill; q_sums/k_sums_b (n,B,H,c,d)
    landmark prefixes; ``n_valid`` the true prompt length (traced);
    ``pos0`` (traced) offsets query positions so a chunk window attends at
    its global positions. Returns (B,H,n,dv)."""
    n = q.shape[2]
    if prefill_impl == "ss_fused" and impl == "spectral_shift":
        from repro.core.attention import full_attention
        from repro.kernels.ops import ss_attention_fused

        if n <= cfg.num_landmarks:
            # Degenerate window: this is the exact-attention regime the
            # unpadded call would hit (n <= c), computed here with the
            # key-validity mask applied directly so a bucket-padded tiny
            # prompt stays exact too (the fused degenerate path carries no
            # mask; the window is <= c tokens, so O(n^2) is trivial).
            key_mask = (jnp.arange(n) < n_valid)[None, None, None, :]
            return full_attention(q, k_b, v_b, mask=key_mask, scale=scale)
        # Bucketed padding: kv_valid masks padded zero-keys out of the
        # softmax normalization and the landmark means, so this computes
        # exactly what the unpadded call would. Contract: n_valid must
        # exceed num_landmarks here (the engine slices shorter prompts to
        # exact-length windows, taking the branch above).
        return ss_attention_fused(
            q, k_b, v_b, ss_config_from(cfg, causal=False), scale=scale,
            interpret=cfg.kernels_interpret, block_n=block_n,
            kv_valid=n_valid,
        )
    qs = jnp.moveaxis(q, 2, 0)[:, :, :, None, :]  # (n, B, H, 1, d)
    pos_t = pos0 + jnp.arange(n)
    if impl == "spectral_shift":
        def one(qt, qsum, ksum, pos):
            return ss_decode_attention(
                qt, k_b, v_b, qsum, ksum, pos, cfg, scale, seq_max=seq_max
            )
    else:
        def one(qt, qsum, ksum, pos):
            return full_decode_attention(qt, k_b, v_b, pos, scale)

    outs = jax.vmap(one)(qs, q_sums, k_sums_b, pos_t)  # (n, B, H, 1, dv)
    return jnp.moveaxis(outs[:, :, :, 0, :], 0, 2)      # (B, H, n, dv)


def _seed_stream_stats(cfg: ModelConfig, prefill_impl: str, q_l, kb, vb,
                       n_valid, scale, seq_max: int, block_n: int):
    """Streaming decode state (serve/decode_state.py) for one layer, seeded
    in one shot from the whole prompt: per-landmark online-softmax partials
    (m, l, acc) over keys 0..n_valid-1, keyed by the cache's horizon-
    segmented landmark means ``q_l`` (B, H, c, d).

    ``ss_fused`` prefill streams the prompt through the ``landmark_summary``
    Pallas kernel once (kv_valid-masked, so bucket padding stays invisible)
    and hands the kernel's (m, l, BV) directly into the cache — the
    prefill->decode handoff costs one O(n) kernel pass. Other modes (replay,
    degenerate <= c windows) use the jnp ``recompute_stats``. Rows past the
    active segment are zeroed (the streaming invariant)."""
    c = cfg.num_landmarks
    pos_last = n_valid - 1
    if cfg.decode_attention_impl != "spectral_shift":
        z = jnp.zeros((*q_l.shape[:3], 1), jnp.float32)
        return z, z, jnp.zeros((*q_l.shape[:3], vb.shape[-1]), jnp.float32)
    if prefill_impl == "ss_fused" and kb.shape[2] > c:
        from repro.kernels.ss_attention import landmark_summary

        b, h, n, d = kb.shape
        dv = vb.shape[-1]
        bv, m, l = landmark_summary(
            q_l.reshape(b * h, c, d),
            kb.reshape(b * h, n, d),
            vb.reshape(b * h, n, dv),
            scale=scale, block_n=block_n, interpret=cfg.kernels_interpret,
            return_stats=True, kv_valid=n_valid,
        )
        m = m.reshape(b, h, c, 1)
        l = l.reshape(b, h, c, 1)
        acc = bv.astype(jnp.float32).reshape(b, h, c, dv) * l
    else:
        m, l, acc = recompute_stats(q_l, kb, vb, pos_last, scale)
    keep = jnp.arange(c) <= pos_last // segment_len(seq_max, c)
    return mask_stats_rows((m, l, acc), keep)


# --------------------------------------------------------------------------
# per-layer prefill (mirrors gqa_decode / mla_decode, vectorized over n)
# --------------------------------------------------------------------------
def _gqa_prefill(p, cfg: ModelConfig, x, sin, cos, t_mask, oh, seq_max, impl,
                 prefill_impl, n_valid, block_n):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bhse", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhe->bhse", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhe->bhse", x, p["w_v"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)[None, :, None, :]
        k = k + p["b_k"].astype(dt)[None, :, None, :]
        v = v + p["b_v"].astype(dt)[None, :, None, :]
    if cfg.rope_theta > 0:
        q = apply_rotary(q, sin, cos)
        k = apply_rotary(k, sin, cos)

    pad = t_mask[None, None, :, None]
    k_m = jnp.where(pad, k, 0).astype(k.dtype)
    v_m = jnp.where(pad, v, 0).astype(v.dtype)

    q_sums = _prefix_sums(oh, q)          # (n, B, H, c, d)
    k_sums = _prefix_sums(oh, k_m)        # (n, B, Hkv, c, d)
    kb = _broadcast_kv(k_m, cfg.num_heads)
    vb = _broadcast_kv(v_m, cfg.num_heads)
    k_sums_b = jax.vmap(_broadcast_kv, (0, None))(k_sums, cfg.num_heads)

    scale = cfg.resolved_head_dim ** -0.5
    out = _attend_prefill(
        cfg, impl, prefill_impl, q, kb, vb, q_sums, k_sums_b,
        scale, seq_max, t_mask, n_valid, block_n,
    )
    c = cfg.num_landmarks
    counts = landmark_counts(n_valid - 1, seq_max, c)
    bv_m, bv_l, bv_acc = _seed_stream_stats(
        cfg, prefill_impl, landmark_means(q_sums[-1], counts), kb, vb,
        n_valid, scale, seq_max, block_n,
    )
    new_cache = {
        "k": k_m, "v": v_m,
        "q_lmk": q_sums[-1].astype(jnp.float32),
        "k_lmk": k_sums[-1].astype(jnp.float32),
        "bv_m": bv_m, "bv_l": bv_l, "bv_acc": bv_acc,
    }
    attn = jnp.einsum("bhse,hed->bsd", out.astype(dt), p["w_o"].astype(dt))
    return attn, new_cache


def _mla_prefill(p, cfg: ModelConfig, x, sin, cos, t_mask, oh, seq_max, impl,
                 prefill_impl, n_valid, block_n):
    dt = x.dtype
    dh, dr = cfg.resolved_head_dim, cfg.rope_head_dim
    c_kv = rms_norm(x @ p["w_dkv"].astype(dt), p["norm_kv"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_k_rope"].astype(dt))[:, None]
    k_rope = apply_rotary(k_rope, sin, cos)[:, 0]  # (B, n, dr)

    q_nope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_nope"].astype(dt))
    q_rope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_rope"].astype(dt))
    q_rope = apply_rotary(q_rope, sin, cos)
    q_abs = jnp.einsum("bhse,rhe->bhsr", q_nope, p["w_uk"].astype(dt))
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)  # (B, H, n, r+dr)

    pad2 = t_mask[None, :, None]
    c_kv_m = jnp.where(pad2, c_kv, 0).astype(c_kv.dtype)
    k_rope_m = jnp.where(pad2, k_rope, 0).astype(k_rope.dtype)
    k_eff = jnp.concatenate([c_kv_m, k_rope_m], axis=-1)  # (B, n, r+dr)

    q_sums = _prefix_sums(oh, q_eff)                    # (n, B, H, c, de)
    k_sums = _prefix_sums(oh, k_eff[:, None])[:, :, 0]  # (n, B, c, de)

    h = cfg.num_heads
    k_eff_b = jnp.broadcast_to(
        k_eff[:, None], (k_eff.shape[0], h, *k_eff.shape[1:])
    )
    lat_b = jnp.broadcast_to(
        c_kv_m[:, None], (c_kv_m.shape[0], h, *c_kv_m.shape[1:])
    )
    k_sums_b = jnp.broadcast_to(
        k_sums[:, :, None], (*k_sums.shape[:2], h, *k_sums.shape[2:])
    )
    scale = (dh + dr) ** -0.5
    out_lat = _attend_prefill(
        cfg, impl, prefill_impl, q_eff, k_eff_b, lat_b, q_sums, k_sums_b,
        scale, seq_max, t_mask, n_valid, block_n,
    )
    out = jnp.einsum("bhsr,rhe->bhse", out_lat.astype(dt), p["w_uv"].astype(dt))
    attn = jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(dt))
    c = cfg.num_landmarks
    counts = landmark_counts(n_valid - 1, seq_max, c)
    bv_m, bv_l, bv_acc = _seed_stream_stats(
        cfg, prefill_impl, landmark_means(q_sums[-1], counts), k_eff_b,
        lat_b, n_valid, scale, seq_max, block_n,
    )
    new_cache = {
        "latent": c_kv_m, "rope": k_rope_m,
        "q_lmk": q_sums[-1].astype(jnp.float32),
        "k_lmk": k_sums[-1].astype(jnp.float32),
        "bv_m": bv_m, "bv_l": bv_l, "bv_acc": bv_acc,
    }
    return attn, new_cache


def _dense_layer_prefill(lp, cfg: ModelConfig, x, sin, cos, t_mask, oh,
                         seq_max, impl, prefill_impl, n_valid, block_n):
    h = rms_norm(x, lp["norm_attn"], cfg.norm_eps)
    fn = _mla_prefill if cfg.mla else _gqa_prefill
    attn, new_cache = fn(
        lp["attn"], cfg, h, sin, cos, t_mask, oh, seq_max, impl, prefill_impl,
        n_valid, block_n,
    )
    x = x + attn
    h = rms_norm(x, lp["norm_mlp"], cfg.norm_eps)
    if cfg.moe:
        ff, _ = moe_forward(lp["moe"], cfg, h)
    else:
        ff = mlp_forward(lp["mlp"], h, cfg.act)
    return x + ff, new_cache


# --------------------------------------------------------------------------
# whole-prompt prefill
# --------------------------------------------------------------------------
def batched_prefill(
    params, cfg: ModelConfig, tokens: jnp.ndarray, n_valid: jnp.ndarray,
    *, seq_max: int, prefill_impl: str = "replay", block_n: int = 512,
):
    """Run a whole (padded) prompt through the model in one pass.

    tokens (1, n_pad) int32, n_valid scalar int32 <= n_pad. Returns
    ``(logits (1, n_pad, V), cache)`` where ``cache`` matches
    ``cache_specs(cfg, 1, n_pad)`` in structure: K/V filled for positions
    < n_valid (zeros elsewhere), landmark running sums accumulated over the
    first n_valid tokens with ``seq_max`` segment routing, pos = n_valid.
    The next-token logits live at index ``n_valid - 1``.

    ``prefill_impl="ss_fused"`` contract: when the padded window exceeds
    ``cfg.num_landmarks``, ``n_valid`` must too — the masked kernels model
    the unpadded >c regime, while a <=c prompt belongs on the exact path
    (the engine slices such prompts to windows <= num_landmarks, where the
    masked exact branch handles any ``n_valid``).
    """
    if not prefill_supported(cfg):
        raise ValueError(f"batched prefill unsupported for family {cfg.family}")
    if (prefill_impl == "ss_fused"
            and tokens.shape[1] > cfg.num_landmarks
            and not isinstance(n_valid, jax.core.Tracer)
            and int(n_valid) <= cfg.num_landmarks):
        # Concrete (eager) callers get the contract enforced loudly; under
        # jit n_valid is a tracer and the engine's window slicing upholds it.
        raise ValueError(
            f"ss_fused prefill: prompt length {int(n_valid)} <= "
            f"num_landmarks {cfg.num_landmarks} must run in a window of at "
            f"most num_landmarks tokens (the engine slices such prompts) — "
            f"the masked kernels model the > num_landmarks regime only"
        )
    params = working_params(params, cfg)
    cache = _zero_cache(cfg, tokens.shape[1])
    dt = jnp.dtype(cfg.compute_dtype)
    n = tokens.shape[1]
    x = _embed_tokens(params, cfg, tokens).astype(dt)
    impl = cfg.decode_attention_impl

    c = cfg.num_landmarks
    t_mask, oh = _routing(n, n_valid, seq_max, c)
    positions = jnp.arange(n)[None]  # (1, n)
    rope_dim = cfg.rope_head_dim if cfg.mla else cfg.resolved_head_dim
    sin, cos = rotary_angles(positions, rope_dim, cfg.rope_theta)
    sin, cos = sin[:, None], cos[:, None]  # (1, 1, n, dh/2)

    layer_fn = functools.partial(
        _dense_layer_prefill, cfg=cfg, sin=sin, cos=cos, t_mask=t_mask,
        oh=oh, seq_max=seq_max, impl=impl, prefill_impl=prefill_impl,
        n_valid=jnp.asarray(n_valid, jnp.int32), block_n=block_n,
    )
    if cfg.scan_layers and not isinstance(params["layers"], list):
        def body(y, lp):
            y, nc = layer_fn(lp, x=y)
            return y, nc

        x, new_layers = jax.lax.scan(body, x, params["layers"])
    else:
        new_layers = []
        for lp in params["layers"]:
            x, nc = layer_fn(lp, x=x)
            new_layers.append(nc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = jnp.asarray(n_valid, jnp.int32)
    return logits, new_cache


# --------------------------------------------------------------------------
# chunked prefill (continuous batching): one fixed-size prompt chunk per
# call, carrying the landmark state across chunks
# --------------------------------------------------------------------------
def _insert_chunk(view, chunk, start, axis: int):
    """Extend a committed-prefix cache view (seq ``axis``) by one chunk:
    pad the view by the chunk length (so a tail chunk can never clamp the
    dynamic write backwards into committed data), then write the chunk's
    rows at global position ``start``."""
    n = chunk.shape[axis]
    pad = [(0, 0)] * view.ndim
    pad[axis] = (0, n)
    ext = jnp.pad(view.astype(chunk.dtype), pad)
    idx = [0] * view.ndim
    idx[axis] = start
    return jax.lax.dynamic_update_slice(ext, chunk, tuple(idx))


def _merge_chunk_stats(cfg: ModelConfig, stats_impl: str, carry, q_l, kb, vb,
                       k_full_b, v_full_b, start, chunk_valid, scale,
                       seq_max: int, block_n: int):
    """Streaming-stat carry across prefill chunks for one layer.

    ``carry`` = the lane's (bv_m, bv_l, bv_acc) leaves after the previous
    chunk (the ``_seed_stream_stats`` state for prompt length ``start``);
    ``q_l`` the landmark means at ``end = start + chunk_valid``; kb/vb the
    chunk window's keys/values (head-broadcast, pad-masked); k_full_b /
    v_full_b the assembled keys 0..end-1. Returns the state whole-prompt
    seeding would produce for prompt length ``end`` (frozen rows up to
    softmax reassociation):

    * rows frozen before the chunk (r < start//seg — their landmark means
      were already final) take the chunk window's partial, computed with
      those final means, merged into the carry via ``flash_merge`` — the
      ss_fused handoff streams the window through ``landmark_summary``;
    * rows whose mean moved (or that were founded) inside the chunk —
      the contiguous span start//seg..(end-1)//seg — are recomputed
      exactly over the assembled view (``rebase_span``);
    * rows past the active segment stay zero (the streaming invariant)."""
    c = cfg.num_landmarks
    if cfg.decode_attention_impl != "spectral_shift":
        return tuple(jnp.zeros_like(s, jnp.float32) for s in carry)
    seg = segment_len(seq_max, c)
    chunk_pad = kb.shape[2]
    end_pos = start + chunk_valid - 1
    if stats_impl == "ss_fused" and chunk_pad > c:
        from repro.kernels.ss_attention import landmark_summary

        b, h, n, d = kb.shape
        dv = vb.shape[-1]
        bv, m_w, l_w = landmark_summary(
            q_l.reshape(b * h, c, d),
            kb.reshape(b * h, n, d),
            vb.reshape(b * h, n, dv),
            scale=scale, block_n=block_n, interpret=cfg.kernels_interpret,
            return_stats=True, kv_valid=chunk_valid,
        )
        m_w = m_w.reshape(b, h, c, 1)
        l_w = l_w.reshape(b, h, c, 1)
        acc_w = bv.astype(jnp.float32).reshape(b, h, c, dv) * l_w
    else:
        m_w, l_w, acc_w = recompute_stats(q_l, kb, vb, chunk_valid - 1, scale)
    from repro.kernels.ops import flash_merge

    carry32 = tuple(s.astype(jnp.float32) for s in carry)
    m_f, l_f, acc_f = flash_merge(*carry32, m_w, l_w, acc_w)
    frozen = (jnp.arange(c) < start // seg)[:, None]
    m = jnp.where(frozen, m_f, carry32[0])
    l = jnp.where(frozen, l_f, carry32[1])
    acc = jnp.where(frozen, acc_f, carry32[2])
    row_lo = start // seg
    row_hi = end_pos // seg
    span = min(chunk_pad // seg + 2, c)
    m, l, acc = rebase_span(
        (m, l, acc), q_l, k_full_b, v_full_b, end_pos, scale,
        row_lo, row_hi, span,
    )
    keep = jnp.arange(c) <= row_hi
    return mask_stats_rows((m, l, acc), keep)


def _gqa_chunk(p, cfg: ModelConfig, x, sin, cos, t_mask, oh, seq_max, impl,
               stats_impl, start, chunk_valid, lcache, block_n):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bhse", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhe->bhse", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhe->bhse", x, p["w_v"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)[None, :, None, :]
        k = k + p["b_k"].astype(dt)[None, :, None, :]
        v = v + p["b_v"].astype(dt)[None, :, None, :]
    if cfg.rope_theta > 0:
        q = apply_rotary(q, sin, cos)
        k = apply_rotary(k, sin, cos)

    pad = t_mask[None, None, :, None]
    k_m = jnp.where(pad, k, 0).astype(k.dtype)
    v_m = jnp.where(pad, v, 0).astype(v.dtype)

    # landmark prefixes continue the lane's running sums
    q_sums = lcache["q_lmk"][None] + _prefix_sums(oh, q)
    k_sums = lcache["k_lmk"][None] + _prefix_sums(oh, k_m)
    kb = _broadcast_kv(k_m, cfg.num_heads)
    vb = _broadcast_kv(v_m, cfg.num_heads)
    k_sums_b = jax.vmap(_broadcast_kv, (0, None))(k_sums, cfg.num_heads)

    # assembled keys 0..end-1: committed view + this chunk at [start, end)
    k_full = _insert_chunk(lcache["k"], k_m, start, axis=2)
    v_full = _insert_chunk(lcache["v"], v_m, start, axis=2)
    kfb = _broadcast_kv(k_full, cfg.num_heads)
    vfb = _broadcast_kv(v_full, cfg.num_heads)

    scale = cfg.resolved_head_dim ** -0.5
    out = _attend_prefill(
        cfg, impl, "replay", q, kfb, vfb, q_sums, k_sums_b,
        scale, seq_max, t_mask, chunk_valid, block_n, pos0=start,
    )
    c = cfg.num_landmarks
    counts = landmark_counts(start + chunk_valid - 1, seq_max, c)
    q_l = landmark_means(q_sums[-1], counts)
    bv_m, bv_l, bv_acc = _merge_chunk_stats(
        cfg, stats_impl, tuple(lcache[nm] for nm in STREAM_LEAVES),
        q_l, kb, vb, kfb, vfb, start, chunk_valid, scale, seq_max, block_n,
    )
    new_cache = {
        "k": k_m, "v": v_m,
        "q_lmk": q_sums[-1].astype(jnp.float32),
        "k_lmk": k_sums[-1].astype(jnp.float32),
        "bv_m": bv_m, "bv_l": bv_l, "bv_acc": bv_acc,
    }
    attn = jnp.einsum("bhse,hed->bsd", out.astype(dt), p["w_o"].astype(dt))
    return attn, new_cache


def _mla_chunk(p, cfg: ModelConfig, x, sin, cos, t_mask, oh, seq_max, impl,
               stats_impl, start, chunk_valid, lcache, block_n):
    dt = x.dtype
    dh, dr = cfg.resolved_head_dim, cfg.rope_head_dim
    c_kv = rms_norm(x @ p["w_dkv"].astype(dt), p["norm_kv"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_k_rope"].astype(dt))[:, None]
    k_rope = apply_rotary(k_rope, sin, cos)[:, 0]  # (B, n, dr)

    q_nope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_nope"].astype(dt))
    q_rope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_rope"].astype(dt))
    q_rope = apply_rotary(q_rope, sin, cos)
    q_abs = jnp.einsum("bhse,rhe->bhsr", q_nope, p["w_uk"].astype(dt))
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)

    pad2 = t_mask[None, :, None]
    c_kv_m = jnp.where(pad2, c_kv, 0).astype(c_kv.dtype)
    k_rope_m = jnp.where(pad2, k_rope, 0).astype(k_rope.dtype)
    k_eff = jnp.concatenate([c_kv_m, k_rope_m], axis=-1)

    q_sums = lcache["q_lmk"][None] + _prefix_sums(oh, q_eff)
    k_sums = (
        lcache["k_lmk"][None] + _prefix_sums(oh, k_eff[:, None])[:, :, 0]
    )

    h = cfg.num_heads
    k_eff_b = jnp.broadcast_to(
        k_eff[:, None], (k_eff.shape[0], h, *k_eff.shape[1:])
    )
    lat_b = jnp.broadcast_to(
        c_kv_m[:, None], (c_kv_m.shape[0], h, *c_kv_m.shape[1:])
    )
    lat_full = _insert_chunk(lcache["latent"], c_kv_m, start, axis=1)
    rope_full = _insert_chunk(lcache["rope"], k_rope_m, start, axis=1)
    k_eff_full = jnp.concatenate([lat_full, rope_full], axis=-1)
    kfb = jnp.broadcast_to(
        k_eff_full[:, None], (k_eff_full.shape[0], h, *k_eff_full.shape[1:])
    )
    vfb = jnp.broadcast_to(
        lat_full[:, None], (lat_full.shape[0], h, *lat_full.shape[1:])
    )
    k_sums_b = jnp.broadcast_to(
        k_sums[:, :, None], (*k_sums.shape[:2], h, *k_sums.shape[2:])
    )
    scale = (dh + dr) ** -0.5
    out_lat = _attend_prefill(
        cfg, impl, "replay", q_eff, kfb, vfb, q_sums, k_sums_b,
        scale, seq_max, t_mask, chunk_valid, block_n, pos0=start,
    )
    out = jnp.einsum("bhsr,rhe->bhse", out_lat.astype(dt), p["w_uv"].astype(dt))
    attn = jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(dt))
    counts = landmark_counts(
        start + chunk_valid - 1, seq_max, cfg.num_landmarks
    )
    q_l = landmark_means(q_sums[-1], counts)
    bv_m, bv_l, bv_acc = _merge_chunk_stats(
        cfg, stats_impl, tuple(lcache[nm] for nm in STREAM_LEAVES),
        q_l, k_eff_b, lat_b, kfb, vfb, start, chunk_valid, scale, seq_max,
        block_n,
    )
    new_cache = {
        "latent": c_kv_m, "rope": k_rope_m,
        "q_lmk": q_sums[-1].astype(jnp.float32),
        "k_lmk": k_sums[-1].astype(jnp.float32),
        "bv_m": bv_m, "bv_l": bv_l, "bv_acc": bv_acc,
    }
    return attn, new_cache


def _dense_layer_chunk(lp, lc, cfg: ModelConfig, x, sin, cos, t_mask, oh,
                       seq_max, impl, stats_impl, start, chunk_valid,
                       block_n):
    h = rms_norm(x, lp["norm_attn"], cfg.norm_eps)
    fn = _mla_chunk if cfg.mla else _gqa_chunk
    attn, new_cache = fn(
        lp["attn"], cfg, h, sin, cos, t_mask, oh, seq_max, impl, stats_impl,
        start, chunk_valid, lc, block_n,
    )
    x = x + attn
    h = rms_norm(x, lp["norm_mlp"], cfg.norm_eps)
    if cfg.moe:
        ff, _ = moe_forward(lp["moe"], cfg, h)
    else:
        ff = mlp_forward(lp["mlp"], h, cfg.act)
    return x + ff, new_cache


def chunk_prefill(
    params, cfg: ModelConfig, cache: Any, tokens: jnp.ndarray, start,
    chunk_valid, *, seq_max: int, stats_impl: str = "replay",
    block_n: int = 512,
):
    """Advance a mid-prefill lane by one fixed-size prompt chunk.

    ``cache`` is the lane's B=1 assembled view (committed K/V for positions
    < ``start``, plus the dense landmark/stream leaves carried from the
    previous chunk); ``tokens`` (1, chunk_pad) the chunk window with
    ``chunk_valid`` real tokens at global positions start..start+valid-1
    (``start``/``chunk_valid`` traced). Returns ``(logits (1, chunk_pad, V),
    new_cache)`` where seq leaves hold the CHUNK's K/V only (the caller
    commits them at the chunk's blocks) and dense leaves the carried-forward
    state; last-token logits live at ``chunk_valid - 1``.

    Chunk attention is the exact per-position replay math at global
    positions over the assembled view — token-identical to feeding the
    prompt one token at a time, hence to whole-prompt ``replay`` prefill.
    ``stats_impl`` only routes the streaming-stat window handoff
    (``_merge_chunk_stats``): ``ss_fused`` streams each chunk window through
    the ``landmark_summary`` kernel, ``replay`` uses the jnp recompute; the
    resulting cache is the same up to softmax reassociation. (Whole-prompt
    ``ss_fused`` *attention* is non-causal over the prompt and so cannot be
    chunked; chunked mode upgrades it to the exact outputs instead.) MoE
    caveat as whole-prompt: expert capacity is computed per chunk window,
    so replay equivalence holds in the dropless regime."""
    if not prefill_supported(cfg):
        raise ValueError(f"chunked prefill unsupported for family {cfg.family}")
    params = working_params(params, cfg)
    dt = jnp.dtype(cfg.compute_dtype)
    n = tokens.shape[1]
    start = jnp.asarray(start, jnp.int32)
    chunk_valid = jnp.asarray(chunk_valid, jnp.int32)
    x = _embed_tokens(params, cfg, tokens).astype(dt)
    impl = cfg.decode_attention_impl

    c = cfg.num_landmarks
    t = jnp.arange(n)
    t_mask = t < chunk_valid
    seg_idx = (start + t) // _segment_len(seq_max, c)
    oh = jax.nn.one_hot(seg_idx, c, dtype=jnp.float32) * t_mask[:, None]
    positions = (start + t)[None]  # (1, n) global positions
    rope_dim = cfg.rope_head_dim if cfg.mla else cfg.resolved_head_dim
    sin, cos = rotary_angles(positions, rope_dim, cfg.rope_theta)
    sin, cos = sin[:, None], cos[:, None]

    layer_fn = functools.partial(
        _dense_layer_chunk, cfg=cfg, sin=sin, cos=cos, t_mask=t_mask, oh=oh,
        seq_max=seq_max, impl=impl, stats_impl=stats_impl, start=start,
        chunk_valid=chunk_valid, block_n=block_n,
    )
    if cfg.scan_layers and not isinstance(params["layers"], list):
        def body(y, lp_lc):
            lp, lc = lp_lc
            y, nc = layer_fn(lp, lc, x=y)
            return y, nc

        x, new_layers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"])
        )
    else:
        new_layers = []
        for lp, lc in zip(params["layers"], cache["layers"]):
            x, nc = layer_fn(lp, lc, x=x)
            new_layers.append(nc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    new_cache = dict(_zero_cache(cfg, n))
    new_cache["layers"] = new_layers
    new_cache["pos"] = jnp.asarray(start + chunk_valid, jnp.int32)
    return logits, new_cache


def make_chunk_prefill_fn(params, cfg: ModelConfig, *, seq_max: int,
                          stats_impl: str = "replay", block_n: int = 512):
    """Chunk-prefill closure ``fn(cache, tokens, start, chunk_valid)`` for
    ``PagedKVCache.make_chunk_step`` (which jits the fused gather ->
    chunk -> commit program; one XLA program per bucketed view length)."""
    def fn(cache, tokens, start, chunk_valid):
        return chunk_prefill(
            params, cfg, cache, tokens, start, chunk_valid,
            seq_max=seq_max, stats_impl=stats_impl, block_n=block_n,
        )

    return fn


def make_prefill_fn(params, cfg: ModelConfig, *, seq_max: int,
                    prefill_impl: str = "replay", block_n: int = 512):
    """Jitted prefill closure ``fn(tokens, n_valid)``; jax.jit specializes
    one XLA program per padded prompt length — per bucket in both modes
    (``ss_fused`` masks the pad via ``kv_valid``), plus one exact-length
    program per degenerate <= num_landmarks prompt in ``ss_fused`` mode.
    ``block_n`` is the Pallas stream block (dispatch plan for the serve
    shape)."""
    fn = functools.partial(
        batched_prefill, params, cfg, seq_max=seq_max,
        prefill_impl=prefill_impl, block_n=block_n,
    )
    return jax.jit(fn)
