"""Serving benchmark: time-to-first-token and throughput, dense token-replay
engine vs paged engine with batched prefill.

TTFT is reported both in engine ticks (the architectural win: one batched
forward pass vs one tick per prompt token) and wall-clock seconds. The
paged engine's tick TTFT is 1 by construction; the replay engine's equals
the prompt length.

    PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine

PROMPT_LENS = (32, 64, 128, 256)
MAX_SEQ = 320
MAX_NEW = 8


def _setup():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _serve_cfg(paged: bool, lanes: int) -> ServeConfig:
    return ServeConfig(
        max_lanes=lanes, max_seq=MAX_SEQ, block_size=16,
        paged=paged, batched_prefill=paged,
    )


def _ttft(cfg, params, serve, prompt_len: int, reps: int = 3) -> tuple[int, float]:
    """(ticks, seconds) from submission to the first generated token of one
    request. The same engine first serves an identical throwaway request so
    every XLA program (prefill bucket + decode tick buckets) is compiled
    before timing; best of ``reps`` to shrug off machine noise."""
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params, serve=serve)
    warm = rng.integers(3, cfg.vocab_size, prompt_len).tolist()
    eng.submit(Request(999, warm, max_new_tokens=MAX_NEW))
    eng.run()
    best = (0, float("inf"))
    for rep in range(1, reps + 1):
        uid = 1000 + rep
        eng.submit(Request(
            uid, rng.integers(3, cfg.vocab_size, prompt_len).tolist(),
            max_new_tokens=MAX_NEW,
        ))
        ticks = 0
        t0 = time.perf_counter()
        while eng.sched.timing[uid].first_token < 0:
            eng.tick()
            ticks += 1
            if ticks > 10 * prompt_len:
                break
        sec = time.perf_counter() - t0
        eng.run()  # drain
        if sec < best[1]:
            best = (ticks, sec)
    return best


def _throughput(cfg, params, serve, n_req: int = 8) -> float:
    """tok/s over a mixed batch; the identical batch runs once un-timed on
    the same engine so compiles aren't billed."""
    eng = ServeEngine(cfg, params, serve=serve)

    def submit_all(offset):
        rng = np.random.default_rng(1)
        for u in range(n_req):
            plen = int(rng.integers(8, 48))
            eng.submit(Request(
                offset + u, rng.integers(3, cfg.vocab_size, plen).tolist(),
                max_new_tokens=16,
            ))

    submit_all(0)
    eng.run()  # warm every program shape
    submit_all(1000)
    t0 = time.perf_counter()
    before = sum(len(v) for v in eng.finished.values())
    eng.run()
    dt = time.perf_counter() - t0
    after = sum(len(v) for v in eng.finished.values())
    return (after - before) / dt


def run(csv_rows: list[str]) -> None:
    cfg, params = _setup()
    fused = dataclasses.replace(_serve_cfg(True, 1), prefill_impl="ss_fused")
    for plen in PROMPT_LENS:
        ticks_d, sec_d = _ttft(cfg, params, _serve_cfg(False, 1), plen)
        ticks_p, sec_p = _ttft(cfg, params, _serve_cfg(True, 1), plen)
        _, sec_f = _ttft(cfg, params, fused, plen)
        csv_rows.append(f"serve,prompt{plen},ttft_ticks_dense,{ticks_d}")
        csv_rows.append(f"serve,prompt{plen},ttft_ticks_paged,{ticks_p}")
        csv_rows.append(f"serve,prompt{plen},ttft_s_dense,{sec_d:.4f}")
        csv_rows.append(f"serve,prompt{plen},ttft_s_paged,{sec_p:.4f}")
        csv_rows.append(f"serve,prompt{plen},ttft_s_paged_ss_fused,{sec_f:.4f}")
        csv_rows.append(
            f"serve,prompt{plen},ttft_tick_speedup,{ticks_d / max(ticks_p, 1):.1f}"
        )
        csv_rows.append(
            f"serve,prompt{plen},ttft_wall_speedup,{sec_d / max(sec_p, 1e-9):.1f}"
        )
        csv_rows.append(
            f"serve,prompt{plen},ttft_wall_speedup_ss_fused,"
            f"{sec_d / max(sec_f, 1e-9):.1f}"
        )
    for lanes in (2, 4):
        tps_d = _throughput(cfg, params, _serve_cfg(False, lanes))
        tps_p = _throughput(cfg, params, _serve_cfg(True, lanes))
        csv_rows.append(f"serve,lanes{lanes},tok_per_s_dense,{tps_d:.1f}")
        csv_rows.append(f"serve,lanes{lanes},tok_per_s_paged,{tps_p:.1f}")


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("name,case,metric,value")
    print("\n".join(rows))
