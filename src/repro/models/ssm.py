"""Recurrent/state-space blocks: xLSTM (mLSTM + sLSTM) and Mamba (S6).

All cores are written in a chunk-parallel form (lax.scan over chunks,
parallel math inside a chunk) so training lowers to big MXU-friendly GEMMs
while decode is a single-step recurrence on a small carried state — the
sub-quadratic property that lets the ssm/hybrid archs run ``long_500k``
natively (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec

_CHUNK = 64


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x (B,S,C), w (W,C), b (C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


# ==========================================================================
# mLSTM (xLSTM matrix-memory cell), chunkwise-parallel
# ==========================================================================
def mlstm_chunked(
    q: jnp.ndarray,  # (B,H,S,Dh)
    k: jnp.ndarray,  # (B,H,S,Dh)
    v: jnp.ndarray,  # (B,H,S,Dh)
    ilog: jnp.ndarray,  # (B,H,S) input-gate pre-activation (log-space)
    flog: jnp.ndarray,  # (B,H,S) forget-gate log (log-sigmoid applied)
    state: tuple | None = None,
    chunk: int = _CHUNK,
    unroll: bool = False,
):
    """Stabilized chunk-parallel mLSTM. Returns (h (B,H,S,Dh), final_state).

    ``unroll=True`` fully unrolls the chunk scan (probe mode: XLA's
    cost_analysis counts while-loop bodies once, so the dry-run probe
    unrolls to see every chunk — identical math, identical per-step cost).
    """
    b, h, s, dh = q.shape
    k = k / (dh**0.5)
    pad = -s % chunk
    if pad:
        z = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ilog, flog = z(ilog), z(flog)
    nc = (s + pad) // chunk
    # (B,H,S,...) -> (nc, B, H, chunk, ...): nc leads for lax.scan.
    rs = lambda a: jnp.moveaxis(
        a.reshape(b, h, nc, chunk, *a.shape[3:]), 2, 0
    )
    qc, kc, vc = rs(q), rs(k), rs(v)
    ic = jnp.moveaxis(ilog.reshape(b, h, nc, chunk), 2, 0)
    fc = jnp.moveaxis(flog.reshape(b, h, nc, chunk), 2, 0)

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def chunk_step(carry, xs):
        c_prev, n_prev, m_prev = carry
        qb, kb, vb, ib, fb = xs  # each (B,H,chunk,...)
        fb32 = fb.astype(jnp.float32)
        ib32 = ib.astype(jnp.float32)
        csf = jnp.cumsum(fb32, axis=-1)  # (B,H,L) inclusive cumulative log-decay
        # Stabilizers.
        g = jax.lax.cummax(ib32 - csf, axis=ib32.ndim - 1)  # (B,H,L)
        m_new = jnp.maximum(m_prev[..., None] + csf, csf + g)  # (B,H,L)
        # Intra-chunk decay matrix D[s,r] = exp(csf_s - csf_r + i_r - m_s), r<=s.
        lw = (
            csf[..., :, None]
            - csf[..., None, :]
            + ib32[..., None, :]
            - m_new[..., :, None]
        )
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(mask, jnp.exp(lw), 0.0)  # (B,H,L,L)
        qk = jnp.einsum(
            "bhsd,bhrd->bhsr",
            qb.astype(jnp.float32),
            kb.astype(jnp.float32),
        )
        w = qk * dmat
        h_intra = jnp.einsum("bhsr,bhrd->bhsd", w, vb.astype(jnp.float32))
        inter = jnp.exp(m_prev[..., None] + csf - m_new)  # (B,H,L)
        h_inter = jnp.einsum(
            "bhde,bhse->bhsd", c_prev, qb.astype(jnp.float32)
        ) * inter[..., None]
        n_eff = (
            inter[..., None] * n_prev[..., None, :]
            + jnp.einsum("bhsr,bhrd->bhsd", dmat, kb.astype(jnp.float32))
        )
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhsd,bhsd->bhs", qb.astype(jnp.float32), n_eff)),
            jnp.exp(-m_new),
        )
        h_out = (h_intra + h_inter) / denom[..., None]
        # Chunk-final state.
        m_last = m_new[..., -1]
        tail = csf[..., -1:] - csf + ib32  # log weight of each r into final state
        wstate = jnp.exp(tail - m_last[..., None])  # (B,H,L)
        c_new = (
            jnp.exp(m_prev + csf[..., -1] - m_last)[..., None, None] * c_prev
            + jnp.einsum(
                "bhr,bhrd,bhre->bhde",
                wstate,
                vb.astype(jnp.float32),
                kb.astype(jnp.float32),
            )
        )
        n_new = (
            jnp.exp(m_prev + csf[..., -1] - m_last)[..., None] * n_prev
            + jnp.einsum("bhr,bhrd->bhd", wstate, kb.astype(jnp.float32))
        )
        return (c_new, n_new, m_last), h_out.astype(q.dtype)

    (c_f, n_f, m_f), hs = jax.lax.scan(
        chunk_step, (c0, n0, m0), (qc, kc, vc, ic, fc),
        unroll=nc if unroll else 1,
    )
    # hs: (nc, B, H, chunk, Dh) -> (B, H, S, Dh)
    hs = jnp.moveaxis(hs, 0, 2).reshape(b, h, nc * chunk, dh)[:, :, :s]
    return hs, (c_f, n_f, m_f)


def mlstm_step(q, k, v, ilog, flog, state):
    """Single-token mLSTM decode. q/k/v (B,H,Dh); ilog/flog (B,H)."""
    c_prev, n_prev, m_prev = state
    dh = q.shape[-1]
    k = k.astype(jnp.float32) / (dh**0.5)
    q, v = q.astype(jnp.float32), v.astype(jnp.float32)
    f32, i32 = flog.astype(jnp.float32), ilog.astype(jnp.float32)
    m_new = jnp.maximum(f32 + m_prev, i32)
    fprime = jnp.exp(f32 + m_prev - m_new)[..., None]
    iprime = jnp.exp(i32 - m_new)[..., None]
    c_new = fprime[..., None] * c_prev + iprime[..., None] * (
        v[..., :, None] * k[..., None, :]
    )
    n_new = fprime * n_prev + iprime * k
    num = jnp.einsum("bhde,bhe->bhd", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), jnp.exp(-m_new))
    return (num / den[..., None]), (c_new, n_new, m_new)


# ==========================================================================
# sLSTM (scalar-memory cell with exponential gating), recurrent
# ==========================================================================
def slstm_scan(
    x_gates: jnp.ndarray,  # (B,S,H,4,Dh) pre-activations for i,f,z,o from x
    r_w: jnp.ndarray,      # (H,4,Dh,Dh) block-diagonal recurrent weights
    state: tuple | None = None,
):
    """Recurrent sLSTM over time. Returns (h (B,S,H,Dh), final_state)."""
    b, s, h, _, dh = x_gates.shape
    if state is None:
        zeros = jnp.zeros((b, h, dh), jnp.float32)
        state = (zeros, zeros, jnp.full((b, h, dh), -1e30, jnp.float32), zeros)

    r_w32 = r_w.astype(jnp.float32)

    def step(carry, xg):
        c, n, m, hprev = carry  # each (B,H,Dh)
        rec = jnp.einsum("bhd,hgde->bhge", hprev, r_w32)  # (B,H,4,Dh)
        pre = xg.astype(jnp.float32) + rec
        il, fl, zl, ol = pre[:, :, 0], pre[:, :, 1], pre[:, :, 2], pre[:, :, 3]
        m_new = jnp.maximum(fl + m, il)
        i_p = jnp.exp(il - m_new)
        f_p = jnp.exp(fl + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(zl)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(ol) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    xs = x_gates.swapaxes(0, 1)  # (S,B,H,4,Dh)
    final, hs = jax.lax.scan(step, state, xs)
    return hs.swapaxes(0, 1).astype(x_gates.dtype), final


# ==========================================================================
# Mamba / S6 selective SSM, chunk-parallel
# ==========================================================================
def mamba_specs(d_model: int, d_inner: int, state: int, conv_width: int, dt_rank: int):
    return {
        "w_in": ParamSpec((d_model, 2 * d_inner), ("embed", "ff")),
        "conv_w": ParamSpec((conv_width, d_inner), (None, "ff"), scale=0.3),
        "conv_b": ParamSpec((d_inner,), ("ff",), init="zeros"),
        "w_bc": ParamSpec((d_inner, 2 * state), ("ff", None)),
        "w_dt": ParamSpec((d_inner, dt_rank), ("ff", None)),
        "w_dt_out": ParamSpec((dt_rank, d_inner), (None, "ff")),
        "b_dt": ParamSpec((d_inner,), ("ff",), init="zeros"),
        "a_log": ParamSpec((d_inner, state), ("ff", None), init="zeros"),
        "d_skip": ParamSpec((d_inner,), ("ff",), init="ones"),
        "w_out": ParamSpec((d_inner, d_model), ("ff", "embed")),
    }


def mamba_forward(p: dict, x: jnp.ndarray, state_dim: int, chunk: int = 256,
                  state: tuple | None = None, unroll: bool = False):
    """Selective SSM. x (B,S,D) -> (out (B,S,D), final_state)."""
    b, s, d = x.shape
    dt = x.dtype
    ui = x @ p["w_in"].astype(dt)  # (B,S,2*di)
    di = ui.shape[-1] // 2
    u, z = ui[..., :di], ui[..., di:]
    conv_state_in = None if state is None else state[1]
    if conv_state_in is not None:
        width = p["conv_w"].shape[0]
        ctx = jnp.concatenate([conv_state_in.astype(dt), u], axis=1)
        u_conv = _causal_conv(ctx, p["conv_w"], p["conv_b"])[:, width - 1 :]
        conv_state = ctx[:, -(width - 1) :]
    else:
        u_conv = _causal_conv(u, p["conv_w"], p["conv_b"])
        width = p["conv_w"].shape[0]
        conv_state = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))[:, -(width - 1):]
    u_conv = jax.nn.silu(u_conv)

    bc = u_conv @ p["w_bc"].astype(dt)  # (B,S,2*state)
    b_mat, c_mat = bc[..., :state_dim], bc[..., state_dim:]
    dt_pre = (u_conv @ p["w_dt"].astype(dt)) @ p["w_dt_out"].astype(dt)
    delta = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["b_dt"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, state), negative

    # Per-step transition a_t = exp(delta_t * A) and input b_t = delta_t*B_t*u_t.
    da = delta[..., None] * a  # (B,S,di,state)
    # abar/bbar are the memory giants of the selective scan ((B,S,di,N) —
    # ~30 ops x 0.84 GB/device on hymba train_4k, §Perf cell C). They are
    # computed in fp32 but STORED in the compute dtype; the chunk recurrence
    # upcasts again, so only the HBM-resident copies shrink (exact no-op
    # when compute dtype is fp32, as in the CPU tests).
    abar = jnp.exp(da).astype(dt)
    bbar = (
        delta[..., None]
        * b_mat.astype(jnp.float32)[..., None, :]
        * u_conv.astype(jnp.float32)[..., None]
    ).astype(dt)  # (B,S,di,state)

    h0 = (
        jnp.zeros((b, di, state_dim), jnp.float32)
        if state is None
        else state[0]
    )
    pad = -s % chunk
    if pad:
        abar = jnp.pad(abar, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bbar = jnp.pad(bbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk
    abar = abar.reshape(b, nc, chunk, di, state_dim).swapaxes(0, 1)
    bbar = bbar.reshape(b, nc, chunk, di, state_dim).swapaxes(0, 1)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h_prev, xs):
        ac, bc_ = xs  # (B,L,di,state), stored dtype
        ac = ac.astype(jnp.float32)
        bc_ = bc_.astype(jnp.float32)
        acum, bcum = jax.lax.associative_scan(assoc, (ac, bc_), axis=1)
        hs = acum * h_prev[:, None] + bcum  # (B,L,di,state) fp32
        return hs[:, -1], hs

    h_final, hs = jax.lax.scan(
        chunk_step, h0, (abar, bbar), unroll=nc if unroll else 1
    )
    hs = hs.swapaxes(0, 1).reshape(b, nc * chunk, di, state_dim)[:, :s]
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_mat.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * u_conv.astype(jnp.float32)
    out = (y.astype(dt) * jax.nn.silu(z)) @ p["w_out"].astype(dt)
    return out, (h_final, conv_state)
