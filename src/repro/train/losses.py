"""Loss functions shared by the model zoo and the trainer.

``next_token_loss`` is the canonical LM objective: masked next-token cross
entropy in fp32, with optional z-loss (logit-norm regularizer, stabilizes
bf16 training at scale) and label smoothing. ``model.loss_fn`` delegates
here so every family uses identical numerics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(
    logits: jnp.ndarray,   # (B, S, V) — positions 0..S-1 predict 1..S
    tokens: jnp.ndarray,   # (B, S) int32; 0 = pad
    *,
    z_loss: float = 0.0,
    label_smoothing: float = 0.0,
) -> tuple[jnp.ndarray, dict]:
    """Masked next-token CE. Returns (loss, metrics)."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    ce_tok = logz - gold
    if label_smoothing:
        # Uniform smoothing: (1-eps)*gold + eps*mean over vocab.
        mean_lp = jnp.mean(lg, axis=-1) - logz
        ce_tok = (1 - label_smoothing) * ce_tok - label_smoothing * mean_lp
    mask = (targets != 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = jnp.sum(ce_tok * mask) / denom
    metrics = {"ce": ce, "tokens": denom}
    loss = ce
    if z_loss:
        zl = jnp.sum(jnp.square(logz) * mask) / denom
        loss = loss + z_loss * zl
        metrics["z_loss"] = zl
    metrics["ppl_proxy"] = jnp.exp(jnp.minimum(ce, 20.0))
    return loss, metrics
