"""Batched serving with continuous batching over the SS decode path.

Submits a bursty stream of requests (staggered arrivals, mixed lengths) to
the lane-based engine and reports throughput + per-request latency.

    PYTHONPATH=src python examples/serve_batched.py [--lanes 4] [--requests 12]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=160)
    ap.add_argument("--decode-impl", default="spectral_shift",
                    choices=["full", "spectral_shift"])
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(get_config(args.arch)),
        decode_attention_impl=args.decode_impl, num_landmarks=16,
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_lanes=args.lanes,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    arrivals = {}  # uid -> tick of arrival
    done_at = {}
    pending = list(range(args.requests))
    t0 = time.time()
    tick = 0
    while pending or engine.stats()["active"] or engine.stats()["queued"]:
        # Bursty arrivals: ~1/3 chance of a new request per tick.
        if pending and (tick % 3 == 0):
            uid = pending.pop(0)
            plen = int(rng.integers(4, 24))
            engine.submit(Request(
                uid, rng.integers(3, cfg.vocab_size, plen).tolist(),
                max_new_tokens=int(rng.integers(8, 32)),
            ))
            arrivals[uid] = tick
        before = set(engine.finished)
        engine.tick()
        for uid in set(engine.finished) - before:
            done_at[uid] = tick
        tick += 1
        if tick > 20_000:
            break
    dt = time.time() - t0

    total_tokens = sum(len(v) for v in engine.finished.values())
    lat = [done_at[u] - arrivals[u] for u in done_at]
    print(f"[serve_batched] impl={args.decode_impl} lanes={args.lanes}")
    print(f"  {len(engine.finished)}/{args.requests} finished, "
          f"{total_tokens} new tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s)")
    print(f"  latency ticks: p50={int(np.median(lat))} "
          f"p95={int(np.percentile(lat, 95))}")


if __name__ == "__main__":
    main()
