"""Context-parallel fused attention: shard_map driver equivalence tests.

Subprocess-based (4 fake host devices, same mechanism as test_multidevice):
the sharded-fused forward/backward must match both the single-device fused
kernels and the jnp-GSPMD route, including ragged final shards and
``remat="ss_stats"`` under sequence parallelism, and
``apply_seq_sharding_config`` must no longer downgrade seq-sharded cells to
the jnp backend.
"""
from __future__ import annotations

import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_sharded_fused_forward_matches_fused_and_jnp():
    """Forward parity on a 4-way sequence shard: vs the single-device fused
    kernels and vs the jnp route run under GSPMD input shardings, causal and
    bidirectional, even and ragged lengths."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.attention import SSConfig, spectral_shift_attention
from repro.kernels.ops import ss_attention_fused
from repro.kernels.sharded import ss_attention_fused_sharded

mesh = jax.make_mesh((4,), ("data",))
rel = lambda a, b: float(np.max(
    np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
    / np.maximum(np.abs(np.asarray(b, np.float32)), 1e-3)))
# 250: ragged last shard; (384, bn=64): 96-key shards pad 32 zero keys
# inside the kernel (regression: the pad must not leak past the global
# valid bound on non-final shards).
for n, causal, bn in [(256, False, 512), (256, True, 512), (250, True, 512),
                      (384, True, 64)]:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, n, 32)) * 0.5
    k = jax.random.normal(ks[1], (2, n, 32)) * 0.5
    v = jax.random.normal(ks[2], (2, n, 32))
    cfg = SSConfig(num_landmarks=16, causal=causal, landmark_via_matmul=True)
    f = jax.jit(lambda q, k, v: ss_attention_fused_sharded(
        q, k, v, cfg, mesh=mesh, seq_axes=("data",), block_n=bn,
        interpret=True))
    out = f(q, k, v)
    r1 = rel(out, ss_attention_fused(q, k, v, cfg, interpret=True))
    if n % 4 == 0:
        # jnp route under GSPMD: seq-sharded inputs, same mesh (GSPMD
        # placement needs even divisibility; ragged covers the jnp ref
        # through the single-device fused comparison above).
        sh = NamedSharding(mesh, P(None, "data", None))
        ref = jax.jit(
            lambda q, k, v: spectral_shift_attention(q, k, v, cfg),
            in_shardings=(sh, sh, sh),
        )(*(jax.device_put(x, sh) for x in (q, k, v)))
    else:
        ref = spectral_shift_attention(q, k, v, cfg)
    r2 = rel(out, ref)
    assert r1 < 1e-3 and r2 < 1e-3, (n, causal, r1, r2)
print('OK')
""", num_devices=4)


@pytest.mark.slow
def test_sharded_fused_grad_matches_jnp():
    """jax.grad through the sharded custom-VJP ops == jnp-route grads."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.attention import SSConfig, spectral_shift_attention
from repro.kernels.sharded import ss_attention_fused_sharded

mesh = jax.make_mesh((4,), ("data",))
rel = lambda a, b: float(np.max(
    np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
    / np.maximum(np.abs(np.asarray(b, np.float32)), 1e-3)))
for n, causal in [(256, False), (250, True)]:
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (2, n, 32)) * 0.5
    k = jax.random.normal(ks[1], (2, n, 32)) * 0.5
    v = jax.random.normal(ks[2], (2, n, 32))
    w = jax.random.normal(ks[3], (2, n, 32))
    cfg = SSConfig(num_landmarks=16, causal=causal, landmark_via_matmul=True)
    g_sp = jax.jit(jax.grad(lambda q, k, v: jnp.sum(ss_attention_fused_sharded(
        q, k, v, cfg, mesh=mesh, seq_axes=("data",), interpret=True) * w),
        argnums=(0, 1, 2)))(q, k, v)
    g_jnp = jax.grad(lambda q, k, v: jnp.sum(
        spectral_shift_attention(q, k, v, cfg) * w), argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", g_sp, g_jnp):
        r = rel(a, b)
        assert r < 1e-2, (n, causal, name, r)
print('OK')
""", num_devices=4)


@pytest.mark.slow
def test_sharded_remat_ss_stats_parity():
    """remat='ss_stats' under SP: the sharded ops' tagged residuals survive
    the checkpoint policy and gradients are bit-identical to no-remat."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.attention import SSConfig
from repro.kernels.sharded import ss_attention_fused_sharded

mesh = jax.make_mesh((4,), ("data",))
ks = jax.random.split(jax.random.PRNGKey(2), 3)
q = jax.random.normal(ks[0], (2, 192, 32)) * 0.5
k = jax.random.normal(ks[1], (2, 192, 32)) * 0.5
v = jax.random.normal(ks[2], (2, 192, 32))
cfg = SSConfig(num_landmarks=16, causal=True, landmark_via_matmul=True)
def loss(q, k, v):
    return jnp.sum(ss_attention_fused_sharded(
        q, k, v, cfg, mesh=mesh, seq_axes=("data",), interpret=True) ** 2)
remat_loss = jax.checkpoint(
    loss, policy=jax.checkpoint_policies.save_only_these_names(
        "ss_bv", "ss_stats"))
g0 = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
g1 = jax.jit(jax.grad(remat_loss, argnums=(0, 1, 2)))(q, k, v)
for a, b in zip(g0, g1):
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
print('OK')
""", num_devices=4)


@pytest.mark.slow
def test_seq_sharding_config_keeps_fused_backend():
    """apply_seq_sharding_config no longer rewrites attention_backend/remat
    for seq-sharded cells (the dispatch registry routes them through the
    shard_map driver); seq_shard_fused=False restores the legacy downgrade.
    Also checks the mesh-aware dispatch key resolution."""
    run_subprocess("""
import jax
from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.distributed.sharding import (
    active_seq_sharding, apply_seq_sharding_config, sharding_rules,
)
from repro.kernels import dispatch
import dataclasses

mesh = jax.make_mesh((4,), ("data",))
cfg = reduced(
    get_config("qwen2-7b"),
    attention_impl="spectral_shift_fused",
    attention_backend="auto",
    remat="ss_stats",
)
out = apply_seq_sharding_config(cfg, mesh, {"seq": "data"})
assert out.attention_backend == "auto", out.attention_backend
assert out.landmark_via_matmul
# This test process runs on the CPU backend, whose auto heuristic routes
# context-parallel cells to jnp (no tagged residuals): remat is widened
# explicitly there. A forced kernel backend keeps ss_stats untouched.
assert out.remat == "full", out.remat
forced = apply_seq_sharding_config(
    dataclasses.replace(cfg, attention_backend="interpret"), mesh,
    {"seq": "data"})
assert forced.attention_backend == "interpret"
assert forced.remat == "ss_stats", forced.remat

legacy = apply_seq_sharding_config(
    dataclasses.replace(cfg, seq_shard_fused=False), mesh, {"seq": "data"})
assert legacy.attention_backend == "jnp"
assert legacy.remat == "full"

with mesh, sharding_rules(mesh, {"seq": "data"}):
    m, seq_axes, lead_axes = active_seq_sharding()
    assert seq_axes == ("data",), seq_axes
    assert "data" not in lead_axes
key = dispatch.make_key(4096, 64, 64, "bfloat16", True, backend="tpu",
                        seq_shards=4)
assert dispatch.heuristic_plan(key).impl == "sharded"
assert dispatch.PlanKey.decode(key.encode()) == key
print('OK')
""", num_devices=4)


@pytest.mark.slow
def test_sp_trainer_matches_single_device():
    """End to end: a Trainer on a seq-sharded mesh keeps the fused backend
    and remat='ss_stats', routes through the shard_map kernels, and after 2
    steps its params match single-device training."""
    run_subprocess("""
import jax, numpy as np, tempfile
from repro.configs.base import ShapeConfig, TrainConfig, reduced
from repro.configs.registry import get_config
from repro.train.trainer import Trainer

cfg = reduced(
    get_config("qwen2-7b"),
    attention_impl="spectral_shift_fused",
    attention_backend="interpret",   # force the kernel route on CPU
    remat="ss_stats",
    num_landmarks=8,
)
shape = ShapeConfig("train_4k", 64, 4, "train")
results = []
for mesh_shape, overrides in [((1, 1), {}), ((2, 4), {"seq": "model"})]:
    devs = np.array(jax.devices()[: mesh_shape[0] * mesh_shape[1]]).reshape(
        mesh_shape)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, TrainConfig(checkpoint_dir=d, seed=0), shape, mesh,
                     rule_overrides=overrides)
        assert tr.cfg.attention_backend == "interpret", tr.cfg.attention_backend
        assert tr.cfg.remat == "ss_stats", tr.cfg.remat
        hist = tr.run(2, log_every=100)
        assert all(abs(h["loss"]) < 100 for h in hist)
        results.append([np.asarray(x, np.float32)
                        for x in jax.tree.leaves(tr.params)])
for a, b in zip(*results):
    np.testing.assert_allclose(a, b, atol=2e-4)
print('OK')
""", num_devices=8, timeout=900)
