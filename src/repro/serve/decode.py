"""Single-token decode step against a KV cache, with the paper's
spectral-shifting approximation as the decode-attention engine.

Decode is the setting where the method applies *exactly* (a single query
attending to all past keys has no causal-mask conflict, DESIGN.md §2.4).
Landmark means are maintained incrementally in the cache as running sums;
per-landmark counts derive from ``pos`` so nothing goes stale.

For each layer the spectral-shift decode computes

    F = L(q K~^T)          (B,H,1,c)     O(c d)
    A = L(Q~ K~^T)         (B,H,c,c)     O(c^2 d)
    B = L(Q~ K_cache^T)    (B,H,c,S)     O(c S d)   <- the linear term
    out = F U_ss (B V) + delta * v_new

Empty landmarks (segments not yet reached) are masked out of F/B and pinned
to identity rows/cols of A so the pseudoinverse is well-posed.

``ModelConfig.decode_streaming`` selects how the linear term is obtained:
``"recompute"`` is the O(c*S*d)-per-token path above; ``"exact"``/``"frozen"``
stream per-landmark online-softmax stats carried in the cache instead
(serve/decode_state.py) — same output formula, the B/BV rebuild replaced by
an O(c*d) flash-append plus (exact mode) a single-row recompute.

Gather-free paged decode (``ServeConfig.decode_impl="paged"``): when
``decode_step`` receives ``paged_table``/``paged_meta``, the seq-shaped
cache leaves ARE the shared block pools (broadcast unbatched through the
engine's lane vmap; layout ``(..., num_blocks, block_size, ...)`` with the
block pair sitting where ``cache_seq`` was). Attention layers then

* never write the pools — each layer returns the new token's K/V (seq axis
  of length 1) and ``PagedKVCache.make_paged_step`` commits it with a
  single-block scatter after the step;
* read the horizon (exact-mode active row, ``full`` decode attention) only
  through the block-table Pallas kernel (kernels/paged_decode.py), whose
  partials over keys ``0..pos-1`` are flash-merged with the current token.

``decode_streaming="frozen"`` ticks therefore touch no horizon bytes at
all; ``"recompute"`` needs the dense B matrix and stays on the gather
route (the engine enforces the fallback).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.spectral_shift import ss_core
from repro.models.layers import (
    apply_rotary,
    layer_norm,
    mlp_forward,
    rms_norm,
    rotary_angles,
    sinusoidal_positions,
)
from repro.models.model import _embed_tokens, _unembed
from repro.models.moe import moe_forward
from repro.models.ssm import mlstm_step
from repro.models.attention import _broadcast_kv
from repro.serve.decode_state import (
    STREAM_LEAVES,
    landmark_counts,
    landmark_means,
    lmk_add,
    masked_softmax as _masked_softmax,
    segment_len,
    ss_decode_attention_streaming,
)

Cache = Any

# Landmark bookkeeping now lives in serve/decode_state.py (backed by the
# shared core/landmarks helpers); these aliases keep the historical import
# surface of this module intact.
_segment_len = segment_len
_landmark_counts = landmark_counts
_lmk_add = lmk_add


def ss_decode_attention(
    q: jnp.ndarray,        # (B, H, 1, d)
    k_cache: jnp.ndarray,  # (B, H, S, d)   (kv heads already broadcast)
    v_cache: jnp.ndarray,  # (B, H, S, dv)
    q_lmk_sum: jnp.ndarray,  # (B, H, c, d)
    k_lmk_sum: jnp.ndarray,  # (B, H, c, d)
    pos: jnp.ndarray,      # scalar int32: index of the current token
    cfg: ModelConfig,
    scale: float,
    seq_max: int | None = None,  # landmark segmentation horizon; defaults to
                                 # the cache view length. Batched prefill
                                 # passes the lane's full max_seq so segment
                                 # routing matches later decode steps even
                                 # though its K/V view is only prompt-long.
) -> jnp.ndarray:
    s_max = k_cache.shape[2]  # view length; the landmark horizon may differ
    c = q_lmk_sum.shape[2]
    horizon = s_max if seq_max is None else seq_max
    counts = _landmark_counts(pos, horizon, c)  # (c,) fp32
    valid = counts > 0
    q_l = landmark_means(q_lmk_sum, counts)
    k_l = landmark_means(k_lmk_sum, counts)

    f = _masked_softmax(
        jnp.einsum("bhqd,bhcd->bhqc", q.astype(jnp.float32), k_l) * scale,
        valid[None, None, None, :],
    )  # (B,H,1,c)
    a_mask = valid[None, None, :, None] & valid[None, None, None, :]
    a_raw = _masked_softmax(
        jnp.einsum("bhcd,bhed->bhce", q_l, k_l) * scale, a_mask
    )
    eye = jnp.eye(c, dtype=jnp.float32)
    a = jnp.where(a_mask, a_raw, eye)  # invalid block pinned to identity
    key_mask = (jnp.arange(s_max) <= pos)[None, None, None, :]
    b_mat = _masked_softmax(
        jnp.einsum("bhcd,bhsd->bhcs", q_l, k_cache.astype(jnp.float32)) * scale,
        key_mask,
    )  # (B,H,c,S)

    core = ss_core(
        a, method="iterative", pinv_iters=cfg.pinv_iters,
        use_shift=cfg.include_shift_identity,
    )
    bv = jnp.einsum("bhcs,bhsd->bhcd", b_mat, v_cache.astype(jnp.float32))
    out = jnp.einsum(
        "bhqc,bhcd->bhqd", f, jnp.einsum("bhce,bhed->bhcd", core.u, bv)
    )
    if cfg.include_shift_identity:
        v_new = jnp.take_along_axis(
            v_cache, jnp.broadcast_to(
                pos, (*v_cache.shape[:2], 1, 1)
            ).astype(jnp.int32), axis=2,
        ).astype(jnp.float32)
        out = out + core.delta * v_new
    return out.astype(q.dtype)


def full_decode_attention(q, k_cache, v_cache, pos, scale):
    s_max = k_cache.shape[2]
    scores = jnp.einsum(
        "bhqd,bhsd->bhqs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    mask = (jnp.arange(s_max) <= pos)[None, None, None, :]
    p = _masked_softmax(scores, mask)
    return jnp.einsum("bhqs,bhsd->bhqd", p, v_cache.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------------------------------
# Gather-free paged horizon reads (kernels/paged_decode.py). ``paged`` is
# the per-layer route descriptor ``(table, block_size, interpret)``: the
# traced (n_slots,) int32 block table plus the static kernel knobs.
# --------------------------------------------------------------------------
def _paged_merged_stats(q_g, k_pools, v_pool, k_new_g, v_new_g, paged, pos,
                        scale):
    """Exact softmax partials of rows ``q_g`` (hkv, R, d) over keys
    ``0..pos``: the kernel streams the pools (which hold keys 0..pos-1 —
    the tick commits the new token after the step), the current token is
    flash-merged on top."""
    from repro.kernels.ops import flash_merge
    from repro.kernels.paged_decode import paged_row_stats

    table, block_size, interpret = paged
    m, l, acc = paged_row_stats(
        q_g, k_pools, v_pool, table, pos, scale=scale,
        block_size=block_size, interpret=interpret,
    )
    s_new = jnp.einsum(
        "hrd,hd->hr", q_g.astype(jnp.float32), k_new_g.astype(jnp.float32)
    )[..., None] * scale                                   # (hkv, R, 1)
    return flash_merge(
        m, l, acc, s_new, jnp.ones_like(s_new),
        v_new_g[:, None, :].astype(jnp.float32),
    )


def _paged_active_stats_fn(k_pools, v_pool, k_new_g, v_new_g, paged, pos,
                           scale):
    """The ``active_stats_fn`` hook for ``ss_decode_attention_streaming``:
    one-row exact recompute through the block-table kernel. ``k_new_g`` /
    ``v_new_g`` are the current token's key/value with RAW kv heads
    (hkv, d) / (hkv, dv)."""
    hkv = v_pool.shape[0]

    def fn(q_act):  # (B=1, H, 1, d) active landmark means
        b, h = q_act.shape[:2]
        q_g = q_act.reshape(b, hkv, h // hkv, q_act.shape[-1])[0]
        m, l, acc = _paged_merged_stats(
            q_g, k_pools, v_pool, k_new_g, v_new_g, paged, pos, scale,
        )
        return (
            m.reshape(b, h, 1, 1),
            l.reshape(b, h, 1, 1),
            acc.reshape(b, h, 1, acc.shape[-1]),
        )

    return fn


def full_decode_attention_paged(q, k_pools, v_pool, k_new_g, v_new_g, paged,
                                pos, scale):
    """Exact decode attention (one query row per head) straight from the
    block pools — the gather-free form of ``full_decode_attention``, which
    also covers the degenerate <=c regime where spectral shifting reduces
    to exact attention. ``q`` (B=1, H, 1, d); output (B, H, 1, dv)."""
    b, h = q.shape[:2]
    hkv = v_pool.shape[0]
    q_g = q.astype(jnp.float32).reshape(b, hkv, h // hkv, q.shape[-1])[0]
    m, l, acc = _paged_merged_stats(
        q_g, k_pools, v_pool, k_new_g, v_new_g, paged, pos, scale,
    )
    out = acc / jnp.maximum(l, 1e-30)                      # (hkv, G, dv)
    return out.reshape(b, h, 1, out.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# per-layer decode
# --------------------------------------------------------------------------
def _update_seq(cache_arr, new, pos):
    """cache (B,H,S,D) <- new (B,H,1,D) at position pos."""
    return jax.lax.dynamic_update_slice(
        cache_arr, new.astype(cache_arr.dtype), (0, 0, pos, 0)
    )


def gqa_decode(p, cfg: ModelConfig, x, cache, pos, impl, seq_max=None,
               paged=None):
    """x (B,1,D); cache {k,v,q_lmk,k_lmk}. Returns (attn_out, new_cache).

    ``seq_max`` pins the landmark segmentation horizon when the cache view
    is shorter than the lane's logical sequence (paged short views).

    ``paged`` = (table, block_size, interpret) flips the gather-free route:
    ``cache["k"]``/``cache["v"]`` are the shared block pools
    (B=1, hkv, nb, bs, d) — never written here; ``new_cache`` returns the
    NEW TOKEN's k/v (seq length 1) for the tick's single-block scatter
    commit, and horizon reads go through the block-table kernel."""
    dt = x.dtype
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhe->bhse", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhe->bhse", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhe->bhse", x, p["w_v"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)[None, :, None, :]
        k = k + p["b_k"].astype(dt)[None, :, None, :]
        v = v + p["b_v"].astype(dt)[None, :, None, :]
    if cfg.rope_theta > 0:
        sin, cos = rotary_angles(pos[None, None], dh, cfg.rope_theta)
        q = apply_rotary(q, sin[None], cos[None])
        k = apply_rotary(k, sin[None], cos[None])

    new_cache = dict(cache)
    if paged is None:
        s_max = cache["k"].shape[2] if seq_max is None else seq_max
        new_cache["k"] = _update_seq(cache["k"], k, pos)
        new_cache["v"] = _update_seq(cache["v"], v, pos)
    else:
        if seq_max is None:
            raise ValueError("paged decode requires an explicit seq_max")
        s_max = seq_max
        new_cache["k"], new_cache["v"] = k, v  # new-token commits
    new_cache["q_lmk"] = _lmk_add(cache["q_lmk"], q[:, :, 0], pos, s_max)
    new_cache["k_lmk"] = _lmk_add(cache["k_lmk"], k[:, :, 0], pos, s_max)

    scale = dh**-0.5
    if paged is not None:
        k_pools, v_pool = (cache["k"][0],), cache["v"][0]  # (hkv, nb, bs, d)
        k_new_g, v_new_g = k[0, :, 0], v[0, :, 0]          # raw kv heads
    if impl == "spectral_shift":
        k_lmk = _broadcast_kv(new_cache["k_lmk"], cfg.num_heads)
        if cfg.decode_streaming == "recompute":
            if paged is not None:
                raise ValueError(
                    "decode_streaming='recompute' rebuilds the dense B "
                    "matrix and is only served by the gather route"
                )
            kb = _broadcast_kv(new_cache["k"], cfg.num_heads)
            vb = _broadcast_kv(new_cache["v"], cfg.num_heads)
            out = ss_decode_attention(
                q, kb, vb, new_cache["q_lmk"], k_lmk, pos, cfg, scale,
                seq_max=s_max,
            )
        else:
            k_new = _broadcast_kv(k, cfg.num_heads)[:, :, 0]  # (B, H, d)
            v_new = _broadcast_kv(v, cfg.num_heads)[:, :, 0]
            stats = tuple(cache[name] for name in STREAM_LEAVES)
            if paged is None:
                kc, vc, stats_fn = new_cache["k"], new_cache["v"], None
            else:
                kc = vc = None
                stats_fn = _paged_active_stats_fn(
                    k_pools, v_pool, k_new_g, v_new_g, paged, pos, scale,
                )
            out, new_stats = ss_decode_attention_streaming(
                q, k_new, v_new, kc, vc,
                new_cache["q_lmk"], k_lmk, stats,
                pos, cfg, scale, seq_max=s_max, mode=cfg.decode_streaming,
                active_stats_fn=stats_fn,
            )
            new_cache.update(dict(zip(STREAM_LEAVES, new_stats)))
    elif paged is not None:
        out = full_decode_attention_paged(
            q, k_pools, v_pool, k_new_g, v_new_g, paged, pos, scale,
        )
    else:
        kb = _broadcast_kv(new_cache["k"], cfg.num_heads)
        vb = _broadcast_kv(new_cache["v"], cfg.num_heads)
        out = full_decode_attention(q, kb, vb, pos, scale)
    return jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(dt)), new_cache


def mla_decode(p, cfg: ModelConfig, x, cache, pos, impl, seq_max=None,
               paged=None):
    """Absorbed MLA decode: attention runs in the (kv_lora + rope) latent
    space; values are the latents, up-projected after mixing.

    The gather-free ``paged`` route reads the latent and rope pools as two
    separate key pools (scores accumulate per pool inside the kernel — the
    O(S) ``concat`` of the dense path never materializes) with the latent
    pool doubling as the value pool."""
    dt = x.dtype
    dh, dr, r = cfg.resolved_head_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    c_kv = rms_norm(x @ p["w_dkv"].astype(dt), p["norm_kv"], cfg.norm_eps)  # (B,1,r)
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_k_rope"].astype(dt))  # (B,1,dr)
    sin, cos = rotary_angles(pos[None, None], dr, cfg.rope_theta)
    k_rope = apply_rotary(k_rope[:, None], sin[None], cos[None])[:, 0]

    q_nope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_nope"].astype(dt))
    q_rope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_rope"].astype(dt))
    q_rope = apply_rotary(q_rope, sin[None], cos[None])
    q_abs = jnp.einsum("bhse,rhe->bhsr", q_nope, p["w_uk"].astype(dt))
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)  # (B,H,1,r+dr)

    new_cache = dict(cache)
    if paged is None:
        new_cache["latent"] = jax.lax.dynamic_update_slice(
            cache["latent"], c_kv.astype(cache["latent"].dtype), (0, pos, 0)
        )
        new_cache["rope"] = jax.lax.dynamic_update_slice(
            cache["rope"], k_rope.astype(cache["rope"].dtype), (0, pos, 0)
        )
        s_max = cache["latent"].shape[1] if seq_max is None else seq_max
    else:
        if seq_max is None:
            raise ValueError("paged decode requires an explicit seq_max")
        new_cache["latent"], new_cache["rope"] = c_kv, k_rope  # new token
        s_max = seq_max
    k_eff_new = jnp.concatenate([c_kv, k_rope], axis=-1)[:, 0]  # (B, r+dr)
    new_cache["k_lmk"] = _lmk_add(cache["k_lmk"], k_eff_new, pos, s_max)
    new_cache["q_lmk"] = _lmk_add(cache["q_lmk"], q_eff[:, :, 0], pos, s_max)

    scale = (dh + dr) ** -0.5
    h = cfg.num_heads
    b = x.shape[0]
    if paged is None:
        k_eff = jnp.concatenate(
            [new_cache["latent"], new_cache["rope"]], axis=-1
        )[:, None]  # (B,1,S,r+dr)
        lat = new_cache["latent"][:, None]  # (B,1,S,r) as values
    else:
        # hkv=1 pools: latent (1, nb, bs, r), rope (1, nb, bs, dr); the
        # latent pool doubles as the value pool (absorbed MLA).
        k_pools = (cache["latent"][0][None], cache["rope"][0][None])
        v_pool = k_pools[0]
        k_new_g = k_eff_new[0][None]                        # (1, r+dr)
        v_new_g = c_kv[0, 0][None]                          # (1, r)
    if impl == "spectral_shift":
        k_lmk = jnp.broadcast_to(
            new_cache["k_lmk"][:, None], new_cache["q_lmk"].shape[:2] + new_cache["k_lmk"].shape[1:]
        )
        if cfg.decode_streaming == "recompute":
            if paged is not None:
                raise ValueError(
                    "decode_streaming='recompute' rebuilds the dense B "
                    "matrix and is only served by the gather route"
                )
            k_eff_b = jnp.broadcast_to(
                k_eff, (k_eff.shape[0], h, *k_eff.shape[2:])
            )
            lat_b = jnp.broadcast_to(lat, (lat.shape[0], h, *lat.shape[2:]))
            out_lat = ss_decode_attention(
                q_eff, k_eff_b, lat_b, new_cache["q_lmk"], k_lmk, pos, cfg,
                scale, seq_max=s_max,
            )
        else:
            k_new = jnp.broadcast_to(
                k_eff_new[:, None], (b, h, k_eff_new.shape[-1])
            )
            v_new = jnp.broadcast_to(c_kv[:, 0][:, None], (b, h, r))
            stats = tuple(cache[name] for name in STREAM_LEAVES)
            if paged is None:
                kc, vc, stats_fn = k_eff, lat, None
            else:
                kc = vc = None
                stats_fn = _paged_active_stats_fn(
                    k_pools, v_pool, k_new_g, v_new_g, paged, pos, scale,
                )
            out_lat, new_stats = ss_decode_attention_streaming(
                q_eff, k_new, v_new, kc, vc, new_cache["q_lmk"],
                k_lmk, stats, pos, cfg, scale, seq_max=s_max,
                mode=cfg.decode_streaming, active_stats_fn=stats_fn,
            )
            new_cache.update(dict(zip(STREAM_LEAVES, new_stats)))
    elif paged is not None:
        out_lat = full_decode_attention_paged(
            q_eff, k_pools, v_pool, k_new_g, v_new_g, paged, pos, scale,
        )
    else:
        k_eff_b = jnp.broadcast_to(k_eff, (k_eff.shape[0], h, *k_eff.shape[2:]))
        lat_b = jnp.broadcast_to(lat, (lat.shape[0], h, *lat.shape[2:]))
        out_lat = full_decode_attention(q_eff, k_eff_b, lat_b, pos, scale)
    out = jnp.einsum("bhsr,rhe->bhse", out_lat, p["w_uv"].astype(dt))
    return jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(dt)), new_cache


def mamba_decode(p, cfg: ModelConfig, x, state):
    """Single-step mamba. x (B,1,D); state {ssm_h (B,di,n), conv (B,w-1,di)}."""
    dt = x.dtype
    ui = x[:, 0] @ p["w_in"].astype(dt)  # (B, 2di)
    di = ui.shape[-1] // 2
    u, z = ui[..., :di], ui[..., di:]
    width = p["conv_w"].shape[0]
    ctx = jnp.concatenate([state["conv"].astype(dt), u[:, None]], axis=1)  # (B,w,di)
    u_conv = jnp.einsum("bwd,wd->bd", ctx, p["conv_w"].astype(dt)) + p["conv_b"].astype(dt)
    u_conv = jax.nn.silu(u_conv)
    bc = u_conv @ p["w_bc"].astype(dt)
    n = cfg.ssm_state
    b_mat, c_mat = bc[..., :n], bc[..., n:]
    dt_pre = (u_conv @ p["w_dt"].astype(dt)) @ p["w_dt_out"].astype(dt)
    delta = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["b_dt"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    abar = jnp.exp(delta[..., None] * a)  # (B,di,n)
    bbar = delta[..., None] * b_mat.astype(jnp.float32)[:, None, :] * u_conv.astype(jnp.float32)[..., None]
    h_new = abar * state["ssm_h"] + bbar
    y = jnp.einsum("bdn,bn->bd", h_new, c_mat.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * u_conv.astype(jnp.float32)
    out = (y.astype(dt) * jax.nn.silu(z)) @ p["w_out"].astype(dt)
    return out[:, None], {"ssm_h": h_new, "conv": ctx[:, 1:]}


def mlstm_block_decode(p, cfg: ModelConfig, x, state):
    b = x.shape[0]
    h = cfg.num_heads
    dt = x.dtype
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    up = xn[:, 0] @ p["w_up"].astype(dt)  # (B, 2di)
    di = up.shape[-1] // 2
    xm, z = up[..., :di], up[..., di:]
    ctx = jnp.concatenate([state["conv"].astype(dt), xm[:, None]], axis=1)
    xc = jnp.einsum("bwd,wd->bd", ctx, p["conv_w"].astype(dt)) + p["conv_b"].astype(dt)
    xc = jax.nn.silu(xc)
    to_heads = lambda a: a.reshape(b, h, di // h)
    q = to_heads(xc @ p["w_q"].astype(dt))
    k = to_heads(xc @ p["w_k"].astype(dt))
    v = to_heads(xm @ p["w_v"].astype(dt))
    gates = xc @ p["w_if"].astype(dt) + p["b_if"].astype(dt)
    ilog = gates[..., :h]
    flog = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))
    core, (c_n, n_n, m_n) = mlstm_step(q, k, v, ilog, flog,
                                       (state["c"], state["n"], state["m"]))
    core = rms_norm(core.reshape(b, di), p["ln_inner"], cfg.norm_eps)
    out = (core * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return x + out[:, None], {"c": c_n, "n": n_n, "m": m_n, "conv": ctx[:, 1:]}


def slstm_block_decode(p, cfg: ModelConfig, x, state):
    b = x.shape[0]
    h = cfg.num_heads
    dh = cfg.d_model // h
    dt = x.dtype
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    xg = jnp.einsum("bd,dhge->bhge", xn[:, 0], p["w_g"].astype(dt)) + p["b_g"].astype(dt)
    rec = jnp.einsum("bhd,hgde->bhge", state["h"], p["r_w"].astype(jnp.float32))
    pre = xg.astype(jnp.float32) + rec
    il, fl, zl, ol = pre[:, :, 0], pre[:, :, 1], pre[:, :, 2], pre[:, :, 3]
    m_new = jnp.maximum(fl + state["m"], il)
    i_p = jnp.exp(il - m_new)
    f_p = jnp.exp(fl + state["m"] - m_new)
    c_new = f_p * state["c"] + i_p * jnp.tanh(zl)
    n_new = f_p * state["n"] + i_p
    h_new = jax.nn.sigmoid(ol) * c_new / jnp.maximum(n_new, 1.0)
    hs = rms_norm(h_new.reshape(b, cfg.d_model).astype(dt), p["ln_inner"], cfg.norm_eps)
    out = jax.nn.gelu(hs @ p["w_out"].astype(dt)) @ p["w_down"].astype(dt)
    new_state = {"c": c_new, "n": n_new, "m": m_new, "h": h_new}
    return x + out[:, None], new_state


# --------------------------------------------------------------------------
# whole-model decode step
# --------------------------------------------------------------------------
def _dense_layer_decode(lp, cfg, x, lcache, pos, impl, seq_max=None,
                        paged=None):
    h = rms_norm(x, lp["norm_attn"], cfg.norm_eps)
    if cfg.mla:
        attn, new_cache = mla_decode(lp["attn"], cfg, h, lcache, pos, impl,
                                     seq_max, paged)
    else:
        attn, new_cache = gqa_decode(lp["attn"], cfg, h, lcache, pos, impl,
                                     seq_max, paged)
    x = x + attn
    h = rms_norm(x, lp["norm_mlp"], cfg.norm_eps)
    if cfg.moe:
        ff, _ = moe_forward(lp["moe"], cfg, h)
    else:
        ff = mlp_forward(lp["mlp"], h, cfg.act)
    return x + ff, new_cache


def _hymba_layer_decode(lp, cfg, x, lcache, pos, impl, seq_max=None,
                        paged=None):
    h = rms_norm(x, lp["norm_mix"], cfg.norm_eps)
    attn, attn_cache = gqa_decode(lp["attn"], cfg, h, lcache["attn"], pos,
                                  impl, seq_max, paged)
    ssm, ssm_state = mamba_decode(lp["mamba"], cfg, h, lcache["mamba"])
    mixed = (
        lp["gate_attn"].astype(x.dtype) * attn + lp["gate_ssm"].astype(x.dtype) * ssm
    )
    x = x + mixed
    h = rms_norm(x, lp["norm_mlp"], cfg.norm_eps)
    x = x + mlp_forward(lp["mlp"], h, cfg.act)
    return x, {"attn": attn_cache, "mamba": ssm_state}


def decode_step(params, cfg: ModelConfig, cache: Cache, tokens: jnp.ndarray,
                seq_max: int | None = None, paged_table=None,
                paged_meta=None):
    """One decode step. tokens (B,1) int32. Returns (logits (B,1,V), cache).

    ``seq_max`` (optional) fixes the landmark segmentation horizon
    independently of the K/V view length — the paged engine gathers views
    only as long as the longest active sequence needs.

    ``paged_table`` ((n_slots,) int32, traced) + ``paged_meta``
    ((block_size, interpret), static) switch the gather-free paged route:
    seq-shaped cache leaves are the shared block pools (module docstring),
    and the returned cache carries each layer's NEW TOKEN in their place
    for ``PagedKVCache.make_paged_step`` to scatter-commit."""
    from repro.models.model import working_params

    paged = None if paged_table is None else (paged_table, *paged_meta)
    params = working_params(params, cfg)
    pos = cache["pos"]
    dt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(params, cfg, tokens).astype(dt)
    impl = cfg.decode_attention_impl

    if cfg.family == "ssm":
        new_layers = []
        for lp, lc in zip(params["layers"], cache["layers"]):
            if "kind_slstm" in lp:
                x, st = slstm_block_decode(lp["kind_slstm"], cfg, x, lc["kind_slstm"])
                new_layers.append({"kind_slstm": st})
            else:
                x, st = mlstm_block_decode(lp["kind_mlstm"], cfg, x, lc["kind_mlstm"])
                new_layers.append({"kind_mlstm": st})
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = _unembed(params, cfg, x)
        return logits, {"pos": pos + 1, "layers": new_layers}

    if cfg.family == "audio":
        return _whisper_decode(params, cfg, cache, tokens, seq_max, paged)

    layer_decode = {
        "dense": _dense_layer_decode,
        "moe": _dense_layer_decode,
        "vlm": _dense_layer_decode,
        "hybrid": _hymba_layer_decode,
    }[cfg.family]

    if cfg.scan_layers and not isinstance(params["layers"], list):
        # Pool leaves scan fine: their layout keeps the layer axis leading
        # (the block pair replaced cache_seq in place), and each layer's
        # output carries only the new token, so the scan's stacked ys stay
        # O(L*c*d) — the pools are read-only xs.
        def body(y, xs):
            lp, lc = xs
            y, nc = layer_decode(lp, cfg, y, lc, pos, impl, seq_max, paged)
            return y, nc

        x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    else:
        new_list = []
        for lp, lc in zip(params["layers"], cache["layers"]):
            x, nc = layer_decode(lp, cfg, x, lc, pos, impl, seq_max, paged)
            new_list.append(nc)
        new_layer_cache = new_list

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layer_cache
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _whisper_decode(params, cfg: ModelConfig, cache, tokens, seq_max=None,
                    paged=None):
    pos = cache["pos"]
    dt = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(params, cfg, tokens).astype(dt)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], jnp.minimum(pos, params["dec_pos"].shape[0] - 1), 1
    ).astype(dt)
    impl = cfg.decode_attention_impl
    new_layers = []
    for i, (lp, lc) in enumerate(zip(params["layers"], cache["layers"])):
        h = layer_norm(x, lp["ln_self"]["scale"], lp["ln_self"]["bias"], cfg.norm_eps)
        attn, nc = gqa_decode(lp["self_attn"], cfg, h, lc, pos, impl, seq_max,
                              paged)
        x = x + attn
        h = layer_norm(x, lp["ln_cross"]["scale"], lp["ln_cross"]["bias"], cfg.norm_eps)
        ck, cv = cache["cross_k"][i], cache["cross_v"][i]
        cp = lp["cross_attn"]
        q = jnp.einsum("bsd,dhe->bhse", h, cp["w_q"].astype(dt))
        scores = jnp.einsum(
            "bhqd,bhsd->bhqs", q.astype(jnp.float32), ck.astype(jnp.float32)
        ) * (cfg.resolved_head_dim**-0.5)
        pattn = jax.nn.softmax(scores, axis=-1)
        cr = jnp.einsum("bhqs,bhsd->bhqd", pattn, cv.astype(jnp.float32)).astype(dt)
        x = x + jnp.einsum("bhse,hed->bsd", cr, cp["w_o"].astype(dt))
        h = layer_norm(x, lp["ln_mlp"]["scale"], lp["ln_mlp"]["bias"], cfg.norm_eps)
        x = x + mlp_forward(lp["mlp"], h, "gelu")
        new_layers.append(nc)
    x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + 1
    return logits, new_cache
