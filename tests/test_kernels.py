"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles.

Kernels execute in interpret mode (CPU); TPU is the compile target. The
sweep covers padded tails (n % block != 0), non-square head dims, and both
fp32/bf16 in/out dtypes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import SSConfig, spectral_shift_attention
from repro.core.landmarks import segment_means
from repro.kernels.ops import nystrom_attention_fused, ss_attention_fused
from repro.kernels.ref import ref_landmark_summary, ref_query_side
from repro.kernels.ss_attention import landmark_summary, query_side


def _inputs(b, n, d, dv, c, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = (jax.random.normal(ks[0], (b, n, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, n, d)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (b, n, dv)).astype(dtype)
    q_l = segment_means(q, c)
    k_l = segment_means(k, c)
    return q, k, v, q_l, k_l


_TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
        jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


class TestLandmarkSummaryKernel:
    @pytest.mark.parametrize("n", [128, 384, 500])     # 500: padded tail
    @pytest.mark.parametrize("c", [16, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, n, c, dtype):
        q, k, v, q_l, k_l = _inputs(2, n, 32, 32, c, dtype)
        scale = 1 / 32**0.5
        out = landmark_summary(q_l, k, v, scale=scale, block_n=128, interpret=True)
        ref = ref_landmark_summary(q_l, k, v, scale)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_TOL[dtype],
        )

    @pytest.mark.parametrize("d,dv", [(32, 64), (64, 32), (128, 128)])
    def test_rect_head_dims(self, d, dv):
        q, k, v, q_l, _ = _inputs(1, 256, d, dv, 32, jnp.float32)
        scale = 1 / d**0.5
        out = landmark_summary(q_l, k, v, scale=scale, block_n=64, interpret=True)
        ref = ref_landmark_summary(q_l, k, v, scale)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_single_block(self):
        # n < block_n: one grid step, still correct.
        q, k, v, q_l, _ = _inputs(2, 100, 32, 32, 16, jnp.float32)
        out = landmark_summary(q_l, k, v, scale=0.17, block_n=512, interpret=True)
        ref = ref_landmark_summary(q_l, k, v, 0.17)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


class TestQuerySideKernel:
    @pytest.mark.parametrize("n", [128, 384, 500])
    @pytest.mark.parametrize("c", [16, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_oracle(self, n, c, dtype):
        q, k, v, q_l, k_l = _inputs(2, n, 32, 32, c, dtype, seed=1)
        m_mat = jax.random.normal(jax.random.PRNGKey(7), (2, c, 32)).astype(dtype)
        delta = jnp.full((2, 1, 1), 0.3, jnp.float32)
        scale = 1 / 32**0.5
        out = query_side(q, k_l, m_mat, v, delta, scale=scale, block_n=128,
                         interpret=True)
        ref = ref_query_side(q, k_l, m_mat, v, delta, scale)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_TOL[dtype],
        )

    def test_zero_delta(self):
        q, k, v, q_l, k_l = _inputs(1, 256, 32, 32, 32, jnp.float32)
        m_mat = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32))
        delta = jnp.zeros((1, 1, 1))
        out = query_side(q, k_l, m_mat, v, delta, scale=0.2, interpret=True)
        ref = ref_query_side(q, k_l, m_mat, v, delta, 0.2)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


class TestFusedOp:
    @pytest.mark.parametrize("n,c", [(256, 32), (512, 64), (384, 48)])
    def test_fused_matches_jnp_path(self, n, c):
        q, k, v, *_ = _inputs(2, n, 32, 32, c, jnp.float32, seed=2)
        cfg = SSConfig(num_landmarks=c, method="iterative", pinv_iters=6)
        fused = ss_attention_fused(q, k, v, cfg, interpret=True)
        ref = spectral_shift_attention(q, k, v, cfg)
        np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)

    def test_fused_multihead_lead_dims(self):
        # (B, H, n, d) leading dims flatten into the kernel batch.
        key = jax.random.PRNGKey(5)
        q = jax.random.normal(key, (2, 4, 256, 16)) * 0.5
        k = jax.random.normal(key, (2, 4, 256, 16)) * 0.5
        v = jax.random.normal(key, (2, 4, 256, 16))
        cfg = SSConfig(num_landmarks=32)
        fused = ss_attention_fused(q, k, v, cfg, interpret=True)
        ref = spectral_shift_attention(q, k, v, cfg)
        np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)

    def test_nystrom_fused(self):
        q, k, v, *_ = _inputs(2, 256, 32, 32, 32, jnp.float32)
        fused = nystrom_attention_fused(q, k, v, interpret=True)
        from repro.core.attention import nystrom_attention

        ref = nystrom_attention(q, k, v, num_landmarks=64)
        np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)

    def test_bf16_end_to_end(self):
        q, k, v, *_ = _inputs(1, 512, 64, 64, 64, jnp.bfloat16, seed=4)
        cfg = SSConfig(num_landmarks=64)
        out = ss_attention_fused(q, k, v, cfg, interpret=True)
        assert out.dtype == jnp.bfloat16
        assert not bool(jnp.any(jnp.isnan(out.astype(jnp.float32))))


class TestCausalKernels:
    """Segment-causal masks evaluated inside the streams vs masked oracles."""

    @staticmethod
    def _ls_ref(q_l, k, v, scale):
        c, n = q_l.shape[1], k.shape[1]
        seg = -(-n // c)
        mask = jnp.arange(n)[None, :] < (jnp.arange(c)[:, None] + 1) * seg
        s = jnp.einsum("bcd,bnd->bcn", q_l, k) * scale
        s = jnp.where(mask, s, -1e30)
        p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
        p = jnp.where(mask, p, 0.0)
        p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
        return jnp.einsum("bcn,bnd->bcd", p, v)

    @staticmethod
    def _qs_ref(q, k_l, m_mat, v, delta, scale):
        n, c = q.shape[1], k_l.shape[1]
        seg = -(-n // c)
        mask = jnp.arange(c)[None, :] <= (jnp.arange(n)[:, None] // seg)
        s = jnp.einsum("bnd,bcd->bnc", q, k_l) * scale
        s = jnp.where(mask, s, -1e30)
        p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
        p = jnp.where(mask, p, 0.0)
        p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
        return jnp.einsum("bnc,bcd->bnd", p, m_mat) + delta * v

    @pytest.mark.parametrize("n", [256, 500])  # 500: padded tail
    def test_landmark_summary_causal(self, n):
        q, k, v, q_l, _ = _inputs(2, n, 32, 32, 16, jnp.float32, seed=6)
        scale = 1 / 32**0.5
        out = landmark_summary(
            q_l, k, v, scale=scale, block_n=128, causal=True, interpret=True
        )
        np.testing.assert_allclose(
            out, self._ls_ref(q_l, k, v, scale), atol=2e-5, rtol=2e-5
        )

    @pytest.mark.parametrize("n", [256, 500])
    def test_query_side_causal(self, n):
        q, k, v, q_l, k_l = _inputs(2, n, 32, 32, 16, jnp.float32, seed=7)
        m_mat = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 32))
        delta = jnp.full((2, 1, 1), 0.25, jnp.float32)
        scale = 1 / 32**0.5
        out = query_side(
            q, k_l, m_mat, v, delta, scale=scale, block_n=128, causal=True,
            interpret=True,
        )
        np.testing.assert_allclose(
            out, self._qs_ref(q, k_l, m_mat, v, delta, scale),
            atol=2e-5, rtol=2e-5,
        )

    @pytest.mark.parametrize("n,c", [(256, 32), (384, 48)])
    def test_fused_causal_matches_jnp_path(self, n, c):
        q, k, v, *_ = _inputs(2, n, 32, 32, c, jnp.float32, seed=8)
        cfg = SSConfig(num_landmarks=c, causal=True)
        fused = ss_attention_fused(q, k, v, cfg, interpret=True)
        ref = spectral_shift_attention(q, k, v, cfg)
        np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)

    def test_stats_reconstruct_softmax(self):
        """(m, l) stats reconstruct the streamed softmax factor exactly."""
        q, k, v, q_l, _ = _inputs(1, 320, 32, 32, 16, jnp.float32, seed=9)
        scale = 1 / 32**0.5
        bv, m, l = landmark_summary(
            q_l, k, v, scale=scale, block_n=128, interpret=True,
            return_stats=True,
        )
        plain = landmark_summary(
            q_l, k, v, scale=scale, block_n=128, interpret=True
        )
        np.testing.assert_allclose(bv, plain, atol=0, rtol=0)
        s = jnp.einsum("bcd,bnd->bcn", q_l, k) * scale
        p = jnp.exp(s - m) / l  # reconstructed from the saved stats
        np.testing.assert_allclose(
            jnp.sum(p, -1), jnp.ones_like(l[..., 0]), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_allclose(
            jnp.einsum("bcn,bnd->bcd", p, v), bv, atol=2e-5, rtol=2e-5
        )


class TestDynamicBounds:
    """Traced kv_offset/kv_valid/q_offset bounds: the SMEM-scalar plumbing
    the shard_map driver and bucketed prefill share."""

    def test_kv_valid_masks_padded_keys(self):
        """Padded-key softmax with a traced kv_valid == unpadded kernel."""
        q, k, v, q_l, _ = _inputs(2, 192, 32, 32, 16, jnp.float32, seed=10)
        n_valid = 160
        scale = 1 / 32**0.5
        out = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, interpret=True,
            kv_valid=jnp.int32(n_valid),
        )
        ref = ref_landmark_summary(q_l, k[:, :n_valid], v[:, :n_valid], scale)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_two_shard_merge_equals_full_stream(self):
        """Manual two-shard flash merge (the shard_map combine) == one full
        stream: per-shard kernels with kv_offset plus (m, l)-weighted psum."""
        q, k, v, q_l, _ = _inputs(2, 256, 32, 32, 16, jnp.float32, seed=11)
        scale = 1 / 32**0.5
        full = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, causal=True, interpret=True
        )
        half = 128
        parts = []
        for off in (0, half):
            parts.append(landmark_summary(
                q_l, k[:, off : off + half], v[:, off : off + half],
                scale=scale, block_n=64, causal=True, interpret=True,
                return_stats=True, kv_offset=jnp.int32(off),
                kv_valid=jnp.int32(256), seq_len_k=256,
            ))
        m_g = jnp.maximum(parts[0][1], parts[1][1])
        corrs = [l * jnp.exp(m - m_g) for _, m, l in parts]
        l_g = corrs[0] + corrs[1]
        bv_g = (parts[0][0] * corrs[0] + parts[1][0] * corrs[1]) / jnp.maximum(
            l_g, 1e-30
        )
        np.testing.assert_allclose(bv_g, full, atol=2e-5, rtol=2e-5)

    def test_shard_merge_with_internal_block_padding(self):
        """Regression: a shard whose length is not a block_n multiple pads
        zero keys inside the kernel; their GLOBAL positions sit below the
        global valid end on non-final shards, so the kernel must clamp the
        bound by the local length or the pad leaks into the softmax."""
        q, k, v, q_l, _ = _inputs(2, 192, 32, 32, 16, jnp.float32, seed=20)
        scale = 1 / 32**0.5
        full = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, interpret=True
        )
        half = 96  # 96 % 64 != 0 -> 32 zero-padded keys per shard
        parts = [
            landmark_summary(
                q_l, k[:, off : off + half], v[:, off : off + half],
                scale=scale, block_n=64, interpret=True, return_stats=True,
                kv_offset=jnp.int32(off), kv_valid=jnp.int32(192),
                seq_len_k=192,
            )
            for off in (0, half)
        ]
        m_g = jnp.maximum(parts[0][1], parts[1][1])
        corrs = [l * jnp.exp(m - m_g) for _, m, l in parts]
        l_g = corrs[0] + corrs[1]
        bv_g = (parts[0][0] * corrs[0] + parts[1][0] * corrs[1]) / jnp.maximum(
            l_g, 1e-30
        )
        np.testing.assert_allclose(bv_g, full, atol=2e-5, rtol=2e-5)

    def test_kv_offset_alone_keeps_all_local_keys(self):
        """Regression: kv_offset without kv_valid must default the bound to
        offset + n (all local keys valid in global coordinates), not the
        local length n."""
        q, k, v, q_l, _ = _inputs(1, 128, 32, 32, 16, jnp.float32, seed=21)
        scale = 1 / 32**0.5
        plain = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, interpret=True
        )
        offset = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, interpret=True,
            kv_offset=jnp.int32(128),  # bidir: offset alone changes nothing
        )
        np.testing.assert_allclose(offset, plain, atol=2e-5, rtol=2e-5)

    def test_query_side_dynamic_offset(self):
        """A traced q_offset reproduces the static decode-convention mask."""
        q, k, v, q_l, k_l = _inputs(2, 128, 32, 32, 16, jnp.float32, seed=12)
        m_mat = jax.random.normal(jax.random.PRNGKey(13), (2, 16, 32))
        delta = jnp.full((2, 1, 1), 0.2, jnp.float32)
        scale = 1 / 32**0.5
        n_k = 256  # queries are the last 128 rows of a 256-token context
        static = query_side(
            q, k_l, m_mat, v, delta, scale=scale, block_n=64, causal=True,
            seq_len_k=n_k, interpret=True,
        )
        dyn = query_side(
            q, k_l, m_mat, v, delta, scale=scale, block_n=64, causal=True,
            seq_len_k=n_k, interpret=True, q_offset=jnp.int32(n_k - 128),
        )
        np.testing.assert_allclose(dyn, static, atol=0, rtol=0)

    def test_bwd_kernels_accept_bounds(self):
        """Backward kernels under dynamic bounds == slicing by hand."""
        from repro.kernels.ss_attention_bwd import landmark_summary_bwd

        q, k, v, q_l, _ = _inputs(1, 160, 32, 32, 16, jnp.float32, seed=14)
        scale = 1 / 32**0.5
        n_valid = 130
        bv, m, l = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, interpret=True,
            return_stats=True, kv_valid=jnp.int32(n_valid),
        )
        g = jax.random.normal(jax.random.PRNGKey(15), bv.shape)
        dq, dk, dv = landmark_summary_bwd(
            q_l, k, v, bv, m, l, g, scale=scale, block_n=64, interpret=True,
            kv_valid=jnp.int32(n_valid),
        )
        dq_r, dk_r, dv_r = landmark_summary_bwd(
            q_l, k[:, :n_valid], v[:, :n_valid], bv, m, l, g, scale=scale,
            block_n=64, interpret=True,
        )
        np.testing.assert_allclose(dq, dq_r, atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(dk[:, :n_valid], dk_r, atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(dv[:, :n_valid], dv_r, atol=2e-5, rtol=2e-5)
        assert float(jnp.max(jnp.abs(dk[:, n_valid:]))) == 0.0
        assert float(jnp.max(jnp.abs(dv[:, n_valid:]))) == 0.0


class TestMaskedFusedAttention:
    """ss_attention_fused(kv_valid=...): the bucketed-prefill contract."""

    def test_padded_equals_unpadded(self):
        q, k, v, *_ = _inputs(2, 96, 32, 32, 16, jnp.float32, seed=16)
        cfg = SSConfig(num_landmarks=16)
        for n_valid in (50, 77, 96):
            ref = ss_attention_fused(
                q[:, :n_valid], k[:, :n_valid], v[:, :n_valid], cfg,
                interpret=True,
            )
            out = ss_attention_fused(
                q, k, v, cfg, interpret=True, kv_valid=jnp.int32(n_valid)
            )
            np.testing.assert_allclose(
                out[:, :n_valid], ref, atol=1e-5, rtol=1e-5
            )

    def test_padded_equals_unpadded_corrected_delta(self):
        """Regression: the delta_scale="corrected" rescale (delta * c/n)
        must read the TRUE prompt length, not the padded shape."""
        q, k, v, *_ = _inputs(2, 96, 32, 32, 16, jnp.float32, seed=22)
        cfg = SSConfig(num_landmarks=16, delta_scale="corrected")
        n_valid = 50
        ref = ss_attention_fused(
            q[:, :n_valid], k[:, :n_valid], v[:, :n_valid], cfg,
            interpret=True,
        )
        out = ss_attention_fused(
            q, k, v, cfg, interpret=True, kv_valid=jnp.int32(n_valid)
        )
        np.testing.assert_allclose(out[:, :n_valid], ref, atol=1e-5, rtol=1e-5)

    def test_masked_landmarks_match_segment_means(self):
        from repro.core.landmarks import masked_segment_means

        x = jax.random.normal(jax.random.PRNGKey(17), (2, 80, 8))
        for n_valid in (33, 64, 80):
            got = masked_segment_means(x, 16, jnp.int32(n_valid))
            want = segment_means(x[:, :n_valid], 16, via_matmul=True)
            np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)

    def test_guards(self):
        q, k, v, *_ = _inputs(1, 64, 16, 16, 16, jnp.float32)
        with pytest.raises(ValueError, match="num_landmarks"):
            # Padded degenerate prompt: exact path has no mask (assert-guard).
            ss_attention_fused(
                q, k, v, SSConfig(num_landmarks=64), interpret=True,
                kv_valid=jnp.int32(10),
            )
        with pytest.raises(ValueError, match="bidirectional"):
            ss_attention_fused(
                q, k, v, SSConfig(num_landmarks=8, causal=True),
                interpret=True, kv_valid=jnp.int32(40),
            )


class TestBlockCTiling:
    """block_c grid tiling of the B-side kernel (autotune candidate): each
    landmark-row tile re-runs the key stream with its own scratch — results
    must be bit-comparable to the untiled kernel."""

    @pytest.mark.parametrize("block_c", [4, 8])
    @pytest.mark.parametrize("causal", [False, True])
    def test_tiled_matches_untiled(self, block_c, causal):
        q, k, v, q_l, _ = _inputs(2, 200, 32, 32, 16, jnp.float32, seed=30)
        scale = 1 / 32**0.5
        ref = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, causal=causal, interpret=True
        )
        out, m, l = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, block_c=block_c,
            causal=causal, interpret=True, return_stats=True,
        )
        np.testing.assert_allclose(out, ref, atol=1e-6, rtol=1e-6)
        _, m_ref, l_ref = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, causal=causal,
            interpret=True, return_stats=True,
        )
        np.testing.assert_allclose(m, m_ref, atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(l, l_ref, atol=1e-6, rtol=1e-6)

    def test_non_divisor_block_c_ignored(self):
        q, k, v, q_l, _ = _inputs(1, 128, 32, 32, 16, jnp.float32, seed=31)
        scale = 1 / 32**0.5
        ref = landmark_summary(q_l, k, v, scale=scale, block_n=64, interpret=True)
        out = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, block_c=5, interpret=True
        )
        np.testing.assert_allclose(out, ref, atol=0, rtol=0)

    def test_tiled_with_kv_valid(self):
        q, k, v, q_l, _ = _inputs(2, 192, 32, 32, 16, jnp.float32, seed=32)
        scale = 1 / 32**0.5
        n_valid = 150
        ref = ref_landmark_summary(q_l, k[:, :n_valid], v[:, :n_valid], scale)
        out = landmark_summary(
            q_l, k, v, scale=scale, block_n=64, block_c=8, interpret=True,
            kv_valid=jnp.int32(n_valid),
        )
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_fused_attention_block_c_parity(self):
        q, k, v, *_ = _inputs(2, 256, 32, 32, 16, jnp.float32, seed=33)
        cfg = SSConfig(num_landmarks=16)
        ref = ss_attention_fused(q, k, v, cfg, interpret=True)
        out = ss_attention_fused(q, k, v, cfg, block_c=8, interpret=True)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


class TestPagedDecodeKernel:
    """Gather-free paged row stats (kernels/paged_decode.py): the block-
    table-aware kernel must reproduce the jnp recompute over the gathered
    dense view — including permuted tables, ragged last blocks, ZERO_BLOCK
    tail slots, and the zeros-empty-row convention — and its custom_vmap
    rule must lower the lane batch to one multi-lane launch bitwise."""

    def _setup(self, lanes=3, hkv=2, r=4, d=16, dv=8, bs=8, nb_pool=12,
               n_slots=5, seed=40):
        from repro.serve.paged import ZERO_BLOCK

        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(lanes, hkv, r, d)), jnp.float32)
        k_pool = jnp.asarray(
            rng.normal(size=(hkv, nb_pool, bs, d)), jnp.float32
        ).at[:, ZERO_BLOCK].set(0.0)
        v_pool = jnp.asarray(
            rng.normal(size=(hkv, nb_pool, bs, dv)), jnp.float32
        ).at[:, ZERO_BLOCK].set(0.0)
        # Distinct permuted blocks per lane, ZERO_BLOCK backing the tail.
        blocks = rng.permutation(np.arange(1, nb_pool))
        tables = np.full((lanes, n_slots), ZERO_BLOCK, np.int32)
        tables[0, :4] = blocks[:4]
        tables[1, :3] = blocks[4:7]
        tables[2, :5] = np.concatenate([blocks[7:], blocks[:1]])
        # ragged: none of these is a block multiple
        kv_valid = jnp.asarray([27, 17, 39], jnp.int32)
        return q, k_pool, v_pool, jnp.asarray(tables), kv_valid

    def _ref(self, q, k_pool, v_pool, tables, kv_valid, lane, scale):
        from repro.serve.decode_state import recompute_stats

        tb = np.asarray(tables[lane])
        kv = jnp.concatenate([k_pool[:, b] for b in tb], axis=1)[None]
        vv = jnp.concatenate([v_pool[:, b] for b in tb], axis=1)[None]
        return recompute_stats(
            q[lane][None], kv, vv, int(kv_valid[lane]) - 1, scale
        )

    def test_vs_dense_recompute(self):
        from repro.kernels.paged_decode import paged_row_stats_lanes

        q, k_pool, v_pool, tables, kv_valid = self._setup()
        scale = 0.3
        m, l, acc = paged_row_stats_lanes(
            q, (k_pool,), v_pool, tables, kv_valid, scale=scale,
            block_size=8, interpret=True,
        )
        for lane in range(q.shape[0]):
            m_r, l_r, acc_r = self._ref(q, k_pool, v_pool, tables, kv_valid,
                                        lane, scale)
            # anchor-invariant comparisons: log-mass and normalized BV
            np.testing.assert_allclose(
                np.log(np.maximum(np.asarray(l[lane]), 1e-30))
                + np.asarray(m[lane]),
                np.asarray(jnp.log(jnp.maximum(l_r[0], 1e-30)) + m_r[0]),
                atol=1e-5, rtol=1e-5,
            )
            np.testing.assert_allclose(
                np.asarray(acc[lane] / jnp.maximum(l[lane], 1e-30)),
                np.asarray(acc_r[0] / jnp.maximum(l_r[0], 1e-30)),
                atol=1e-5, rtol=1e-5,
            )

    def test_custom_vmap_matches_batched_launch(self):
        from repro.kernels.paged_decode import (
            paged_row_stats, paged_row_stats_lanes,
        )

        q, k_pool, v_pool, tables, kv_valid = self._setup()
        ref = paged_row_stats_lanes(
            q, (k_pool,), v_pool, tables, kv_valid, scale=0.3, block_size=8,
            interpret=True,
        )
        got = jax.jit(jax.vmap(
            lambda qq, tt, kk: paged_row_stats(
                qq, (k_pool,), v_pool, tt, kk, scale=0.3, block_size=8,
                interpret=True,
            ),
            in_axes=(0, 0, 0),
        ))(q, tables, kv_valid)
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    def test_lane_batched_pools_rejected(self):
        from repro.kernels.paged_decode import paged_row_stats

        q, k_pool, v_pool, tables, kv_valid = self._setup()
        k_lanes = jnp.broadcast_to(k_pool[None], (q.shape[0], *k_pool.shape))
        with pytest.raises(NotImplementedError, match="broadcast"):
            jax.vmap(
                lambda qq, kp, tt, kk: paged_row_stats(
                    qq, (kp,), v_pool, tt, kk, scale=0.3, block_size=8,
                    interpret=True,
                ),
                in_axes=(0, 0, 0, 0),
            )(q, k_lanes, tables, kv_valid)

    def test_no_valid_keys_emits_absorbing_state(self):
        """kv_valid=0: (m=-inf, l=0, acc=0) — the anchor must be absorbing
        so that flash-merging a strongly negative token score re-anchors
        at that score instead of underflowing against a finite anchor."""
        from repro.kernels.ops import flash_merge
        from repro.kernels.paged_decode import paged_row_stats

        q, k_pool, v_pool, tables, _ = self._setup()
        m, l, acc = paged_row_stats(
            q[0], (k_pool,), v_pool, tables[0], 0, scale=0.3, block_size=8,
            interpret=True,
        )
        assert np.all(np.asarray(l) == 0.0) and np.all(np.asarray(acc) == 0.0)
        assert np.all(np.asarray(m) <= -1e29)
        # merge one token with a score deep in exp-underflow territory
        s = jnp.full_like(m, -200.0)
        v = jnp.ones_like(acc)
        m2, l2, acc2 = flash_merge(m, l, acc, s, jnp.ones_like(s), v)
        np.testing.assert_allclose(np.asarray(acc2 / l2), np.asarray(v))

    def test_two_pool_split_matches_single(self):
        """MLA contract: scores accumulated across (latent, rope) pools ==
        one kernel over the feature-concatenated pool."""
        from repro.kernels.paged_decode import paged_row_stats_lanes

        q, k_pool, v_pool, tables, kv_valid = self._setup()
        ref = paged_row_stats_lanes(
            q, (k_pool,), v_pool, tables, kv_valid, scale=0.3, block_size=8,
            interpret=True,
        )
        got = paged_row_stats_lanes(
            q, (k_pool[..., :10], k_pool[..., 10:]), v_pool, tables,
            kv_valid, scale=0.3, block_size=8, interpret=True,
        )
        for g, r in zip(got, ref):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(r), atol=1e-5, rtol=1e-5
            )

    def test_split_dim_mismatch_rejected(self):
        from repro.kernels.paged_decode import paged_row_stats_lanes

        q, k_pool, v_pool, tables, kv_valid = self._setup()
        with pytest.raises(ValueError, match="sum"):
            paged_row_stats_lanes(
                q, (k_pool[..., :10],), v_pool, tables, kv_valid,
                scale=0.3, block_size=8, interpret=True,
            )
