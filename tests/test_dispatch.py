"""Dispatch registry: plan selection, disk-cache round-trip, autotune smoke,
and routing parity of ``dispatch_ss_attention`` across forced backends."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import SSConfig, spectral_shift_attention
from repro.kernels import dispatch


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    """Every test gets a private cache file and a clean registry."""
    monkeypatch.setenv(
        "REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json")
    )
    dispatch.clear_registry()
    yield
    dispatch.clear_registry()


def test_key_buckets_sequence_length():
    k1 = dispatch.make_key(1000, 64, 64, jnp.float32, False, backend="tpu")
    k2 = dispatch.make_key(1024, 64, 64, jnp.float32, False, backend="tpu")
    k3 = dispatch.make_key(1025, 64, 64, jnp.float32, False, backend="tpu")
    assert k1 == k2 and k1.n == 1024
    assert k3.n == 2048


def test_key_encode_decode_roundtrip():
    key = dispatch.make_key(4096, 64, 128, jnp.bfloat16, True, backend="tpu")
    assert dispatch.PlanKey.decode(key.encode()) == key


def test_heuristics():
    cpu = dispatch.make_key(4096, 64, 64, jnp.float32, False, backend="cpu")
    assert dispatch.heuristic_plan(cpu).impl == "jnp"
    tpu_small = dispatch.make_key(512, 64, 64, jnp.bfloat16, True, backend="tpu")
    tpu_big = dispatch.make_key(32768, 64, 64, jnp.bfloat16, True, backend="tpu")
    assert dispatch.heuristic_plan(tpu_small).impl == "fused"
    assert dispatch.heuristic_plan(tpu_big).block_n == 1024


def test_register_overrides_heuristic():
    key = dispatch.make_key(2048, 64, 64, jnp.float32, False, backend="tpu")
    forced = dispatch.Plan(impl="jnp", block_n=256, source="registered")
    dispatch.register_plan(key, forced)
    assert dispatch.get_plan(key) == forced


def test_cache_round_trip():
    key = dispatch.make_key(8192, 64, 128, jnp.bfloat16, True, backend="tpu")
    plan = dispatch.Plan(impl="fused", block_n=1024, source="autotuned")
    dispatch.register_plan(key, plan)
    path = dispatch.save_cache()
    assert os.path.exists(path)
    with open(path) as f:
        payload = json.load(f)
    assert key.encode() in payload["plans"]

    # A fresh process: empty registry, plans come back from disk.
    dispatch.clear_registry()
    assert dispatch.load_cache() == 1
    got = dispatch.get_plan(key)
    assert (got.impl, got.block_n) == ("fused", 1024)
    assert got.source == "cache"


def test_save_cache_merges_existing_entries():
    k1 = dispatch.make_key(1024, 64, 64, jnp.float32, False, backend="tpu")
    dispatch.register_plan(k1, dispatch.Plan("fused", 512, source="autotuned"))
    dispatch.save_cache()
    dispatch.clear_registry()
    k2 = dispatch.make_key(4096, 64, 64, jnp.float32, True, backend="tpu")
    dispatch.register_plan(k2, dispatch.Plan("fused", 1024, source="autotuned"))
    dispatch.save_cache()
    dispatch.clear_registry()
    assert dispatch.load_cache() == 2


def test_heuristic_plans_not_persisted():
    key = dispatch.make_key(1024, 64, 64, jnp.float32, False, backend="cpu")
    dispatch.register_plan(key, dispatch.heuristic_plan(key))
    dispatch.save_cache()
    with open(dispatch.cache_path()) as f:
        assert f.read().count('"plans": {}') == 1


def test_autotune_records_measured_plan():
    plan = dispatch.autotune(
        128, 16, 16, causal=False, block_candidates=(64,), reps=1
    )
    assert plan.source == "autotuned"
    assert plan.impl in ("jnp", "interpret")  # CPU: fused means interpret
    # Winner is queryable without re-measuring, in-memory and from disk.
    key = dispatch.make_key(128, 16, 16, jnp.float32, False)
    assert dispatch.get_plan(key) == plan
    dispatch.clear_registry()
    dispatch.load_cache()
    assert dispatch.get_plan(key).impl == plan.impl


class TestDispatchRouting:
    def _qkv(self, n=192, d=32):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        return (
            jax.random.normal(ks[0], (2, n, d)) * 0.5,
            jax.random.normal(ks[1], (2, n, d)) * 0.5,
            jax.random.normal(ks[2], (2, n, d)),
        )

    @pytest.mark.parametrize("causal", [False, True])
    def test_forced_backends_agree(self, causal):
        q, k, v = self._qkv()
        cfg = SSConfig(num_landmarks=16, causal=causal)
        ref = spectral_shift_attention(q, k, v, cfg)
        out_jnp = dispatch.dispatch_ss_attention(q, k, v, cfg, backend="jnp")
        out_interp = dispatch.dispatch_ss_attention(
            q, k, v, cfg, backend="interpret"
        )
        np.testing.assert_allclose(out_jnp, ref, atol=1e-6)
        np.testing.assert_allclose(out_interp, ref, atol=1e-4, rtol=1e-4)

    def test_auto_on_cpu_routes_to_jnp_plan(self):
        q, k, v = self._qkv()
        cfg = SSConfig(num_landmarks=16)
        key = dispatch.make_key(q.shape[-2], 16, q.shape[-1], q.dtype, False)
        assert dispatch.get_plan(key).impl == "jnp"
        out = dispatch.dispatch_ss_attention(q, k, v, cfg, backend="auto")
        np.testing.assert_allclose(
            out, spectral_shift_attention(q, k, v, cfg), atol=1e-6
        )

    def test_registered_plan_steers_auto_route(self):
        q, k, v = self._qkv()
        cfg = SSConfig(num_landmarks=16)
        key = dispatch.make_key(q.shape[-2], 16, q.shape[-1], q.dtype, False)
        dispatch.register_plan(
            key, dispatch.Plan(impl="interpret", block_n=64, source="registered")
        )
        out = dispatch.dispatch_ss_attention(q, k, v, cfg, backend="auto")
        np.testing.assert_allclose(
            out, spectral_shift_attention(q, k, v, cfg), atol=1e-4, rtol=1e-4
        )

    def test_unknown_backend_raises(self):
        q, k, v = self._qkv(64, 16)
        with pytest.raises(ValueError, match="unknown attention backend"):
            dispatch.dispatch_ss_attention(
                q, k, v, SSConfig(num_landmarks=8), backend="cuda"
            )

    def test_model_attention_impl_uses_dispatch(self):
        """models/attention.py fused impl (causal) == jnp impl output."""
        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.models.attention import _core_attention

        cfg = reduced(
            get_config("qwen2-7b"), num_landmarks=16,
            attention_backend="interpret",
        )
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (1, 2, 160, 32)) * 0.5
        fused = _core_attention(
            cfg, "spectral_shift_fused", q, q, q, causal=True
        )
        ref = _core_attention(cfg, "spectral_shift", q, q, q, causal=True)
        np.testing.assert_allclose(fused, ref, atol=1e-4, rtol=1e-4)


class TestKeyFamilies:
    """decode / seq_shards key families (serving + context parallelism)."""

    def test_decode_key_roundtrip_and_heuristic(self):
        key = dispatch.make_key(
            32768, 64, 128, jnp.bfloat16, True, backend="tpu", family="decode"
        )
        assert dispatch.PlanKey.decode(key.encode()) == key
        assert key != dispatch.make_key(
            32768, 64, 128, jnp.bfloat16, True, backend="tpu"
        )
        # Accelerators default to the gather-free paged kernel; CPU keeps
        # the gather route (interpret-mode Pallas loses to jnp there).
        assert dispatch.heuristic_plan(key).impl == "paged"
        cpu = dispatch.make_key(
            32768, 64, 128, jnp.bfloat16, True, backend="cpu", family="decode"
        )
        assert dispatch.heuristic_plan(cpu).impl == "jnp"

    def test_seq_shards_key_roundtrip_and_heuristic(self):
        key = dispatch.make_key(
            524288, 64, 128, jnp.bfloat16, True, backend="tpu", seq_shards=16
        )
        assert dispatch.PlanKey.decode(key.encode()) == key
        plan = dispatch.heuristic_plan(key)
        assert plan.impl == "sharded"
        # Block size follows the per-shard stream length (n / seq_shards).
        unsharded = dispatch.heuristic_plan(dispatch.make_key(
            524288, 64, 128, jnp.bfloat16, True, backend="tpu"))
        assert plan.block_n <= unsharded.block_n
        # CPU keeps routing context-parallel cells to jnp-GSPMD.
        cpu = dispatch.make_key(
            4096, 64, 64, jnp.float32, False, backend="cpu", seq_shards=4)
        assert dispatch.heuristic_plan(cpu).impl == "jnp"

    def test_legacy_cache_keys_still_decode(self):
        """Pre-family on-disk cache entries (6-field keys) keep parsing."""
        key = dispatch.PlanKey.decode("tpu|n4096|c64|d128|bfloat16|causal")
        assert key.family == "self" and key.seq_shards == 1
        assert key.encode() == "tpu|n4096|c64|d128|bfloat16|causal"

    def test_unknown_family_rejected(self):
        with pytest.raises(ValueError, match="family"):
            dispatch.make_key(128, 16, 16, jnp.float32, False, family="wat")

    def test_sharded_plans_persist(self):
        key = dispatch.make_key(
            8192, 64, 64, jnp.bfloat16, True, backend="tpu", seq_shards=8)
        dispatch.register_plan(
            key, dispatch.Plan(impl="sharded", block_n=256, source="autotuned"))
        dispatch.save_cache()
        dispatch.clear_registry()
        assert dispatch.load_cache() == 1
        got = dispatch.get_plan(key)
        assert (got.impl, got.block_n) == ("sharded", 256)

    def test_sharded_plan_without_mesh_degenerates_to_fused(self):
        """A registered sharded plan outside any mesh context still routes
        (single shard == the plain fused kernels)."""
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 96, 16)) * 0.5
        cfg = SSConfig(num_landmarks=8)
        out = dispatch.dispatch_ss_attention(
            q, q, q, cfg, backend="sharded", interpret=True
        )
        ref = spectral_shift_attention(q, q, q, cfg)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_autotune_not_measured_for_mesh_keys(self):
        """Regression: get_plan(autotune_enabled=True) must not run the
        measured sweep for seq_shards keys — the harness measures the
        single-device program and would register the winner under a
        different key, re-tuning on every trace. (decode keys now DO
        measure, through their own harness — see TestBlockTablePlans.)"""
        calls = []

        def boom(key):
            calls.append(key)
            raise AssertionError("measured autotune ran for a mesh key")

        key = dispatch.make_key(1024, 16, 16, jnp.float32, False, seq_shards=4)
        plan = dispatch.get_plan(key, autotune_enabled=True, tune_fn=boom)
        assert plan.source == "heuristic"
        assert not calls

    def test_autotune_measures_decode_keys_via_own_harness(self):
        """Decode keys route to the decode tune_fn and register under the
        decode key itself (no re-tuning on later traces)."""
        key = dispatch.make_key(1024, 16, 16, jnp.float32, True,
                                family="decode")
        calls = []

        def tune(k):
            calls.append(k)
            plan = dispatch.Plan(impl="paged", block_n=512, block_table=4,
                                 source="autotuned")
            dispatch.register_plan(k, plan)
            return plan

        plan = dispatch.get_plan(key, autotune_enabled=True, tune_fn=tune)
        assert calls == [key] and plan.impl == "paged"
        again = dispatch.get_plan(key, autotune_enabled=True, tune_fn=tune)
        assert calls == [key]  # registry hit, no second sweep
        assert again.block_table == 4


class TestBlockCPlans:
    """block_c in the Plan/value layer: v2 cache round-trip, legacy v1
    caches stay readable, and the measured sweep covers the tile grid."""

    def test_cache_v2_round_trip_with_block_c(self):
        key = dispatch.make_key(8192, 64, 128, jnp.bfloat16, True, backend="tpu")
        plan = dispatch.Plan(
            impl="fused", block_n=1024, block_c=32, source="autotuned"
        )
        dispatch.register_plan(key, plan)
        path = dispatch.save_cache()
        with open(path) as f:
            payload = json.load(f)
        assert payload["version"] == 3  # v3 added block_table
        assert payload["plans"][key.encode()]["block_c"] == 32
        dispatch.clear_registry()
        dispatch.load_cache()
        got = dispatch.get_plan(key)
        assert (got.impl, got.block_n, got.block_c) == ("fused", 1024, 32)

    def test_legacy_v1_cache_readable(self):
        key = dispatch.make_key(4096, 64, 64, jnp.float32, False, backend="tpu")
        payload = {
            "version": 1,
            "plans": {key.encode(): {"impl": "fused", "block_n": 256}},
        }
        with open(dispatch.cache_path(), "w") as f:
            json.dump(payload, f)
        assert dispatch.load_cache() == 1
        got = dispatch.get_plan(key)
        assert (got.impl, got.block_n, got.block_c) == ("fused", 256, 0)
        assert got.source == "cache"

    def test_autotune_sweeps_block_c_grid(self):
        plan = dispatch.autotune(
            128, 16, 16, causal=False, block_candidates=(64,),
            block_c_candidates=(0, 8), reps=1,
        )
        assert plan.source == "autotuned"
        assert plan.block_c in (0, 8)
        # Winner round-trips through the on-disk cache with its tile size.
        key = dispatch.make_key(128, 16, 16, jnp.float32, False)
        dispatch.clear_registry()
        dispatch.load_cache()
        assert dispatch.get_plan(key).block_c == plan.block_c


class TestBlockTablePlans:
    """block_table in the Plan/value layer (the paged decode kernel's
    view-slot bucketing quantum): v3 cache round-trip, v2/v1 caches stay
    readable, the measured decode sweep, and routing guards."""

    def test_cache_v3_round_trip_with_block_table(self):
        key = dispatch.make_key(
            32768, 64, 128, jnp.bfloat16, True, backend="tpu",
            family="decode",
        )
        plan = dispatch.Plan(
            impl="paged", block_n=512, block_table=8, source="autotuned"
        )
        dispatch.register_plan(key, plan)
        path = dispatch.save_cache()
        with open(path) as f:
            payload = json.load(f)
        assert payload["version"] == 3
        assert payload["plans"][key.encode()]["block_table"] == 8
        dispatch.clear_registry()
        dispatch.load_cache()
        got = dispatch.get_plan(key)
        assert (got.impl, got.block_table) == ("paged", 8)

    def test_legacy_v2_cache_readable(self):
        """v2 entries (block_c, no block_table) load with block_table=0."""
        key = dispatch.make_key(4096, 64, 64, jnp.float32, False, backend="tpu")
        payload = {
            "version": 2,
            "plans": {key.encode(): {
                "impl": "fused", "block_n": 256, "block_c": 16,
            }},
        }
        with open(dispatch.cache_path(), "w") as f:
            json.dump(payload, f)
        assert dispatch.load_cache() == 1
        got = dispatch.get_plan(key)
        assert (got.impl, got.block_c, got.block_table) == ("fused", 16, 0)

    def test_autotune_decode_sweep(self):
        """The measured decode harness runs gather-vs-paged at the serve
        shape, sweeps the block_table grid, and persists the winner under
        the decode key."""
        plan = dispatch.autotune_decode(
            256, 16, 16, block_size=16, block_table_candidates=(0, 4),
            reps=1,
        )
        assert plan.source == "autotuned"
        assert plan.impl in ("jnp", "paged")
        if plan.impl == "paged":
            assert plan.block_table in (0, 4)
        key = dispatch.make_key(256, 16, 16, jnp.float32, True,
                                family="decode")
        dispatch.clear_registry()
        dispatch.load_cache()
        got = dispatch.get_plan(key)
        assert (got.impl, got.block_table) == (plan.impl, plan.block_table)

    def test_paged_rejected_for_self_attention(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 16)) * 0.5
        with pytest.raises(ValueError, match="decode"):
            dispatch.dispatch_ss_attention(
                q, q, q, SSConfig(num_landmarks=8), backend="paged"
            )
