import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh with abstract (ShapeDtypeStruct) params/inputs — no
allocation — and record memory / cost / collective statistics for the
roofline analysis.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json and is skipped
if that file already exists (restartable sweep).
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPE_PRESETS, TrainConfig
from repro.configs.registry import ARCH_IDS, batch_specs, get_config
from repro.distributed.sharding import (
    apply_seq_sharding_config,
    named_sharding,
    shardings_for,
    sharding_rules,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model import model_specs
from repro.models.params import abstract_params, count_params, logical_axes
from repro.optim.adamw import AdamWState
from repro.optim.schedules import warmup_cosine
from repro.train.train_step import make_serve_step, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|u32|s8|u8|s16|u16|s64|u64|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Total bytes of all shapes on an HLO op result (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective op stats from the post-SPMD HLO.

    For each collective line we record the RESULT bytes (per-device) and a
    modeled transmitted-bytes figure using ring-collective factors with the
    participant count parsed from replica_groups:
        all-gather:      out * (g-1)/g
        all-reduce:      out * 2(g-1)/g
        reduce-scatter:  out * (g-1)          (input = out*g)
        all-to-all:      out * (g-1)/g
        collective-permute: out
    """
    stats: dict = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"= \S+ {c}(-start)?\(", stripped):
                op = c
                break
        if op is None:
            continue
        out_bytes = _shape_bytes(stripped.split("=", 1)[1].split("(", 1)[0])
        g = 1
        m = _GROUPS_RE.search(stripped)
        if m:
            g = int(m.group(2))
        else:
            m = _GROUPS_LIST_RE.search(stripped)
            if m:
                g = len(m.group(1).split(","))
        if op == "all-gather":
            moved = out_bytes * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            moved = out_bytes * 2 * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = out_bytes * (g - 1)
        elif op == "all-to-all":
            moved = out_bytes * (g - 1) / max(g, 1)
        else:
            moved = out_bytes
        rec = stats.setdefault(op, {"count": 0, "result_bytes": 0, "moved_bytes": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += out_bytes
        rec["moved_bytes"] += moved
    return stats


def _sharded_bytes(tree_abstract, tree_sharding, n_dev: int) -> float:
    """Analytic per-device bytes of a sharded abstract pytree."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree_abstract), jax.tree.leaves(
            tree_sharding, is_leaf=lambda x: isinstance(x, NamedSharding))):
        shard_shape = sh.shard_shape(leaf.shape)
        n = leaf.dtype.itemsize
        for d in shard_shape:
            n *= d
        total += n
    return total


def _probe_cfg(cfg, num_layers: int, seq_len: int):
    """Variant of ``cfg`` for HLO cost probing: unrolled layers AND unrolled
    inner chunk scans (chunked attention, mLSTM/mamba chunk scans) so XLA's
    cost_analysis — which counts while-loop bodies once — sees every body.

    Math-identical to the real program: the online-softmax / chunk recurrence
    structure is preserved, so FLOPs AND bytes reflect the streaming
    implementation (an earlier probe swapped chunked->full attention, which
    inflated HLO bytes with n^2 score materialization the real kernels never
    do — see EXPERIMENTS.md §Perf iteration 0)."""
    import dataclasses

    # Cap unrolled SSM chunk count at 64: mamba's per-chunk associative
    # scans make XLA compile time explode past ~100 unrolled bodies (hymba
    # prefill_32k never finished). Larger chunks mildly OVERestimate the
    # mLSTM/SSD intra-chunk terms (O(chunk) per token) — conservative for
    # the roofline.
    ssm_chunk = max(cfg.ssm_chunk, -(-seq_len // 64))
    return dataclasses.replace(
        cfg,
        num_layers=num_layers,
        scan_layers=False,
        unroll_scans=True,
        ssm_chunk=ssm_chunk,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, attention: str | None = None,
             remat: str | None = None, extra_rules: dict | None = None,
             probe: bool = True, cfg_overrides: dict | None = None,
             tcfg: TrainConfig | None = None) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if attention:
        field = ("decode_attention_impl"
                 if SHAPE_PRESETS[shape_name].kind == "decode" else "attention_impl")
        cfg = dataclasses.replace(cfg, **{field: attention})
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPE_PRESETS[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    overrides = dict(extra_rules or {})
    if cfg.num_heads % mesh.shape["model"] != 0 and shape.kind != "decode":
        # Heads don't divide the TP axis (28/25/56-head archs): shard the
        # sequence over "model" instead (context parallelism) so per-device
        # compute still scales 1/256; GSPMD inserts the K/V gathers.
        overrides.setdefault("seq", "model")
    if shape_name == "long_500k":
        # batch=1: sequence-parallel cache, batch unsharded.
        overrides.setdefault("cache_batch", None)
        overrides.setdefault("batch", None)
        overrides.setdefault(
            "cache_seq", ("pod", "data", "model") if multi_pod else ("data", "model")
        )
    elif shape.kind == "decode":
        # Shard the KV-cache sequence over "model" (kv heads are often
        # narrower than the model axis).
        overrides.setdefault("cache_seq", "model")

    # Seq-sharded fused cells keep attention_backend intact and lower the
    # shard_map context-parallel program (kernels/sharded.py), so the
    # compile-time stats below model the same kernel route the trainer runs.
    cfg = apply_seq_sharding_config(cfg, mesh, overrides)

    t0 = time.time()
    result: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": n_dev,
        "attention": (cfg.decode_attention_impl if shape.kind == "decode"
                       else cfg.attention_impl),
        "remat": cfg.remat,
    }
    result.update(_lower_and_stats(cfg, shape, mesh, overrides, tcfg))

    # HLO-cost probe: unrolled L=2 / L=4 variants -> per-layer-linear
    # extrapolation of flops / bytes / collective traffic (XLA cost_analysis
    # counts while-loop bodies once; DESIGN.md §7).
    if probe and cfg.scan_layers and cfg.num_layers > 4:
        try:
            p2 = _lower_and_stats(_probe_cfg(cfg, 2, shape.seq_len), shape, mesh, overrides, tcfg)
            p4 = _lower_and_stats(_probe_cfg(cfg, 4, shape.seq_len), shape, mesh, overrides, tcfg)
            L = cfg.num_layers
            lin = lambda a, b: a + (b - a) / 2.0 * (L - 2)
            result["probe"] = {
                "flops_l2": p2["flops_total"], "flops_l4": p4["flops_total"],
                "flops_extrapolated": lin(p2["flops_total"], p4["flops_total"]),
                "bytes_extrapolated": lin(
                    p2["hlo_bytes_accessed"], p4["hlo_bytes_accessed"]
                ),
                "collective_moved_extrapolated": lin(
                    _moved(p2["collectives"]), _moved(p4["collectives"])
                ),
                "collectives_l4": p4["collectives"],
            }
        except Exception:
            result["probe"] = {"error": traceback.format_exc()}

    result["total_s"] = round(time.time() - t0, 2)
    return result


def _moved(collectives: dict) -> float:
    return sum(v["moved_bytes"] for v in collectives.values())


def _lower_and_stats(cfg, shape, mesh, overrides, tcfg=None) -> dict:
    """Lower + compile one step function; return cost/memory/collective stats."""
    n_dev = mesh.size
    result: dict = {}
    t0 = time.time()
    specs = model_specs(cfg)
    result["param_count"] = count_params(specs)
    pdt = jnp.dtype(cfg.param_dtype)
    params_abs = abstract_params(specs, dtype=pdt)
    axes = logical_axes(specs)

    with mesh, sharding_rules(mesh, overrides):
        p_sh = shardings_for(mesh, axes, params_abs)
        bspecs, baxes = batch_specs(cfg, shape)
        b_sh = shardings_for(mesh, baxes, bspecs)

        if shape.kind == "train":
            tcfg = tcfg or TrainConfig()
            lr_fn = warmup_cosine(3e-4, 100, 1000)
            step_fn = make_train_step(cfg, tcfg, lr_fn)
            odt = jnp.dtype(tcfg.opt_state_dtype)
            opt_abs = AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                m=abstract_params(specs, dtype=odt),
                v=abstract_params(specs, dtype=odt),
            )
            o_sh = AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_abs, opt_abs, bspecs)
            state_bytes = (
                _sharded_bytes(params_abs, p_sh, n_dev)
                + 2 * _sharded_bytes(opt_abs.m, p_sh, n_dev)
            )
        elif shape.kind == "prefill":
            from repro.distributed.sharding import spec_for
            from repro.train.train_step import make_prefill_step

            step_fn = make_prefill_step(cfg)
            # Keep logits vocab-TP-sharded on the way out: leaving the output
            # sharding open makes GSPMD replicate the (d, V) unembed table
            # on every chip (measured 2.2GB/step, §Perf it5).
            logits_sh = NamedSharding(mesh, spec_for(("batch", None, "vocab_act")))
            jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh),
                             out_shardings=logits_sh)
            lowered = jitted.lower(params_abs, bspecs)
            state_bytes = _sharded_bytes(params_abs, p_sh, n_dev)
        else:  # decode
            step_fn = make_serve_step(cfg)
            cache_abs, tok_abs = bspecs["cache"], bspecs["tokens"]
            c_sh, t_sh = b_sh["cache"], b_sh["tokens"]
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(NamedSharding(mesh, P()), c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_abs, cache_abs, tok_abs)
            state_bytes = (
                _sharded_bytes(params_abs, p_sh, n_dev)
                + _sharded_bytes(cache_abs, c_sh, n_dev)
            )

        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        result["flops_total"] = float(cost.get("flops", 0.0))
        result["hlo_bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        try:
            mem = compiled.memory_analysis()
            result["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            result["memory_analysis"] = {"error": str(e)}
        result["state_bytes_per_device"] = state_bytes
        hlo = compiled.as_text()
        result["collectives"] = parse_collectives(hlo)
        result["hlo_lines"] = hlo.count("\n")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["paper-bert"])
    ap.add_argument("--shape", choices=list(SHAPE_PRESETS))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--attention", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if args.all else [args.arch]
    shapes = list(SHAPE_PRESETS) if args.all else [args.shape]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    for arch, shape, mesh_kind in cells:
        tag = f"__{args.tag}" if args.tag else ""
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}{tag}.json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {path}")
            continue
        print(f"[run ] {arch} x {shape} x {mesh_kind} ...", flush=True)
        try:
            res = run_cell(
                arch, shape, mesh_kind == "multi",
                attention=args.attention, remat=args.remat,
                probe=(mesh_kind == "single"),  # roofline table is single-pod
            )
            res["status"] = "ok"
        except Exception:
            res = {
                "arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "error", "traceback": traceback.format_exc(),
            }
            print(res["traceback"])
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        print(f"[done] {path}: {res.get('status')} "
              f"compile={res.get('compile_s')}s flops={res.get('flops_total', 0):.3e}",
              flush=True)


if __name__ == "__main__":
    main()
