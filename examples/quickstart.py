"""Quickstart: spectral-shifting attention in three calls.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.attention import (
    SSConfig,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)

key = jax.random.PRNGKey(0)
n, d, c = 2048, 64, 64
# Self-similar tokens (q == k) — the diagonally-dominant regime real
# attention exhibits and where the spectral shift earns its keep.
x = jax.random.normal(key, (1, n, d)) * 0.5
v = jax.random.normal(jax.random.PRNGKey(1), (1, n, d))

exact = full_attention(x, x, v)

# The paper's method: landmark Nystrom factors + spectral shift delta*I.
cfg = SSConfig(num_landmarks=c, method="svd")
approx = spectral_shift_attention(x, x, v, cfg)
baseline = nystrom_attention(x, x, v, num_landmarks=c)

err = lambda a: float(jnp.linalg.norm(a - exact) / jnp.linalg.norm(exact))
print(f"sequence length n={n}, landmarks c={c}")
print(f"  spectral-shift rel. error : {err(approx):.4f}")
print(f"  nystromformer  rel. error : {err(baseline):.4f}")

# Timing: O(n^2) exact vs O(n) spectral shift.
f_exact = jax.jit(lambda q, k, v: full_attention(q, k, v))
f_ss = jax.jit(lambda q, k, v: spectral_shift_attention(q, k, v, cfg))
for name, fn in [("exact O(n^2)", f_exact), ("spectral-shift O(n)", f_ss)]:
    jax.block_until_ready(fn(x, x, v))  # compile
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(fn(x, x, v))
    print(f"  {name:22s}: {(time.perf_counter() - t0) / 10 * 1e3:.2f} ms/call")
