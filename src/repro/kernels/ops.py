"""Jitted wrapper: full spectral-shifting attention backed by Pallas kernels.

``ss_attention_fused(q, k, v, ...)`` computes the same function as
``repro.core.attention.spectral_shift_attention`` (non-causal path) but with
the two O(n) GEMMs executed by the Pallas kernels in ``ss_attention.py``:

    1. landmarks            (jnp: reshape+mean, trivial)
    2. A_s, U_ss, delta     (jnp: c x c, O(c^3))
    3. BV                   (Pallas: landmark_summary, streamed over n)
    4. M = U_ss @ BV        (jnp: c x c @ c x dv)
    5. out = F @ M + d * V  (Pallas: query_side, streamed over n)

Accepts (..., n, d) with arbitrary leading dims; leading dims are flattened
into the kernel batch dim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attention import SSConfig, _softmax
from repro.core.landmarks import segment_means
from repro.core.spectral_shift import ss_core
from repro.kernels.ss_attention import landmark_summary, query_side


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "scale", "block_n", "interpret"),
)
def ss_attention_fused(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig = SSConfig(),
    *,
    scale: Optional[float] = None,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas-backed spectral-shifting attention. Shapes (..., n, d)."""
    if cfg.causal:
        raise NotImplementedError(
            "fused kernel is bidirectional/decode-only; use the jnp path for "
            "the segment-causal variant"
        )
    *lead, n, d = q.shape
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    b = 1
    for s_ in lead:
        b *= s_
    qf = q.reshape(b, n, d)
    kf = k.reshape(b, k.shape[-2], d)
    vf = v.reshape(b, v.shape[-2], dv)

    q_l = segment_means(qf, cfg.num_landmarks)  # (b, c, d)
    k_l = segment_means(kf, cfg.num_landmarks)

    # c x c core in jnp (fp32 softmax).
    a = _softmax(
        jnp.einsum("bcd,bed->bce", q_l.astype(jnp.float32), k_l.astype(jnp.float32))
        * scale
    )
    core = ss_core(
        a,
        method=cfg.method,
        pinv_iters=cfg.pinv_iters,
        rank_tol=cfg.rank_tol,
        use_shift=cfg.use_shift,
    )

    bv = landmark_summary(
        q_l, kf, vf, scale=scale, block_n=block_n, interpret=interpret
    )  # (b, c, dv)
    m_mat = jnp.matmul(core.u.astype(jnp.float32), bv.astype(jnp.float32)).astype(
        v.dtype
    )
    delta = (
        core.delta
        if (cfg.include_shift_identity and qf.shape[1] == kf.shape[1])
        else jnp.zeros_like(core.delta)
    )
    out = query_side(
        qf, k_l, m_mat, vf, delta, scale=scale, block_n=block_n,
        interpret=interpret,
    )
    return out.reshape(*lead, n, dv)


@functools.partial(
    jax.jit, static_argnames=("cfg", "scale", "block_n", "interpret")
)
def nystrom_attention_fused(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig = SSConfig(use_shift=False, include_shift_identity=False),
    *,
    scale: Optional[float] = None,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas-backed Nystromformer baseline (delta = 0)."""
    import dataclasses

    cfg = dataclasses.replace(cfg, use_shift=False, include_shift_identity=False)
    return ss_attention_fused(
        q, k, v, cfg, scale=scale, block_n=block_n, interpret=interpret
    )
