"""Block-paged KV cache: vLLM-style fixed-size token blocks over the
spectral-shift decode state.

Two pieces:

* ``BlockAllocator`` — host-side bookkeeping: a free list of fixed-size
  token blocks, per-request block tables, alloc/free/defragment and
  utilization stats. Block 0 is reserved as the permanently-zero block that
  backs unallocated block-table slots, so gathers never need a validity
  mask (the decode path's causal key mask already ignores positions past
  ``pos``).

* ``PagedKVCache`` — maps the ``cache_specs`` ParamSpec tree onto
  block-shaped device storage. Leaves with a ``cache_seq`` axis (attention
  K/V, MLA latents) live in shared block pools shaped
  ``(num_blocks, ..., block_size, ...)``; everything else (landmark running
  sums, SSM states, ``pos``) is small and fixed-size, so it stays dense per
  lane exactly like the seed engine. ``make_fused_step`` builds the whole
  decode tick (gather lane views -> batched decode -> commit touched
  blocks) as one jitted program; ``write_prefill`` installs a batched
  prefill's result; ``gather_views`` assembles the lane-stacked dense tree
  for inspection/tests.

The memory win is at the pool: ``num_blocks`` is sized to the expected
working set, not ``max_lanes * max_seq``. The per-tick gather materializes a
transient dense view (the decode kernels are contiguous-K/V); a paged
attention kernel would remove that copy and is left as a follow-up.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models.params import ParamSpec
from repro.serve.kv_cache import cache_leaf_layout

ZERO_BLOCK = 0  # reserved all-zero block id backing unallocated table slots


# ==========================================================================
# Host-side block bookkeeping
# ==========================================================================
class BlockAllocator:
    """Free-list allocator of fixed-size token blocks with per-request
    block tables. Pure host-side bookkeeping; device storage is owned by
    ``PagedKVCache``."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least one allocatable block past block 0")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list (recently freed blocks are reused first — they are
        # the ones most likely still resident in cache). Block 0 excluded.
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self.tables: dict[int, list[int]] = {}  # request uid -> block ids

    # -- queries ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= self.num_free

    def stats(self) -> dict:
        usable = self.num_blocks - 1
        return {
            "num_blocks": usable,
            "blocks_used": self.num_used,
            "blocks_free": self.num_free,
            "utilization": self.num_used / max(usable, 1),
            "requests": len(self.tables),
        }

    # -- mutation -----------------------------------------------------------
    def alloc(self, uid: int, n_blocks: int) -> Optional[list[int]]:
        """Append ``n_blocks`` fresh blocks to ``uid``'s table. Returns the
        new block ids, or None (no state change) if the pool is short."""
        if n_blocks > self.num_free:
            return None
        got = [self._free.pop() for _ in range(n_blocks)]
        self.tables.setdefault(uid, []).extend(got)
        return got

    def free(self, uid: int) -> list[int]:
        """Release every block owned by ``uid``; returns the freed ids."""
        blocks = self.tables.pop(uid, [])
        self._free.extend(reversed(blocks))
        return blocks

    def defragment(self) -> dict[int, int]:
        """Compact live blocks onto the lowest ids. Returns the {old: new}
        mapping (identity entries omitted); the caller must permute device
        storage with the same mapping (``PagedKVCache.apply_mapping``)."""
        live = sorted(b for blocks in self.tables.values() for b in blocks)
        mapping = {
            old: new for new, old in enumerate(live, start=1) if old != new
        }
        if mapping:
            for blocks in self.tables.values():
                blocks[:] = [mapping.get(b, b) for b in blocks]
            n_live = len(live)
            self._free = list(range(self.num_blocks - 1, n_live, -1))
        return mapping


# ==========================================================================
# Device-side block-pool storage
# ==========================================================================
@dataclasses.dataclass
class _LeafInfo:
    spec: ParamSpec
    seq_axis: Optional[int]  # index of the cache_seq axis, None = dense leaf


def _leaf_infos(cfg: ModelConfig, max_seq: int) -> tuple[list[_LeafInfo], Any]:
    leaves, treedef = cache_leaf_layout(cfg, max_seq)
    return [_LeafInfo(spec, j) for spec, j in leaves], treedef


class PagedKVCache:
    """Block-pool device storage for one engine's decode state.

    With ``paged=False`` every leaf (including K/V) is stored lane-dense —
    bitwise the seed engine's layout — which is the comparison baseline for
    the paged path and the fallback when a model has no sequence-shaped
    cache at all (pure SSM stacks)."""

    def __init__(self, cfg: ModelConfig, serve: ServeConfig):
        self.cfg, self.serve = cfg, serve
        self.block_size = serve.block_size
        self.max_lanes, self.max_seq = serve.max_lanes, serve.max_seq
        self.num_blocks = serve.resolved_num_blocks
        self.infos, self.treedef = _leaf_infos(cfg, serve.max_seq)
        self.paged = serve.paged and any(
            i.seq_axis is not None for i in self.infos
        )
        self._storage: list[jnp.ndarray] = []
        for info in self.infos:
            dt = info.spec.dtype or jnp.float32
            if self.paged and info.seq_axis is not None:
                shape = list(info.spec.shape)
                shape[info.seq_axis] = self.block_size
                self._storage.append(
                    jnp.zeros((self.num_blocks, *shape), dt)
                )
            else:
                self._storage.append(
                    jnp.zeros((self.max_lanes, *info.spec.shape), dt)
                )

    @property
    def has_paged_leaves(self) -> bool:
        return self.paged

    def pool_tokens(self) -> int:
        """Capacity of the shared pool, in tokens (0 when not paged)."""
        return (self.num_blocks - 1) * self.block_size if self.paged else 0

    # -- assemble the dense view decode_step expects -------------------------
    def _gather_leaf(self, arr, info: _LeafInfo, tables) -> jnp.ndarray:
        """Pool (num_blocks, ..., bs, ...) + tables (lanes, nb) ->
        lane-stacked view (lanes, ..., nb*bs, ...)."""
        j = info.seq_axis
        g = jnp.take(arr, tables, axis=0)  # (lanes, nb, ..., bs, ...)
        g = jnp.moveaxis(g, 1, 1 + j)      # nb next to its bs axis
        shape = info.spec.shape
        view_len = tables.shape[1] * self.block_size
        return g.reshape(self.max_lanes, *shape[:j], view_len,
                         *shape[j + 1:])

    def gather_views(self, tables: np.ndarray) -> Any:
        """tables (max_lanes, blocks_per_lane) int32, ZERO_BLOCK where
        unallocated. Returns the lane-stacked dense cache tree: every leaf
        (max_lanes, *spec.shape)."""
        tb = jnp.asarray(tables, jnp.int32)
        leaves = [
            arr if (not self.paged or info.seq_axis is None)
            else self._gather_leaf(arr, info, tb)
            for arr, info in zip(self._storage, self.infos)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- write paths ----------------------------------------------------------
    def write_prefill(
        self, lane: int, prefill_tree: Any, table_row: np.ndarray,
        n_tokens: int,
    ) -> None:
        """Install a batched-prefill result (a B=1 cache tree whose seq
        leaves are padded-prompt long, a block multiple) into ``lane``:
        the first ``ceil(n_tokens / block_size)`` blocks of each seq leaf go
        to the lane's allocated blocks (positions past ``n_tokens`` are
        zero-masked, matching what unallocated slots read as), dense leaves
        overwrite the lane's dense slots."""
        new_leaves = jax.tree_util.tree_leaves(prefill_tree)
        bs = self.block_size
        nb = -(-n_tokens // bs)
        for idx, info in enumerate(self.infos):
            j = info.seq_axis
            leaf = new_leaves[idx]
            if not self.paged or j is None:
                if j is not None and leaf.shape[j] != self.max_seq:
                    pad = [(0, 0)] * leaf.ndim
                    pad[j] = (0, self.max_seq - leaf.shape[j])
                    leaf = jnp.pad(leaf, pad)
                self._storage[idx] = self._storage[idx].at[lane].set(leaf)
                continue
            if leaf.shape[j] % bs:  # ss_fused runs unpadded prompt lengths
                pad = [(0, 0)] * leaf.ndim
                pad[j] = (0, -leaf.shape[j] % bs)
                leaf = jnp.pad(leaf, pad)
            shape = leaf.shape
            n_blocks_pad = shape[j] // bs
            split = leaf.reshape(
                *shape[:j], n_blocks_pad, bs, *shape[j + 1:]
            )
            split = jnp.moveaxis(split, j, 0)  # (n_blocks_pad, ..., bs, ...)
            ids = jnp.asarray(table_row[:nb], jnp.int32)
            self._storage[idx] = self._storage[idx].at[ids].set(split[:nb])

    def make_fused_step(self, vmapped_decode_step):
        """One jitted XLA program for the whole decode tick:
        gather lane views from the pool -> batched decode step -> commit
        (dense leaves masked to active lanes; the touched K/V block of each
        active lane scattered back). Pool buffers are donated, so block
        writes update in place instead of copying the pool every tick.

        Views are gathered only ``n_view_blocks`` long — the engine passes
        the (bucketed) block count of the longest active sequence, so short
        working sets pay short gathers and short attention reads; the
        decode step's ``seq_max`` keeps landmark segmentation pinned to the
        full horizon regardless of view length.

        Returns ``fn(storage, tables, tokens, positions, active,
        n_view_blocks) -> (logits, new_storage)``; one XLA program compiles
        per distinct ``n_view_blocks``; the engine swaps its storage list
        for the returned one."""
        infos, treedef = self.infos, self.treedef
        paged, bs = self.paged, self.block_size
        n_lanes = self.max_lanes

        def fused(storage, tables, tokens, positions, active):
            views = [
                arr if (not paged or info.seq_axis is None)
                else self._gather_leaf(arr, info, tables)
                for arr, info in zip(storage, infos)
            ]
            cache = jax.tree_util.tree_unflatten(treedef, views)
            logits, new_cache = vmapped_decode_step(cache, tokens)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for arr, new, info in zip(storage, new_leaves, infos):
                if not paged or info.seq_axis is None:
                    mask = active.reshape((n_lanes,) + (1,) * (arr.ndim - 1))
                    out.append(jnp.where(mask, new.astype(arr.dtype), arr))
                    continue
                j = info.seq_axis

                def ext(per_lane, p, j=j):
                    return jax.lax.dynamic_slice_in_dim(
                        per_lane, (p // bs) * bs, bs, axis=j
                    )

                blocks = jax.vmap(ext)(new, positions)
                ids = tables[jnp.arange(n_lanes), positions // bs]
                # inactive lanes dump into the zero block, re-zeroed below
                ids = jnp.where(active, ids, ZERO_BLOCK)
                pool = arr.at[ids].set(blocks.astype(arr.dtype))
                pool = pool.at[ZERO_BLOCK].set(
                    jnp.zeros_like(pool[ZERO_BLOCK])
                )
                out.append(pool)
            return logits, out

        jitted = jax.jit(fused, donate_argnums=(0,))

        def call(storage, tables, tokens, positions, active, n_view_blocks):
            if self.paged:
                tables = tables[:, :n_view_blocks]
            return jitted(storage, tables, tokens, positions, active)

        return call

    def make_rebase_step(self, vmapped_rebase):
        """Jitted frozen-mode boundary rebase (serve/decode_state.py):
        gather lane views from the pool -> vmapped ``rebase_streaming`` ->
        commit the lane-dense streaming-stat leaves of flagged lanes. The
        paged K/V pool is read (the rebase recomputes two landmark rows over
        the horizon) but never written, so only dense leaves commit.

        Returns ``fn(storage, tables, positions, flags, n_view_blocks) ->
        new_storage``; like ``make_fused_step``, one XLA program compiles
        per distinct ``n_view_blocks`` and pool buffers are donated."""
        infos, treedef = self.infos, self.treedef
        paged = self.paged
        n_lanes = self.max_lanes

        def fused(storage, tables, positions, flags):
            views = [
                arr if (not paged or info.seq_axis is None)
                else self._gather_leaf(arr, info, tables)
                for arr, info in zip(storage, infos)
            ]
            cache = jax.tree_util.tree_unflatten(treedef, views)
            new_cache = vmapped_rebase(cache, positions)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for arr, new, info in zip(storage, new_leaves, infos):
                if not paged or info.seq_axis is None:
                    mask = flags.reshape((n_lanes,) + (1,) * (arr.ndim - 1))
                    out.append(jnp.where(mask, new.astype(arr.dtype), arr))
                else:
                    out.append(arr)
            return out

        jitted = jax.jit(fused, donate_argnums=(0,))

        def call(storage, tables, positions, flags, n_view_blocks):
            if self.paged:
                tables = tables[:, :n_view_blocks]
            return jitted(storage, tables, positions, flags)

        return call

    def view_blocks_needed(self, positions, lanes) -> int:
        """Bucketed (next power of two) block count covering the deepest
        active position; a handful of tick programs total."""
        if not self.paged or not lanes:
            return self.max_seq // self.block_size
        need = max(int(positions[i]) // self.block_size + 1 for i in lanes)
        nb = 1
        while nb < need:
            nb *= 2
        return min(nb, self.max_seq // self.block_size)

    def zero_lane_dense(self, lane: int) -> None:
        """Fresh-request reset of a lane's dense (non-paged) state."""
        for idx, info in enumerate(self.infos):
            if self.paged and info.seq_axis is not None:
                continue
            self._storage[idx] = self._storage[idx].at[lane].set(
                jnp.zeros_like(self._storage[idx][lane])
            )

    def apply_mapping(self, mapping: dict[int, int]) -> None:
        """Permute pool storage after ``BlockAllocator.defragment``."""
        if not mapping or not self.paged:
            return
        old = jnp.asarray(list(mapping.keys()), jnp.int32)
        new = jnp.asarray(list(mapping.values()), jnp.int32)
        for idx, info in enumerate(self.infos):
            if info.seq_axis is None:
                continue
            arr = self._storage[idx]
            self._storage[idx] = arr.at[new].set(arr[old])
