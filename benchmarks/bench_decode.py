"""Per-token decode latency vs cache horizon: recompute vs streaming state.

The legacy spectral-shift decode rebuilds the landmark-to-key softmax
``B = softmax(Q~ K^T)`` and its value summary ``B V`` over the whole cache
horizon every tick — O(c*S*d) per token, linear in S with slope c. The
streaming decode state (serve/decode_state.py) carries per-landmark
online-softmax partials in the cache instead:

    exact   — flash-append + ONE row recomputed per tick: O(S*d + c*d),
              linear with slope 1 (a c-fold cut), token-identical greedy;
    frozen  — fully streamed O(c*d) per tick (near-flat in S) plus an
              amortized two-row rebase at segment boundaries.

Cells: ``dense`` drives a donated jitted ``decode_step`` on a lane-dense
cache (pure decode-math cost); ``paged`` drives the block-pool fused tick
(gather -> step -> scatter), whose gather adds an O(S)-bytes term in every
mode. Caches are seeded synthetically (random K/V + consistent landmark
sums + exact streaming stats) so the 32k cell doesn't need a 32k-token
prefill. Frozen-mode per-token numbers charge the boundary rebase at its
amortized steady-state rate: the rebase program is timed separately and
one rebase per ``seg = ceil(S/c)`` tokens is added (the engine fires it
exactly once per segment), reported alongside as ``rebase_ms``.

    PYTHONPATH=src python -m benchmarks.run --only decode
    REPRO_BENCH_SMOKE=1 ... (one tiny horizon for CI)
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.attention import _broadcast_kv
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.decode import decode_step
from repro.serve.decode_state import (
    landmark_counts,
    landmark_means,
    make_rebase_fn,
    recompute_stats,
    segment_len,
)
from repro.serve.paged import BlockAllocator, PagedKVCache, ZERO_BLOCK

MODES = ("recompute", "exact", "frozen")


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def _setup():
    # scan_layers=False: per-layer cache leaves are separate donated jit
    # arguments, so the K/V updates alias in place — a layer scan routes
    # the cache through scan outputs, which forces an O(S) copy per tick
    # that would mask the attention-cost differences this bench measures.
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0,
        decode_attention_impl="spectral_shift", scan_layers=False,
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


@functools.partial(jax.jit, static_argnames=("cfg", "s_max", "pos"))
def _synthetic_cache(cfg, s_max: int, pos: int, key):
    """B=1 decode cache at write position ``pos+1``: random K/V, landmark
    sums consistent with them, and exact streaming stats — everything a
    decode tick reads, without paying an O(S) prefill at bench setup."""
    h, hkv, dh, c = (
        cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
        cfg.num_landmarks,
    )
    seg = segment_len(s_max, c)
    t = jnp.arange(s_max)
    t_mask = (t <= pos).astype(jnp.float32)
    oh = (
        ((t // seg)[None, :] == jnp.arange(c)[:, None]).astype(jnp.float32)
        * t_mask[None, :]
    )  # (c, S)
    counts = landmark_counts(jnp.asarray(pos), s_max, c)
    scale = dh ** -0.5

    def layer(key):
        ks = jax.random.split(key, 3)
        kk = jax.random.normal(ks[0], (1, hkv, s_max, dh)) * 0.5 * t_mask[:, None]
        vv = jax.random.normal(ks[1], (1, hkv, s_max, dh)) * t_mask[:, None]
        qq = jax.random.normal(ks[2], (1, h, s_max, dh)) * 0.5 * t_mask[:, None]
        q_lmk = jnp.einsum("cs,bhsd->bhcd", oh, qq)
        k_lmk = jnp.einsum("cs,bhsd->bhcd", oh, kk)
        kb = _broadcast_kv(kk, h)
        vb = _broadcast_kv(vv, h)
        m, l, acc = recompute_stats(
            landmark_means(q_lmk, counts), kb, vb, pos, scale,
            row_valid=counts > 0,
        )
        return {
            "k": kk, "v": vv, "q_lmk": q_lmk, "k_lmk": k_lmk,
            "bv_m": m, "bv_l": l, "bv_acc": acc,
        }

    keys = jax.random.split(key, cfg.num_layers)
    if cfg.scan_layers:
        layers = jax.vmap(layer)(keys)
    else:
        layers = [layer(k) for k in keys]
    return {"pos": jnp.asarray(pos + 1, jnp.int32), "layers": layers}


def _dense_cell(rows, cfg, params, horizon: int, mode: str, tokens: int):
    mcfg = dataclasses.replace(cfg, decode_streaming=mode)
    seg = segment_len(horizon, mcfg.num_landmarks)
    pos0 = horizon - tokens - 2
    cache = _synthetic_cache(mcfg, horizon, pos0, jax.random.PRNGKey(1))
    step = jax.jit(
        lambda c, t: decode_step(params, mcfg, c, t), donate_argnums=(0,)
    )
    tok = jnp.ones((1, 1), jnp.int32)
    _, cache = step(cache, tok)  # compile + warmup (advances pos by 1)
    rebase_ms = 0.0
    if mode == "frozen":
        # Time the boundary-rebase program on its own; the steady-state
        # per-token cost charges one rebase per segment (seg tokens).
        rebase = jax.jit(make_rebase_fn(mcfg, horizon), donate_argnums=(0,))
        cache = rebase(cache, jnp.asarray(pos0 + 1))  # compile
        jax.block_until_ready(jax.tree.leaves(cache)[0])
        t0 = time.perf_counter()
        for _ in range(2):
            cache = rebase(cache, jnp.asarray(pos0 + 1))
        jax.block_until_ready(jax.tree.leaves(cache)[0])
        rebase_ms = (time.perf_counter() - t0) / 2 * 1e3
        rows.append(
            f"decode,dense_h{horizon}_{mode},rebase_ms,{rebase_ms:.3f}"
        )
    jax.block_until_ready(jax.tree.leaves(cache)[0])
    t0 = time.perf_counter()
    for _ in range(tokens):
        logits, cache = step(cache, tok)
    jax.block_until_ready(logits)
    ms = (time.perf_counter() - t0) / tokens * 1e3 + rebase_ms / seg
    rows.append(f"decode,dense_h{horizon}_{mode},per_token_ms,{ms:.3f}")
    return ms


def _paged_cell(rows, cfg, params, horizon: int, mode: str, tokens: int):
    mcfg = dataclasses.replace(cfg, decode_streaming=mode)
    seg = segment_len(horizon, mcfg.num_landmarks)
    block = max(horizon // 64, 16)
    serve = ServeConfig(max_lanes=1, max_seq=horizon, block_size=block)
    kv = PagedKVCache(mcfg, serve)
    alloc = BlockAllocator(serve.resolved_num_blocks, serve.block_size)
    pos0 = horizon - tokens - 2
    alloc.alloc(0, alloc.blocks_for_tokens(pos0 + 1))
    tables = np.full((1, serve.blocks_per_lane), ZERO_BLOCK, np.int32)
    row = alloc.tables[0]
    tables[0, : len(row)] = row
    cache = _synthetic_cache(mcfg, horizon, pos0, jax.random.PRNGKey(1))
    kv.write_prefill(0, cache, tables[0], n_tokens=pos0 + 1)
    step = functools.partial(decode_step, params, mcfg, seq_max=horizon)
    fused = kv.make_fused_step(jax.vmap(step))
    nb = kv.view_blocks_needed(np.asarray([horizon - 1]), [0])
    tok = np.ones((1, 1, 1), np.int32)
    active = np.asarray([True])

    def tick(pos):
        nonlocal tables
        need = pos // block
        if need >= len(alloc.tables[0]):
            alloc.alloc(0, 1)
            tables = np.full((1, serve.blocks_per_lane), ZERO_BLOCK, np.int32)
            tables[0, : len(alloc.tables[0])] = alloc.tables[0]
        logits, new_storage = fused(
            kv._storage, jnp.asarray(tables), jnp.asarray(tok),
            jnp.asarray([pos], np.int32), jnp.asarray(active), nb,
        )
        kv._storage = list(new_storage)
        return logits

    lg = tick(pos0 + 1)  # compile + warmup
    rebase_ms = 0.0
    if mode == "frozen":
        rebase = kv.make_rebase_step(jax.vmap(make_rebase_fn(mcfg, horizon)))

        def run_rebase(pos):
            kv._storage = list(rebase(
                kv._storage, jnp.asarray(tables),
                jnp.asarray([pos], np.int32), jnp.asarray(active), nb,
            ))

        run_rebase(pos0 + 1)  # compile
        jax.block_until_ready(kv._storage[0])
        t0 = time.perf_counter()
        for _ in range(2):
            run_rebase(pos0 + 1)
        jax.block_until_ready(kv._storage[0])
        rebase_ms = (time.perf_counter() - t0) / 2 * 1e3
        rows.append(
            f"decode,paged_h{horizon}_{mode},rebase_ms,{rebase_ms:.3f}"
        )
    jax.block_until_ready(lg)
    t0 = time.perf_counter()
    for i in range(tokens):
        lg = tick(pos0 + 2 + i)
    jax.block_until_ready(lg)
    ms = (time.perf_counter() - t0) / tokens * 1e3 + rebase_ms / seg
    rows.append(f"decode,paged_h{horizon}_{mode},per_token_ms,{ms:.3f}")
    return ms


def run(rows: list[str]) -> None:
    cfg, params = _setup()
    if _smoke():
        horizons, tokens = (512,), 4
    else:
        horizons, tokens = (1024, 8192, 32768), 8
    for h in horizons:
        ms = {}
        for mode in MODES:
            ms[mode] = _dense_cell(rows, cfg, params, h, mode, tokens)
        for mode in MODES:
            _paged_cell(rows, cfg, params, h, mode, tokens)
        rows.append(
            f"decode,dense_h{h},exact_speedup_vs_recompute,"
            f"{ms['recompute'] / max(ms['exact'], 1e-9):.2f}"
        )
        rows.append(
            f"decode,dense_h{h},frozen_speedup_vs_recompute,"
            f"{ms['recompute'] / max(ms['frozen'], 1e-9):.2f}"
        )


if __name__ == "__main__":
    out: list[str] = []
    run(out)
    print("name,case,metric,value")
    print("\n".join(out))
