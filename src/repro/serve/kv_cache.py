"""KV-cache (and recurrent-state) layout for batched serving.

Caches are ParamSpec trees (reusing models/params.py) so the dry-run can
lower them abstractly and the sharding rules apply uniformly:

* attention caches: (L, B, H_kv, S, Dh) — batch over ("pod","data"), heads
  over "model"; for ``long_500k`` the rules override ``cache_seq`` -> data
  (sequence-parallel cache, batch unsharded).
* landmark state: the paper-technique addition — running segment SUMS of the
  query/key projections, (L, B, H, c, Dh). Counts are derived from ``pos``
  (segment j holds clip(pos+1 - j*l, 0, l) tokens), so means never go stale.
* streaming B-side state (serve/decode_state.py): per-landmark online-
  softmax partials ``bv_m``/``bv_l`` (L, B, H, c, 1) and the running BV
  numerator ``bv_acc`` (L, B, H, c, Dv). Lane-dense like the landmark sums
  (fixed size, no ``cache_seq`` axis); zeros is their valid empty state, so
  they share the init/reset/prefill-overwrite machinery of the other dense
  leaves. Ignored (carried through untouched) by the legacy
  ``decode_streaming="recompute"`` path and by ``full`` decode attention.
* ssm/hybrid states: mLSTM (C, n, m), mamba (h, conv tail) per layer.

The ``cache_seq`` axis doubles as the SHARING boundary for prefix caching
(serve/paged.py ``PrefixCache``): only seq-shaped leaves live in the block
pool and can be mapped into multiple requests' block tables; every other
leaf here is lane-dense and position-dependent, so a cached prefix carries
them as a ``dense_snapshot`` host copy per block-aligned boundary (its
"stat points") that attach restores — the same mechanism parked-resume
uses. The snapshots are only meaningful at the segmentation they were
captured under; see decode_state.resegment_sums for the cross-segmentation
contract.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec

BATCH = "cache_batch"
SEQ = "cache_seq"


def _gqa_cache(cfg: ModelConfig, b: int, s: int) -> dict:
    h, hkv, dh, c = (
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.resolved_head_dim,
        cfg.num_landmarks,
    )
    f32 = jnp.float32
    return {
        "k": ParamSpec((b, hkv, s, dh), (BATCH, "kv_heads", SEQ, None), init="zeros"),
        "v": ParamSpec((b, hkv, s, dh), (BATCH, "kv_heads", SEQ, None), init="zeros"),
        "q_lmk": ParamSpec((b, h, c, dh), (BATCH, "heads", None, None), init="zeros"),
        "k_lmk": ParamSpec((b, hkv, c, dh), (BATCH, "kv_heads", None, None), init="zeros"),
        "bv_m": ParamSpec((b, h, c, 1), (BATCH, "heads", None, None), init="zeros", dtype=f32),
        "bv_l": ParamSpec((b, h, c, 1), (BATCH, "heads", None, None), init="zeros", dtype=f32),
        "bv_acc": ParamSpec((b, h, c, dh), (BATCH, "heads", None, None), init="zeros", dtype=f32),
    }


def _mla_cache(cfg: ModelConfig, b: int, s: int) -> dict:
    r, dr, c, h = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.num_landmarks, cfg.num_heads
    de = r + dr  # effective (absorbed) key dim
    f32 = jnp.float32
    return {
        "latent": ParamSpec((b, s, r), (BATCH, SEQ, None), init="zeros"),
        "rope": ParamSpec((b, s, dr), (BATCH, SEQ, None), init="zeros"),
        "q_lmk": ParamSpec((b, h, c, de), (BATCH, "heads", None, None), init="zeros"),
        "k_lmk": ParamSpec((b, c, de), (BATCH, None, None), init="zeros"),
        "bv_m": ParamSpec((b, h, c, 1), (BATCH, "heads", None, None), init="zeros", dtype=f32),
        "bv_l": ParamSpec((b, h, c, 1), (BATCH, "heads", None, None), init="zeros", dtype=f32),
        # values are the kv_lora latents in absorbed MLA decode
        "bv_acc": ParamSpec((b, h, c, r), (BATCH, "heads", None, None), init="zeros", dtype=f32),
    }


def _mamba_state(cfg: ModelConfig, b: int, d_inner: int) -> dict:
    return {
        "ssm_h": ParamSpec(
            (b, d_inner, cfg.ssm_state), (BATCH, "ff_act", None),
            init="zeros", dtype=jnp.float32,
        ),
        "conv": ParamSpec(
            (b, cfg.conv_width - 1, d_inner), (BATCH, None, "ff_act"), init="zeros"
        ),
    }


def _mlstm_state(cfg: ModelConfig, b: int) -> dict:
    di = 2 * cfg.d_model
    h = cfg.num_heads
    dh = di // h
    f32 = jnp.float32
    return {
        "c": ParamSpec((b, h, dh, dh), (BATCH, "heads", None, None), init="zeros", dtype=f32),
        "n": ParamSpec((b, h, dh), (BATCH, "heads", None), init="zeros", dtype=f32),
        "m": ParamSpec((b, h), (BATCH, "heads"), init="zeros", dtype=f32),
        "conv": ParamSpec((b, cfg.conv_width - 1, di), (BATCH, None, "ff_act"), init="zeros"),
    }


def _slstm_state(cfg: ModelConfig, b: int) -> dict:
    h = cfg.num_heads
    dh = cfg.d_model // h
    f32 = jnp.float32
    return {
        k: ParamSpec((b, h, dh), (BATCH, "heads", None), init="zeros", dtype=f32)
        for k in ("c", "n", "m", "h")
    }


def _stack(layer: dict, n: int) -> dict:
    from repro.models.params import stack_layer_specs

    return stack_layer_specs(layer, n)


def cache_leaf_layout(cfg: ModelConfig, seq_len: int):
    """Flatten the B=1 cache tree for block-paged storage planning.

    Returns ``(leaves, treedef)`` where each leaf is ``(spec, seq_axis)``:
    ``seq_axis`` is the index of the ``cache_seq`` dimension (pageable into
    token blocks) or None for fixed-size state (landmark running sums, SSM
    states, ``pos``) that stays dense per lane."""
    import jax

    from repro.models.params import ParamSpec

    specs = cache_specs(cfg, 1, seq_len)
    paths, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    leaves = [
        (spec, spec.axes.index(SEQ) if SEQ in spec.axes else None)
        for _, spec in paths
    ]
    return leaves, treedef


STREAM_STAT_LEAVES = ("bv_m", "bv_l", "bv_acc")


def stream_leaf_indices(cfg: ModelConfig, seq_len: int) -> dict:
    """Flat-leaf indices (``cache_leaf_layout`` order) of the streaming
    online-softmax stat leaves, keyed by leaf name.

    ``cache_leaf_layout`` drops path names, but telemetry's drift/spectrum
    monitors need to find ``bv_m``/``bv_l``/``bv_acc`` inside the paged
    storage list. The flatten order here matches ``PagedKVCache.infos``
    exactly (both come from ``tree_flatten_with_path`` over the same spec
    tree), and within it each name's indices are in layer order, so zipping
    the three lists yields per-layer ``(m, l, acc)`` triples. Families
    without streaming stats (ssm) return empty lists."""
    import jax

    from repro.models.params import ParamSpec

    specs = cache_specs(cfg, 1, seq_len)
    paths, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    out = {name: [] for name in STREAM_STAT_LEAVES}
    for i, (path, _spec) in enumerate(paths):
        key = getattr(path[-1], "key", None)
        if key in out:
            out[key].append(i)
    return out


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Full decode-state ParamSpec tree for one model."""
    specs: dict = {"pos": ParamSpec((), (), init="zeros", dtype=jnp.int32)}
    maybe_stack = (
        (lambda layer: _stack(layer, cfg.num_layers))
        if cfg.scan_layers
        else (lambda layer: [layer for _ in range(cfg.num_layers)])
    )
    if cfg.family in ("dense", "vlm"):
        specs["layers"] = maybe_stack(_gqa_cache(cfg, batch, seq_len))
    elif cfg.family == "moe":
        layer = _mla_cache(cfg, batch, seq_len) if cfg.mla else _gqa_cache(cfg, batch, seq_len)
        specs["layers"] = maybe_stack(layer)
    elif cfg.family == "hybrid":
        layer = {"attn": _gqa_cache(cfg, batch, seq_len),
                 "mamba": _mamba_state(cfg, batch, cfg.d_model)}
        specs["layers"] = maybe_stack(layer)
    elif cfg.family == "ssm":
        layers = []
        for i in range(cfg.num_layers):
            if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0:
                layers.append({"kind_slstm": _slstm_state(cfg, batch)})
            else:
                layers.append({"kind_mlstm": _mlstm_state(cfg, batch)})
        specs["layers"] = layers
    elif cfg.family == "audio":
        enc_len = 1500
        h, dh = cfg.num_heads, cfg.resolved_head_dim
        # Whisper's decoder stack is unrolled -> per-layer cache list.
        specs["layers"] = [
            _gqa_cache(cfg, batch, seq_len) for _ in range(cfg.num_layers)
        ]
        specs["cross_k"] = ParamSpec(
            (cfg.num_layers, batch, h, enc_len, dh),
            ("layers", BATCH, "heads", None, None), init="zeros",
        )
        specs["cross_v"] = ParamSpec(
            (cfg.num_layers, batch, h, enc_len, dh),
            ("layers", BATCH, "heads", None, None), init="zeros",
        )
    else:
        raise ValueError(cfg.family)
    return specs
