"""Multi-device integration tests. The test process owns the single CPU
device, so these spawn subprocesses with ``--xla_force_host_platform_device_count``
(same mechanism as the dry-run) to exercise real GSPMD partitioning,
shard_map pipeline parallelism and elastic restart."""
from __future__ import annotations

import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_pipeline_parallel_matches_reference():
    run_subprocess("""
import jax, jax.numpy as jnp
from repro.distributed.pipeline import (
    make_pipeline_forward, reference_forward, stack_stages,
)

mesh = jax.make_mesh((4,), ('pipe',))
key = jax.random.PRNGKey(0)
L, D, M, mb = 8, 32, 6, 4
layers = []
for i in range(L):
    k1, k2, key = jax.random.split(key, 3)
    layers.append({'w': jax.random.normal(k1, (D, D)) * 0.2,
                   'b': jax.random.normal(k2, (D,)) * 0.1})
layer_fn = lambda p, x: jnp.tanh(x @ p['w'] + p['b'])
stage_params = stack_stages(layers, 4)
x = jax.random.normal(jax.random.PRNGKey(9), (M, mb, D))
out = jax.jit(make_pipeline_forward(layer_fn, mesh, 'pipe'))(stage_params, x)
ref = reference_forward(layer_fn, layers, x.reshape(M * mb, D)).reshape(M, mb, D)
assert jnp.allclose(out, ref, atol=1e-5), float(jnp.max(jnp.abs(out - ref)))
print('OK')
""", num_devices=4)


@pytest.mark.slow
def test_elastic_restart_recovers_and_continues():
    run_subprocess("""
import jax
from repro.configs.base import ShapeConfig, TrainConfig, reduced
from repro.configs.registry import get_config
from repro.distributed.fault_tolerance import FailureInjector, HeartbeatMonitor
from repro.train.trainer import Trainer
import tempfile

cfg = reduced(get_config('qwen2-7b'))
shape = ShapeConfig('train_4k', 128, 8, 'train')
with tempfile.TemporaryDirectory() as d:
    tcfg = TrainConfig(checkpoint_dir=d, checkpoint_every=3, total_steps=20)
    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    inj = FailureInjector({6: ['host0']})
    mon = HeartbeatMonitor([f'host{i}' for i in range(4)], timeout_s=600)
    tr = Trainer(cfg, tcfg, shape, mesh, injector=inj, monitor=mon)
    hist = tr.run(10)
    assert tr.step == 10
    assert dict(tr.mesh.shape) == {'data': 2, 'model': 2}, dict(tr.mesh.shape)
    assert all(abs(h['loss']) < 100 for h in hist)
print('OK')
""", num_devices=8)


@pytest.mark.slow
def test_tp_sharded_training_matches_single_device():
    """Same seed, same data: TP=4 training equals single-device training."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ShapeConfig, TrainConfig, reduced
from repro.configs.registry import get_config
from repro.train.trainer import Trainer
import tempfile

cfg = reduced(get_config('qwen2-7b'))
shape = ShapeConfig('train_4k', 64, 4, 'train')
results = []
for shape_mesh in [(1, 1), (2, 4)]:
    devs = np.array(jax.devices()[: shape_mesh[0] * shape_mesh[1]]).reshape(shape_mesh)
    mesh = jax.sharding.Mesh(devs, ('data', 'model'))
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(checkpoint_dir=d, seed=0)
        tr = Trainer(cfg, tcfg, shape, mesh)
        tr.run(3, log_every=1000)
        results.append([np.asarray(x, np.float32)
                        for x in jax.tree.leaves(tr.params)])
for a, b in zip(*results):
    np.testing.assert_allclose(a, b, atol=2e-4)
print('OK')
""", num_devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_allreduce_multidevice():
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.optim.compression import make_compressed_grad_allreduce

mesh = jax.make_mesh((4,), ('data',))
f = make_compressed_grad_allreduce(mesh, 'data')
g = {'w': jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
r = {'w': jnp.zeros((64,), jnp.float32)}
red, new_r = f(g, r)
# SUM all-reduce of 4 identical replicated shards == 4x the shard
# (up to int8 quantization error, which also sums over participants).
err = float(jnp.max(jnp.abs(red['w'] - 4 * g['w'])))
scale = float(jnp.max(jnp.abs(g['w']))) / 127
assert err <= scale * 4 * 0.51 + 1e-6, (err, scale)
print('OK')
""", num_devices=4)


@pytest.mark.slow
def test_production_mesh_lowering_smoke():
    """One reduced arch lowers + compiles on the full 512-chip multi-pod
    mesh inside the test (cheap: reduced layer count)."""
    run_subprocess("""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
import jax, jax.numpy as jnp
from repro.configs.base import SHAPE_PRESETS, TrainConfig, reduced
from repro.configs.registry import get_config
from repro.launch.dryrun import run_cell

res = run_cell('qwen2-7b', 'train_4k', multi_pod=True, probe=False)
assert res['flops_total'] > 0
assert res['collectives'], 'expected collectives on the production mesh'
print('OK')
""", num_devices=512, timeout=900)
