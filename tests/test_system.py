"""End-to-end system behaviour: the paper's claims on the full stack.

These are the 'does the system do what the paper says' tests; unit-level
coverage lives in the per-module files."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.core.attention import (
    SSConfig,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)
from repro.models.model import model_forward, model_specs
from repro.models.params import init_params


def test_linear_time_scaling():
    """Paper Table 1: SS attention cost scales ~linearly in n (vs quadratic
    exact). Measured via jaxpr FLOP proxy: count dot_general output sizes."""
    def flops_of(fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args)
        total = 0

        def walk(jp):
            nonlocal total
            for eq in jp.eqns:
                if eq.primitive.name in ("dot_general",):
                    lhs, rhs = eq.invars[0].aval, eq.invars[1].aval
                    out = eq.outvars[0].aval
                    # FLOPs = 2 * prod(out shape) * contraction dim
                    dims = eq.params["dimension_numbers"][0][0]
                    kdim = 1
                    for d_ in dims:
                        kdim *= lhs.shape[d_]
                    total += 2 * int(np.prod(out.shape)) * kdim
                for sub in eq.params.values():
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)
        walk(jaxpr.jaxpr)
        return total

    d, c = 32, 32
    cfg = SSConfig(num_landmarks=c)
    key = jax.random.PRNGKey(0)
    fl_ss, fl_full = [], []
    for n in (256, 512, 1024):
        q = jax.random.normal(key, (1, n, d))
        fl_ss.append(flops_of(
            lambda q_: spectral_shift_attention(q_, q_, q_, cfg), q
        ))
        fl_full.append(flops_of(lambda q_: full_attention(q_, q_, q_), q))
    # SS: doubling n should ~double FLOPs (ratio < 2.4); full: ~4x.
    assert fl_ss[2] / fl_ss[1] < 2.4, fl_ss
    assert fl_full[2] / fl_full[1] > 3.5, fl_full


def test_ss_more_accurate_than_nystrom_on_attention():
    """Theorem-1 flavour on real attention: averaged over self-similar
    (diagonally dominant) attention patterns, SS error <= Nystrom error."""
    wins, total = 0, 8
    for seed in range(total):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (1, 384, 32))
        v = jax.random.normal(jax.random.PRNGKey(seed + 100), (1, 384, 32))
        exact = full_attention(x, x, v)
        ss = spectral_shift_attention(
            x, x, v, SSConfig(num_landmarks=48, method="svd")
        )
        ny = nystrom_attention(x, x, v, num_landmarks=48)
        e_ss = float(jnp.linalg.norm(ss - exact))
        e_ny = float(jnp.linalg.norm(ny - exact))
        wins += e_ss <= e_ny
    assert wins >= total // 2 + 1, f"SS won only {wins}/{total}"


def test_spectrum_not_low_rank():
    """Figure 2: the SS-approximated attention matrix has no truncated
    spectrum (full rank), unlike the Nystrom approximation."""
    key = jax.random.PRNGKey(0)
    n, c = 256, 32
    x = jax.random.normal(key, (n, 16)) * 0.7
    s = x @ x.T / 4.0
    p = jnp.exp(s - s.max(-1, keepdims=True))
    attn = p / p.sum(-1, keepdims=True)  # row-stochastic attention matrix

    from repro.core.matrix_approx import approximate_spsd, sample_columns

    cols = sample_columns(n, c)
    ny = approximate_spsd(attn, cols, "prototype")
    # target_rank selects the truncated-SS regime (delta = mean of the
    # discarded core tail) — the setting where Fig 2's claim applies.
    ss = approximate_spsd(attn, cols, "modified_ss", target_rank=c // 2)
    sv_ny = jnp.linalg.svd(ny, compute_uv=False)
    sv_ss = jnp.linalg.svd(ss, compute_uv=False)
    rank = lambda sv: int(jnp.sum(sv > 1e-6 * sv[0]))
    assert rank(sv_ny) <= c
    assert rank(sv_ss) == n


def test_end_to_end_training_with_ss_attention():
    """A model trained WITH spectral-shift attention learns (loss drops)."""
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.launch.mesh import make_local_mesh
    from repro.train.trainer import Trainer
    import tempfile

    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")),
        attention_impl="spectral_shift", num_landmarks=8,
    )
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3,
                           checkpoint_dir=d, total_steps=30)
        tr = Trainer(cfg, tcfg, ShapeConfig("train_4k", 64, 4, "train"),
                     make_local_mesh(1))
        hist = tr.run(25, log_every=1000)
    assert np.mean([h["loss"] for h in hist[-5:]]) < \
           np.mean([h["loss"] for h in hist[:5]]) - 0.05


def test_serve_quality_ss_vs_full_on_trained_model():
    """After a short training run, greedy decoding with SS attention agrees
    with exact attention on most early tokens (sanity of the serve path)."""
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.launch.mesh import make_local_mesh
    from repro.serve.engine import Request, ServeEngine
    from repro.train.trainer import Trainer
    import tempfile

    base = reduced(get_config("qwen2-7b"))
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=3,
                           checkpoint_dir=d)
        tr = Trainer(base, tcfg, ShapeConfig("train_4k", 64, 4, "train"),
                     make_local_mesh(1))
        tr.run(15, log_every=1000)
        params = tr.params

    # Teacher-force a 24-token prompt through both decode paths and compare
    # the next-token logits (trajectory comparison is chaotic: one token of
    # disagreement diverges everything after it).
    from repro.models.params import init_params as ip
    from repro.serve.decode import decode_step
    from repro.serve.kv_cache import cache_specs

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(3, 100, (1, 24)), jnp.int32)
    logits = {}
    for impl in ("full", "spectral_shift"):
        cfg = dataclasses.replace(base, decode_attention_impl=impl,
                                  num_landmarks=8)
        cache = ip(cache_specs(cfg, 1, 48), jax.random.PRNGKey(1))
        lg = None
        for i in range(prompt.shape[1]):
            lg, cache = decode_step(params, cfg, cache, prompt[:, i:i + 1])
        logits[impl] = np.asarray(lg[0, 0, : base.vocab_size], np.float32)
    corr = float(np.corrcoef(logits["full"], logits["spectral_shift"])[0, 1])
    top_f = set(np.argsort(logits["full"])[-10:])
    top_s = set(np.argsort(logits["spectral_shift"])[-10:])
    assert corr > 0.5 or len(top_f & top_s) >= 3, (corr, top_f, top_s)
