"""Serving launcher: continuous-batching engine demo on a reduced model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --requests 12 --lanes 4 --max-seq 192

Loads (or randomly initializes) a reduced config, submits a synthetic
request stream and drives the engine to completion, printing throughput.
The decode path is the paper's spectral-shifting attention with the
incrementally-maintained landmark state (serve/decode.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=192)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    if cfg.family == "audio":
        raise SystemExit("whisper serving needs encoder features; use examples/")
    specs = model_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(args.seed))

    engine = ServeEngine(
        cfg, params, max_lanes=args.lanes, max_seq=args.max_seq, seed=args.seed
    )
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        prompt = rng.integers(3, cfg.vocab_size, size=args.prompt_len).tolist()
        engine.submit(
            Request(uid, prompt, max_new_tokens=args.max_new,
                    temperature=args.temperature)
        )

    t0 = time.time()
    outputs = engine.run()
    dt = time.time() - t0
    total_new = sum(len(v) for v in outputs.values())
    print(
        f"[serve] {args.arch}: {len(outputs)}/{args.requests} requests, "
        f"{total_new} tokens in {dt:.2f}s "
        f"({total_new / max(dt, 1e-9):.1f} tok/s, lanes={args.lanes})"
    )
    for uid in sorted(outputs)[:3]:
        print(f"  req {uid}: {outputs[uid][:12]}...")
    return outputs


if __name__ == "__main__":
    main()
