"""Continuous batching: chunked prefill riding the decode tick.

The load-bearing contract is TOKEN IDENTITY: splitting a prompt into
fixed-size chunks — committed across many ticks, with landmark prefix sums
and online-softmax stream stats carried chunk to chunk — must produce
exactly the greedy tokens of the whole-prompt two-phase engine (and of the
original token-replay engine). On top of that: preemption parks a
mid-prefill lane and resumes at the completed-chunk boundary without
changing outputs, decode lanes never starve under a long-prompt flood,
resume latency lands in its own histogram, Poisson traces replay
deterministically, and the flight recorder coalesces chunk runs into
valid Perfetto traces.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import BlockAllocator
from repro.serve.scheduler import Scheduler
from repro.serve.workload import latency_metrics, poisson_trace, replay_trace
from repro.telemetry.export import chrome_trace, validate_trace

# prompt lengths exercise every divisibility case: 37 and 9 are not
# block-multiples, 24 divides chunk 8 but not 24, 50 divides neither
PROMPT_LENS = (37, 9, 24, 50)
MAX_NEW = 6


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lens=PROMPT_LENS, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, cfg.vocab_size, int(p)).tolist() for p in lens]


def _outputs(cfg, params, serve, prompts, max_new=MAX_NEW):
    eng = ServeEngine(cfg, params, serve=serve)
    for u, p in enumerate(prompts):
        eng.submit(Request(u, list(p), max_new_tokens=max_new))
    return dict(eng.run()), eng


def _base(**kw):
    return ServeConfig(
        max_lanes=2, max_seq=64, block_size=8,
        paged=True, batched_prefill=True, **kw,
    )


# ==========================================================================
# Token identity: chunked == whole-prompt two-phase == token replay
# ==========================================================================
class TestChunkedIdentity:
    @pytest.fixture(scope="class")
    def two_phase(self, qwen):
        cfg, params = qwen
        prompts = _prompts(cfg)
        return {
            paged: _outputs(cfg, params, dataclasses.replace(
                _base(), paged=paged), prompts)[0]
            for paged in (True, False)
        }

    @pytest.mark.parametrize("paged", [True, False])
    @pytest.mark.parametrize("chunk", [8, 24])
    def test_matches_two_phase(self, qwen, two_phase, paged, chunk):
        cfg, params = qwen
        out, eng = _outputs(cfg, params, dataclasses.replace(
            _base(), paged=paged,
            chunked_prefill=True, prefill_chunk_tokens=chunk,
        ), _prompts(cfg))
        assert "chunked-prefill" in eng.stats()["mode"]
        assert out == two_phase[paged]

    def test_matches_token_replay(self, qwen):
        cfg, params = qwen
        prompts = _prompts(cfg)
        replay, _ = _outputs(cfg, params, dataclasses.replace(
            _base(), paged=False, batched_prefill=False), prompts)
        chunked, _ = _outputs(cfg, params, dataclasses.replace(
            _base(), chunked_prefill=True, prefill_chunk_tokens=16), prompts)
        assert chunked == replay

    def test_ss_fused_stats_handoff(self, qwen, two_phase):
        """Chunk attention is always exact replay math; ``prefill_impl``
        only routes the STATS handoff. With the fused landmark-summary
        path feeding the carry, greedy tokens must still match the exact
        two-phase baseline (stats agree to float tolerance; argmax is
        identical)."""
        cfg, params = qwen
        chunked, _ = _outputs(cfg, params, dataclasses.replace(
            _base(), prefill_impl="ss_fused",
            chunked_prefill=True, prefill_chunk_tokens=16), _prompts(cfg))
        assert chunked == two_phase[True]

    def test_chunked_requires_batched_prefill(self):
        with pytest.raises(ValueError):
            ServeConfig(max_lanes=1, max_seq=64, block_size=8, paged=False,
                        batched_prefill=False, chunked_prefill=True)


# ==========================================================================
# Preemption at chunk boundaries + parking
# ==========================================================================
class TestChunkedPreemption:
    def test_tight_pool_outputs_identical(self, qwen):
        """Under a pool too small for all four requests, preemption (with
        mid-prefill parking + chunk-boundary resume) must not change a
        single output token vs the uncontended two-phase run."""
        cfg, params = qwen
        prompts = _prompts(cfg, lens=(40, 48, 30, 20), seed=1)
        tight = dataclasses.replace(
            _base(), chunked_prefill=True, prefill_chunk_tokens=8,
            num_blocks=12,
        )
        out, eng = _outputs(cfg, params, tight, prompts, max_new=10)
        st = eng.stats()
        assert st["preemptions"] >= 1
        assert st["resume_ttft_s_p50"] is not None
        ref, _ = _outputs(cfg, params, _base(), prompts, max_new=10)
        assert out == ref

    def test_all_prefill_deadlock_breaks(self, qwen):
        """Back-to-back admissions can leave EVERY lane stalled mid-prefill
        on a dry pool with no decode lane whose retirement could free
        blocks — the chunk-stall rule alone would livelock (stalled
        prefills hold each other's growth room, and the requeued victim
        re-admits for its parked blocks before the head can take them).
        The in-tick breaker preempts the youngest stalled prefill and
        retries dispatch in the same tick so the FCFS head reclaims the
        blocks first; the batch must drain with outputs identical to an
        uncontended pool."""
        cfg, params = qwen
        prompts = _prompts(cfg, lens=(40, 40, 40, 40), seed=2)

        def cfg4(**kw):
            return ServeConfig(
                max_lanes=4, max_seq=64, block_size=8, paged=True,
                batched_prefill=True, chunked_prefill=True,
                prefill_chunk_tokens=16, **kw,
            )

        out, eng = _outputs(cfg, params, cfg4(num_blocks=10), prompts)
        st = eng.stats()
        assert len(out) == 4          # nothing starved at the tick cap
        assert st["preemptions"] >= 1  # the breaker had to fire
        ref, _ = _outputs(cfg, params, cfg4(), prompts)
        assert out == ref

    def test_resume_ttft_histogram_routing(self):
        """The first post-resume token lands in serve_resume_ttft_seconds —
        never in ttft (already observed) and never in itl (the gap is
        scheduler pressure, not cadence)."""
        alloc = BlockAllocator(17, 8)
        sched = Scheduler(alloc, max_lanes=1, blocks_per_lane=8)
        req = Request(0, list(range(10)), max_new_tokens=4)
        sched.requeue_cb = lambda lane: req
        sched.submit(req)
        assert sched.admit()
        sched.note_token(0)
        assert sched._ttft_s.count == 1
        sched.preempt(0)
        assert sched.timing[0].requeued_s is not None
        assert sched.admit()
        sched.note_token(0)  # first post-resume token
        assert sched._resume_ttft_s.count == 1
        assert sched._ttft_s.count == 1  # unchanged
        assert sched._itl_s.count == 0
        assert sched.timing[0].requeued_s is None
        sched.note_token(0)  # steady cadence resumes
        assert sched._itl_s.count == 1
        assert sched._resume_ttft_s.count == 1


# ==========================================================================
# Starvation invariant: decode lanes survive a long-prompt flood
# ==========================================================================
class TestDecodeNeverStarves:
    def test_tick_gap_is_one_under_flood(self, qwen):
        cfg, params = qwen
        rng = np.random.default_rng(3)
        serve = ServeConfig(
            max_lanes=2, max_seq=96, block_size=8, paged=True,
            batched_prefill=True, chunked_prefill=True,
            prefill_chunk_tokens=8, prefill_token_budget=8,
        )
        eng = ServeEngine(cfg, params, serve=serve)
        ticks: dict[int, list[int]] = {}

        def on_tok(uid, tok):
            ticks.setdefault(uid, []).append(eng._tick)

        eng.submit(Request(0, rng.integers(3, cfg.vocab_size, 8).tolist(),
                           max_new_tokens=30, on_token=on_tok))
        for _ in range(3):
            eng.tick()
        for u in range(1, 4):  # flood: long prompts chunk in behind it
            eng.submit(Request(
                u, rng.integers(3, cfg.vocab_size, 80).tolist(),
                max_new_tokens=4, on_token=on_tok))
        eng.run()
        gaps = np.diff(ticks[0])
        assert len(ticks[0]) == 30
        assert int(gaps.max()) == 1


# ==========================================================================
# Deterministic Poisson workload replay
# ==========================================================================
class TestPoissonReplay:
    def test_trace_is_seed_deterministic(self):
        kw = dict(n_requests=10, mean_interarrival_ticks=2.0,
                  prompt_lens=(8, 40), vocab_size=1000)
        assert poisson_trace(seed=5, **kw) == poisson_trace(seed=5, **kw)
        assert poisson_trace(seed=5, **kw) != poisson_trace(seed=6, **kw)

    def test_replay_outputs_identical(self, qwen):
        cfg, params = qwen
        trace = poisson_trace(
            seed=11, n_requests=6, mean_interarrival_ticks=2.0,
            prompt_lens=(8, 40), vocab_size=cfg.vocab_size,
            max_new_tokens=5,
        )
        serve = dataclasses.replace(
            _base(), chunked_prefill=True, prefill_chunk_tokens=8)
        outs = []
        for _ in range(2):
            eng = ServeEngine(cfg, params, serve=serve)
            stamps = replay_trace(eng, trace)
            outs.append(dict(eng.finished))
            m = latency_metrics(stamps)
            assert m["n_requests"] == 6
            assert m["itl_p99_s"] is not None
        assert outs[0] == outs[1]
        assert sorted(outs[0]) == [it.uid for it in trace]


# ==========================================================================
# Flight lifelines + Perfetto export for chunk runs
# ==========================================================================
class TestChunkFlightTrace:
    def test_chunk_runs_coalesce_and_trace_validates(self, qwen):
        cfg, params = qwen
        serve = dataclasses.replace(
            _base(), max_lanes=1, chunked_prefill=True,
            prefill_chunk_tokens=8, telemetry=True,
        )
        out, eng = _outputs(cfg, params, serve, _prompts(cfg, lens=(40,)),
                            max_new=4)
        line = eng.telemetry.flight.lifeline(0)
        kinds = line.kinds()
        assert kinds == ["submit", "admit", "prefill_chunk", "decode",
                         "finish"]
        run = next(e for e in line.events if e["kind"] == "prefill_chunk")
        # 5 consecutive-tick chunks of 8 tokens coalesced into ONE run
        assert (run["n"], run["chunk0"], run["chunk1"]) == (5, 0, 4)
        assert (run["tok0"], run["tok1"]) == (0, 40)
        assert run["tick1"] == run["tick0"] + 4
        trace = chrome_trace(eng.telemetry)
        assert validate_trace(trace) == []
        names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "B"}
        assert "prefill_chunk" in names
