"""The paper's own experimental setting: a BERT-small-style bidirectional
encoder whose self-attention is approximated by spectral shifting (the
configuration Nystromformer-class papers evaluate on)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-bert", family="dense",
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=30522, rope_theta=1e4,
    attention_impl="spectral_shift", num_landmarks=64,
)
