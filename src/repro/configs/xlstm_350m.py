"""xLSTM-350M [arXiv:2405.04517]: attention-free sLSTM + mLSTM blocks.

The paper's spectral-shifting technique is inapplicable (no softmax
attention) — see DESIGN.md §6. Sub-quadratic natively; long_500k runs as a
recurrent decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=6, conv_width=4,
    scan_layers=False, attention_impl="none", decode_attention_impl="none",
    tie_embeddings=True,
)
