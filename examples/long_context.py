"""Long-context decode: the paper's O(n) vs exact O(n^2) at the serving
level. Fills a KV cache to increasing lengths and times one decode step with
full attention vs spectral-shift attention.

The SS step cost is dominated by the (c x S) B-matrix GEMM — linear in S
with a tiny constant — while exact attention's (1 x S) scores GEMM is also
linear per STEP but the paper's win is at prefill/training; at decode the
win is the landmark state reuse: F/A cost is O(c^2), independent of S.

    PYTHONPATH=src python examples/long_context.py
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.decode import decode_step
from repro.serve.kv_cache import cache_specs


def time_decode(cfg, params, s_max, fill, reps=8):
    cache = init_params(cache_specs(cfg, 1, s_max), jax.random.PRNGKey(1))
    # Pretend the cache is filled to ``fill`` tokens.
    cache = dict(cache)
    cache["pos"] = jnp.asarray(fill, jnp.int32)
    step = jax.jit(lambda c, t: decode_step(params, cfg, c, t))
    tok = jnp.ones((1, 1), jnp.int32)
    logits, new_cache = step(cache, tok)  # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(reps):
        logits, _ = step(cache, tok)
        jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def main():
    base = reduced(get_config("qwen2-7b"))
    print("cache_fill  full(ms)  spectral_shift(ms)")
    for fill in (1024, 4096, 16384):
        row = [f"{fill:10d}"]
        for impl in ("full", "spectral_shift"):
            cfg = dataclasses.replace(
                base, decode_attention_impl=impl, num_landmarks=32
            )
            ms = time_decode(cfg, init_params(
                model_specs(cfg), jax.random.PRNGKey(0)
            ), s_max=16384 + 64, fill=fill)
            row.append(f"{ms:9.2f}")
        print("  ".join(row))
    print("\nxlstm-350m (attention-free, O(1)/token regardless of context):")
    cfg = reduced(get_config("xlstm-350m"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    for fill in (1024, 16384):
        ms = time_decode(cfg, params, s_max=16384 + 64, fill=fill)
        print(f"{fill:10d}  {ms:9.2f}")


if __name__ == "__main__":
    main()
