"""Observability layer: flight-recorder lifelines and bounds, Chrome
trace export validity, XLA recompile accounting, numerics probes, and the
perf-regression gate's tolerance policy."""
from __future__ import annotations

import dataclasses
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.regress import (
    DEFAULT_WALL_TOL,
    Policy,
    compare_cells,
    metric_policy,
)
from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine
from repro.telemetry import (
    FlightRecorder,
    MetricsRegistry,
    NullFlightRecorder,
    NumericsProbe,
    Telemetry,
    XLAAccounting,
    chrome_trace,
    config_hash,
    git_sha,
    provenance,
    validate_trace,
    write_chrome_trace,
)


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")),
        capacity_factor=100.0,
        decode_streaming="frozen",
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, seed=0, lo=4, hi=24, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        Request(
            u,
            rng.integers(3, cfg.vocab_size, int(rng.integers(lo, hi))).tolist(),
            max_new_tokens=max_new,
        )
        for u in range(n)
    ]


BASE = ServeConfig(max_lanes=2, max_seq=64, block_size=8, telemetry=True)


@pytest.fixture(scope="module")
def served(qwen):
    """One telemetry-on paged engine run shared by the lifeline/trace
    tests: 3 requests through admit -> prefill -> decode -> finish."""
    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    for r in _requests(cfg, 3, seed=1, lo=8, hi=20, max_new=6):
        eng.submit(r)
    out = eng.run()
    return eng, out


# ==========================================================================
# FlightRecorder bounds (unit)
# ==========================================================================
class TestFlightRecorder:
    def test_decode_runs_coalesce(self):
        fl = FlightRecorder()
        fl.record(7, "submit", prompt_len=5)
        for tick in range(10, 15):
            fl.record(7, "decode", tick=tick, pos=tick - 4)
        line = fl.lifeline(7)
        # five consecutive ticks -> ONE run event, O(1) steady-state memory
        assert line.kinds() == ["submit", "decode"]
        run = line.events[-1]
        assert (run["tick0"], run["tick1"]) == (10, 14)
        assert (run["pos0"], run["pos1"]) == (6, 10)
        assert run["n"] == 5
        # a scheduling gap breaks the run
        fl.record(7, "decode", tick=20, pos=11)
        assert line.kinds() == ["submit", "decode", "decode"]
        assert line.events[-1]["tick0"] == 20

    def test_ring_buffer_eviction_and_event_cap(self):
        reg = MetricsRegistry()
        fl = FlightRecorder(max_requests=4, max_events=8, registry=reg)
        for uid in range(10):
            fl.record(uid, "submit", prompt_len=1)
        # FIFO ring: only the newest 4 lifelines survive, evictions counted
        assert [ln.uid for ln in fl.lifelines()] == [6, 7, 8, 9]
        assert fl.summary()["evicted_requests"] == 6
        # per-lifeline cap: events beyond max_events drop and count instead
        # of growing (non-consecutive ticks so nothing coalesces)
        for tick in range(0, 40, 2):
            fl.record(9, "decode", tick=tick, pos=tick)
        line = fl.lifeline(9)
        assert len(line.events) == 8
        assert line.dropped == 20 - 7
        assert fl.summary()["dropped_events"] == line.dropped
        snap = reg.snapshot()
        assert snap["flight_events_dropped_total"]["value"] == line.dropped

    def test_counter_samples_bounded(self):
        fl = FlightRecorder(max_counter_samples=16)
        for i in range(100):
            fl.counter_sample("queue_depth", i)
        samples = fl.counters["queue_depth"]
        assert len(samples) == 16
        assert samples[-1][1] == 99.0

    def test_null_recorder_is_inert(self):
        fl = NullFlightRecorder()
        fl.record(1, "submit")
        fl.counter_sample("x", 1.0)
        assert not fl.enabled and fl.lifelines() == []
        assert fl.dump_jsonl(io.StringIO()) == 0


# ==========================================================================
# Engine lifelines + Chrome trace export
# ==========================================================================
class TestLifelines:
    def test_lifeline_complete(self, served):
        eng, _ = served
        for uid in range(3):
            kinds = eng.telemetry.flight.lifeline(uid).kinds()
            assert kinds[0] == "submit"
            assert kinds[-1] == "finish"
            i = {k: kinds.index(k) for k in
                 ("submit", "admit", "prefill_start", "prefill_end",
                  "decode")}
            assert (i["submit"] < i["admit"] < i["prefill_start"]
                    < i["prefill_end"] < i["decode"])

    def test_prefill_bucket_recorded(self, served):
        eng, _ = served
        events = eng.telemetry.flight.lifeline(0).events
        start = next(e for e in events if e["kind"] == "prefill_start")
        # the padding bucket is the shape that decides which XLA program ran
        assert start["bucket"] >= 8 and start["bucket"] % 8 == 0

    def test_trace_schema_valid(self, served, tmp_path):
        eng, _ = served
        path = tmp_path / "serve.json"
        n = write_chrome_trace(path, eng.telemetry, meta={"case": "test"})
        trace = json.loads(path.read_text())
        assert n == len(trace["traceEvents"]) > 0
        assert trace["metadata"]["trace_schema"] == "repro-chrome-trace-v1"
        assert trace["metadata"]["case"] == "test"
        # balanced B/E per track, monotonic timestamps — Perfetto's contract
        assert validate_trace(trace) == []
        names = {e["name"] for e in trace["traceEvents"]}
        assert {"queued", "prefill", "decode"} <= names
        # one request track per lifeline on the requests pid
        req_tids = {e["tid"] for e in trace["traceEvents"]
                    if e["pid"] == 1 and e["ph"] == "B"}
        assert len(req_tids) == 3

    def test_counter_tracks_exported(self, served):
        eng, _ = served
        trace = chrome_trace(eng.telemetry)
        counters = {e["name"] for e in trace["traceEvents"]
                    if e["ph"] == "C"}
        assert {"queue_depth", "pool_blocks_used",
                "pool_fragmentation"} <= counters

    def test_jsonl_carries_flight_and_provenance(self, served, tmp_path):
        eng, _ = served
        path = tmp_path / "telemetry.jsonl"
        eng.telemetry.dump_jsonl(str(path))
        lines = [json.loads(x) for x in path.read_text().splitlines()]
        head = lines[0]
        assert head["kind"] == "meta"
        assert head["git_sha"] and head["jax"] == jax.__version__
        assert "config_hash" in head
        flights = [x for x in lines if x["kind"] == "flight"]
        assert len(flights) == 3
        assert flights[0]["events"][0]["kind"] == "submit"

    def test_preempted_lifeline_complete(self, qwen, tmp_path):
        """Pool pressure forces preemption; the victim's lifeline shows the
        full round-trip (admit -> preempt -> requeue -> re-prefill ->
        finish) and the trace still validates."""
        cfg, params = qwen
        serve = dataclasses.replace(BASE, max_lanes=3, num_blocks=12)
        eng = ServeEngine(cfg, params, serve=serve)
        for r in _requests(cfg, 4, seed=2, lo=20, hi=21, max_new=30):
            eng.submit(r)
        eng.run()
        assert eng.stats()["preemptions"] > 0
        victims = [ln for ln in eng.telemetry.flight.lifelines()
                   if "preempt" in ln.kinds()]
        assert victims
        kinds = victims[0].kinds()
        p = kinds.index("preempt")
        assert "admit" in kinds[:p]
        assert kinds[p + 1] == "requeue"
        rest = kinds[p + 2:]
        assert "prefill_start" in rest and rest[-1] == "finish"
        path = tmp_path / "preempt.json"
        write_chrome_trace(path, eng.telemetry)
        trace = json.loads(path.read_text())
        assert validate_trace(trace) == []
        instants = {e["name"] for e in trace["traceEvents"]
                    if e["ph"] == "i"}
        assert "preempt" in instants


# ==========================================================================
# XLA program accounting
# ==========================================================================
class TestAccounting:
    def test_recompile_detector(self):
        reg = MetricsRegistry()
        acct = XLAAccounting(reg)
        fn = jax.jit(lambda x: x * 2.0)
        wrapped = acct.wrap(fn, "toy")
        wrapped(jnp.ones(4))
        assert acct.compiles("toy") == 1
        # silent across 100 steady-state calls: same shape, no re-jit
        for _ in range(100):
            wrapped(jnp.ones(4))
        assert acct.compiles("toy") == 1
        # a forced re-jit (new input shape) fires exactly once
        wrapped(jnp.ones(8))
        assert acct.compiles("toy") == 2
        snap = reg.snapshot()
        assert snap["xla_compiles_total"]["program=toy"]["value"] == 2
        assert snap["xla_program_calls_total"]["program=toy"]["value"] == 102

    def test_wrap_without_probe_is_identity(self):
        def plain(x):
            return x

        acct = XLAAccounting(MetricsRegistry())
        assert acct.wrap(plain, "noprobe") is plain

    def test_engine_steady_state_compiles(self, served, qwen):
        """The served run's decode program compiled for its view buckets
        and then stayed flat: a fresh request over the same shapes adds
        ZERO compiles (the xla_compiles_total stability contract)."""
        eng, _ = served
        cfg, _ = qwen
        before = dict(eng.stats()["xla_compiles"])
        assert before["prefill"] >= 1 and before["decode_tick"] >= 1
        (req,) = _requests(cfg, 1, seed=1, lo=8, hi=20, max_new=6)
        eng.submit(Request(99, list(req.prompt), req.max_new_tokens))
        eng.run()
        assert eng.stats()["xla_compiles"] == before


# ==========================================================================
# Numerics probes
# ==========================================================================
class TestNumericsProbe:
    def test_catches_injected_inf_in_landmark_stats(self):
        reg = MetricsRegistry()
        probe = NumericsProbe(reg)
        m = np.zeros((2, 16), np.float32)  # (lanes, landmarks) m-stats shape
        assert probe.check("landmark_m", m) == 0
        assert probe.last_bad is None
        m[1, 3] = np.inf
        assert probe.check("landmark_m", m) == 1
        assert probe.last_bad == "landmark_m"
        l = np.ones((2, 16), np.float32)
        l[0, 0] = np.nan
        l[1, 5] = np.nan
        assert probe.check("landmark_l", l) == 2
        snap = reg.snapshot()
        assert snap["numerics_nonfinite_total"]["site=landmark_m"]["value"] == 1
        assert snap["numerics_nonfinite_total"]["site=landmark_l"]["value"] == 2
        assert snap["numerics_checks_total"]["value"] == 3

    def test_skips_integer_arrays(self):
        probe = NumericsProbe(MetricsRegistry())
        assert probe.check("tokens", np.arange(8)) == 0

    def test_engine_probe_runs_clean(self, qwen):
        """With the probe on every 2nd tick, a healthy run reports zero
        non-finite values in logits and (m, l) stats — the frozen decode
        state uses a finite NEG_INF sentinel by design."""
        cfg, params = qwen
        serve = dataclasses.replace(BASE, numerics_probe_every=2)
        eng = ServeEngine(cfg, params, serve=serve)
        for r in _requests(cfg, 2, seed=3, lo=8, hi=16, max_new=6):
            eng.submit(r)
        eng.run()
        snap = eng.telemetry.metrics.snapshot()
        assert snap["numerics_checks_total"]["value"] > 0
        assert "numerics_nonfinite_total" not in snap or all(
            s["value"] == 0
            for s in snap["numerics_nonfinite_total"].values()
        )


# ==========================================================================
# Provenance
# ==========================================================================
def test_provenance_stamp():
    sha = git_sha()
    assert sha == "unknown" or len(sha) == 40
    p = provenance(BASE)
    assert p["jax"] == jax.__version__
    assert len(p["config_hash"]) == 12
    # the hash tracks config content, not object identity
    assert config_hash(BASE) == config_hash(dataclasses.replace(BASE))
    assert config_hash(BASE) != config_hash(
        dataclasses.replace(BASE, max_lanes=7))


def test_git_sha_degrades_on_hung_git(monkeypatch):
    """A git that times out (TimeoutExpired) must degrade to $GITHUB_SHA /
    "unknown" like every other failure mode — provenance is never the
    reason an artifact fails to write."""
    import subprocess

    def hang(*a, **k):
        raise subprocess.TimeoutExpired(cmd=a[0], timeout=k.get("timeout", 10))

    monkeypatch.setattr(subprocess, "run", hang)
    monkeypatch.delenv("GITHUB_SHA", raising=False)
    git_sha.cache_clear()
    try:
        assert git_sha() == "unknown"
        monkeypatch.setenv("GITHUB_SHA", "f" * 40)
        git_sha.cache_clear()
        assert git_sha() == "f" * 40
    finally:
        git_sha.cache_clear()  # don't poison the per-process cache


# ==========================================================================
# Perf-regression gate
# ==========================================================================
class TestRegressGate:
    CELLS = {
        "paged|batched|prompt32": {
            "ttft_s": 0.02, "ttft_ticks": 1.0, "tok_per_s": 250.0,
            "hbm_bytes": 1.5e7, "note": "not a number-gated field",
        },
        "paged|batched|lanes4": {"tok_per_s": 400.0, "drift_err": 1e-4},
    }

    def test_policy_classification(self):
        assert metric_policy("ttft_s").direction == "lower"
        assert metric_policy("ttft_s").wall
        assert metric_policy("tok_per_s").direction == "higher"
        assert metric_policy("hbm_bytes") == Policy("both", 0.01, 0.5)
        assert not metric_policy("xla_cost_bytes").wall
        assert metric_policy("drift_err").direction == "lower"
        assert metric_policy("finished") is None  # informational
        # prefix-cache cells: warm TTFT gates like a latency, the speedup
        # rule still wins for ratios, and the hit rate is pinned
        assert metric_policy("ttft_warm_s") == Policy(
            "lower", DEFAULT_WALL_TOL, 2e-3, wall=True)
        assert metric_policy("ttft_warm_speedup").direction == "higher"
        assert metric_policy("prefix_hit_rate") == Policy("both", 0.01, 0.01)
        # chaos cell: seeded-schedule counters are pinned, the surviving
        # goodput fraction gates like a throughput
        assert metric_policy("chaos_injections") == Policy("both", 0.01, 0.5)
        assert metric_policy("quarantines") == Policy("both", 0.01, 0.5)
        assert metric_policy("goodput_frac") == Policy(
            "higher", DEFAULT_WALL_TOL, 0.0, wall=True)
        assert metric_policy("goodput_tok_per_s").direction == "higher"
        assert metric_policy("goodput_tok_per_s").wall

    def test_identical_cells_pass(self):
        violations, compared = compare_cells(
            "serve", self.CELLS, json.loads(json.dumps(self.CELLS)))
        assert violations == []
        assert compared == 6

    def test_doctored_regression_fails(self):
        doctored = json.loads(json.dumps(self.CELLS))
        cell = doctored["paged|batched|prompt32"]
        cell["ttft_s"] *= 2.0        # 2x slower: outside the 0.75 band
        cell["tok_per_s"] /= 2.0     # 2x less throughput
        cell["hbm_bytes"] *= 1.05    # structural drift beyond +-1%
        violations, _ = compare_cells("serve", doctored, self.CELLS)
        assert {v.metric for v in violations} == {
            "ttft_s", "tok_per_s", "hbm_bytes"}
        v = next(v for v in violations if v.metric == "ttft_s")
        assert "REGRESSION" in str(v) and "+100.0%" in str(v)

    def test_improvement_within_role_passes(self):
        better = json.loads(json.dumps(self.CELLS))
        better["paged|batched|prompt32"]["ttft_s"] *= 0.5  # faster is fine
        violations, _ = compare_cells("serve", better, self.CELLS)
        assert violations == []
        # ...but a structural metric moving EITHER way fails loudly
        better["paged|batched|prompt32"]["hbm_bytes"] *= 0.9
        violations, _ = compare_cells("serve", better, self.CELLS)
        assert [v.metric for v in violations] == ["hbm_bytes"]

    def test_host_mismatch_skips_wall_metrics(self):
        doctored = json.loads(json.dumps(self.CELLS))
        doctored["paged|batched|prompt32"]["ttft_s"] *= 10
        violations, compared = compare_cells(
            "serve", doctored, self.CELLS, host_match=False)
        assert violations == []
        assert compared == 3  # ttft_ticks, hbm_bytes, drift_err still gated

    def test_new_cells_and_metrics_skipped(self):
        fresh = {"brand|new|cell": {"ttft_s": 9.9},
                 "paged|batched|lanes4": {"tok_per_s": 400.0,
                                          "new_metric_s": 5.0}}
        violations, compared = compare_cells("serve", fresh, self.CELLS)
        assert violations == []
        assert compared == 1  # only the shared tok_per_s
