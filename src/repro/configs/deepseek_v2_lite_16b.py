"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: MLA (kv_lora=512) + MoE.

Assignment sheet lists both "64e top-6" and "2 shared+160 routed"; we follow
the structured field (64 routed, top-6, 2 shared), matching the released
model. First-layer-dense simplification noted in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    mla=True, kv_lora_rank=512, rope_head_dim=64, head_dim=128,
    moe=True, num_experts=64, num_shared_experts=2, top_k=6, moe_d_ff=1408,
    attention_impl="chunked",
)
