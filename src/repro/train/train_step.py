"""Jittable train / prefill / serve step builders.

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with optional microbatch gradient accumulation (scan) — the thing the
launcher jits with in/out shardings and donation.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.model import loss_fn, model_forward
from repro.optim.adamw import adamw_update
from repro.serve.decode import decode_step


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, lr_fn: Callable):
    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            # Grad accumulation: split the batch dim into microbatches and
            # scan, accumulating fp32 grads.
            def split(x):
                b = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, mb_batch):
                loss, metrics, grads = grads_of(params, mb_batch)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads
                )
                return acc, loss

            grads, losses = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = jnp.mean(losses)
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg, lr_fn
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_grad_step(cfg: ModelConfig):
    """Forward + backward only (no optimizer update): the fwd+bwd cell that
    ``benchmarks/bench_train_step.py`` times and the gradient-parity tests
    compare across attention backends."""

    def grad_step(params, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        return loss, grads

    return grad_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch)
        return loss, metrics

    return eval_step


def make_prefill_step(cfg: ModelConfig):
    """Full-sequence forward returning logits — the inference-prefill cell."""

    def prefill_step(params, batch):
        logits, _ = model_forward(params, cfg, batch, mode="prefill")
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One batched decode step against the KV cache."""

    def serve_step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return serve_step
