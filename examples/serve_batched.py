"""Batched serving over the paged KV cache with batched prefill.

Submits a bursty stream of requests (staggered arrivals, mixed lengths) to
the engine and reports throughput, latency, and pool utilization. Compare
engines with --mode:

    PYTHONPATH=src python examples/serve_batched.py                # paged
    PYTHONPATH=src python examples/serve_batched.py --mode dense   # seed-style
    PYTHONPATH=src python examples/serve_batched.py --mode ss_fused
    PYTHONPATH=src python examples/serve_batched.py --tick paged   # gather-free
    PYTHONPATH=src python examples/serve_batched.py --chunked      # continuous
                                                   # batching (chunked prefill)
    PYTHONPATH=src python examples/serve_batched.py --trace /tmp/serve.json
                                                   # Perfetto trace export
    PYTHONPATH=src python examples/serve_batched.py --prefix-cache
                                                   # shared-prefix workload +
                                                   # content-hash prefix cache
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=160)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool size in blocks (0 = lanes*max_seq/bs)")
    ap.add_argument("--mode", default="paged",
                    choices=["paged", "dense", "ss_fused"],
                    help="paged = block pool + batched prefill; dense = "
                         "seed-style per-lane caches + token replay; "
                         "ss_fused = paged with Pallas-kernel prefill")
    ap.add_argument("--decode-impl", default="spectral_shift",
                    choices=["full", "spectral_shift"])
    ap.add_argument("--tick", default="gather", choices=["gather", "paged"],
                    help="decode-tick route over the block pool: gather = "
                         "legacy dense-view tick; paged = gather-free "
                         "block-table Pallas kernel")
    ap.add_argument("--streaming", default="exact",
                    choices=["recompute", "exact", "frozen"],
                    help="ModelConfig.decode_streaming policy")
    ap.add_argument("--chunked", action="store_true",
                    help="continuous batching: prompts prefill in "
                         "fixed-size chunks riding the decode tick "
                         "(greedy outputs stay token-identical)")
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="chunk size for --chunked (rounded up to a "
                         "block multiple)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="per-tick prompt-token budget for --chunked "
                         "(0 = one chunk per tick)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-hash prefix caching: prompts are drawn "
                         "from a small set of shared prefixes so repeat "
                         "arrivals hit the cache (COW block sharing, "
                         "prefill skipped over the shared span); the "
                         "summary adds hit rate and warm TTFT")
    ap.add_argument("--prefix-cache-blocks", type=int, default=-1,
                    help="cap on pool blocks the prefix cache may retain "
                         "(-1 = half of the pool — an unbounded "
                         "cache on a small pool competes with decode "
                         "working sets and thrashes the preemption "
                         "ladder; 0 = unbounded)")
    ap.add_argument("--telemetry", metavar="PATH", default=None,
                    help="enable the telemetry subsystem, dump the JSONL "
                         "to PATH and print a one-screen summary at exit")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Chrome Trace Event JSON (per-request "
                         "lifelines + host spans + pool/queue counter "
                         "tracks) to PATH; implies telemetry on. Load it "
                         "at ui.perfetto.dev or chrome://tracing")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(get_config(args.arch)),
        decode_attention_impl=args.decode_impl, num_landmarks=16,
        decode_streaming=args.streaming,
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    serve = ServeConfig(
        max_lanes=args.lanes, max_seq=args.max_seq,
        block_size=args.block_size, num_blocks=args.num_blocks,
        paged=args.mode != "dense",
        batched_prefill=args.mode != "dense",
        prefill_impl="ss_fused" if args.mode == "ss_fused" else "replay",
        decode_impl=args.tick,
        chunked_prefill=args.chunked,
        prefill_chunk_tokens=args.chunk_tokens,
        prefill_token_budget=args.prefill_budget,
        prefix_cache=args.prefix_cache,
        prefix_cache_blocks=(
            max(4, (args.num_blocks or args.lanes
                    * -(-args.max_seq // args.block_size)) // 2)
            if args.prefix_cache_blocks < 0 else args.prefix_cache_blocks),
        telemetry=args.telemetry is not None or args.trace is not None,
    )
    engine = ServeEngine(cfg, params, serve=serve)

    rng = np.random.default_rng(0)
    # --prefix-cache workload: two shared prompt stems (think system
    # prompts) with per-request tails. Arrivals are spaced wider than the
    # default burst so a stem's first prefill completes (and inserts its
    # entry) before the stem repeats — the regime the cache serves.
    stems = [rng.integers(3, cfg.vocab_size,
                          int(rng.integers(32, 64))).tolist()
             for _ in range(2)] if args.prefix_cache else []
    cadence = 6 if stems else 3
    pending = list(range(args.requests))
    t0 = time.time()
    tick = 0
    while pending or not engine.sched.idle:
        # Bursty arrivals: a new request roughly every third tick
        # (every sixth with --prefix-cache, see above).
        if pending and (tick % cadence == 0):
            uid = pending.pop(0)
            if stems:
                prompt = list(stems[uid % len(stems)]) + rng.integers(
                    3, cfg.vocab_size, int(rng.integers(4, 16))).tolist()
            else:
                plen = int(rng.integers(4, 48))
                prompt = rng.integers(3, cfg.vocab_size, plen).tolist()
            engine.submit(Request(
                uid, prompt,
                max_new_tokens=int(rng.integers(8, 32)),
            ))
        engine.tick()
        tick += 1
        if tick > 20_000:
            break
    dt = time.time() - t0

    st = engine.stats()
    total_tokens = st["new_tokens"]
    print(f"[serve_batched] mode={st['mode']} impl={args.decode_impl} "
          f"tick={st['decode_impl']} streaming={st['decode_streaming']} "
          f"lanes={args.lanes}")
    print(f"  {st['finished']}/{args.requests} finished, "
          f"{total_tokens} new tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s)")
    print(f"  ttft ticks p50={st['ttft_ticks_p50']} "
          f"latency ticks p50={st['latency_ticks_p50']} "
          f"preemptions={st['preemptions']}")
    if "kv" in st:
        print(f"  kv pool: {st['kv']['num_blocks']} blocks, "
              f"final utilization {st['kv']['utilization']:.2f}")
    if "prefix" in st:
        p = st["prefix"]
        lookups = p["hits"] + p["misses"]
        warm = st.get("ttft_warm_s_p50")
        print(f"  prefix cache: {p['hits']}/{lookups} hits "
              f"({p['hits'] / max(lookups, 1):.0%}), "
              f"{p['entries']} entries over {p['blocks']} blocks, "
              f"{p['evictions']} evictions, "
              f"{st.get('cow_copies', 0)} cow copies; "
              f"warm ttft p50="
              + (f"{warm * 1e3:.2f}ms" if warm else "n/a"))

    if args.telemetry:
        n = engine.telemetry.dump_jsonl(args.telemetry, meta={
            "example": "serve_batched", "mode": args.mode,
            "streaming": args.streaming, "lanes": args.lanes,
        })
        snap = engine.telemetry.snapshot()["metrics"]

        def pct(name, p):
            s = snap.get(name, {})
            v = s.get(p)
            return f"{v * 1e3:.2f}ms" if v is not None else "n/a"

        def val(name):
            s = snap.get(name, {})
            return s.get("value", 0.0)

        print(f"  telemetry: {n} JSONL lines -> {args.telemetry} "
              f"({st['telemetry']['events']} spans)")
        print(f"    ttft    p50={pct('serve_ttft_seconds', 'p50')} "
              f"p99={pct('serve_ttft_seconds', 'p99')}   "
              f"itl p50={pct('serve_itl_seconds', 'p50')} "
              f"p99={pct('serve_itl_seconds', 'p99')}")
        print(f"    rebases={val('serve_rebases_total'):.0f} "
              f"preemptions={val('serve_preempted_total'):.0f} "
              f"pool occupancy={val('pool_utilization'):.2f} "
              f"fragmentation={val('pool_fragmentation'):.2f}")
        drift = snap.get("drift_rebase_residual", {})
        if drift.get("count"):
            print(f"    drift residual p50={drift['p50']:.3g} "
                  f"p99={drift['p99']:.3g} over {drift['count']} rebases; "
                  f"spectrum top1 ema="
                  f"{val('spectrum_mass_top1_ema'):.3f}")

    if args.trace:
        from repro.telemetry import write_chrome_trace

        n_ev = write_chrome_trace(args.trace, engine.telemetry, meta={
            "example": "serve_batched", "mode": args.mode,
            "streaming": args.streaming, "lanes": args.lanes,
        })
        fl = engine.telemetry.flight.summary()
        print(f"  trace: {n_ev} events ({fl['requests']} request lifelines) "
              f"-> {args.trace}")
        print("    load it: open https://ui.perfetto.dev and drag the file "
              "in, or chrome://tracing -> Load. One track per request "
              "(queued/prefill/decode slices, preempt/rebase markers), "
              "host tick spans on pid 0, pool/queue counters below.")


if __name__ == "__main__":
    main()
