"""AdamW with decoupled weight decay and global-norm clipping.

Hand-rolled (no optax dependency) so optimizer state is a plain pytree that
mirrors the parameter tree — it inherits parameter shardings leaf-for-leaf,
giving ZeRO-style sharded optimizer state for free under pjit.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: dict
    v: dict


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    tcfg: TrainConfig,
    lr_fn: Callable[[jnp.ndarray], jnp.ndarray],
):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1, b2 = tcfg.beta1, tcfg.beta2
    lr = lr_fn(step).astype(jnp.float32)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip_scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + tcfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        AdamWState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
