"""Substrate unit tests: checkpointer, data pipeline, optimizer, schedules,
gradient compression, fault-tolerance control plane, sharding rules."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticLM, TextFileLM
from repro.distributed.fault_tolerance import (
    ElasticPlan,
    FailureInjector,
    HeartbeatMonitor,
)
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, global_norm
from repro.optim.compression import Compressed, compress, decompress
from repro.optim.schedules import constant, warmup_cosine
from repro.configs.base import TrainConfig


class TestCheckpointer:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {
            "w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
            "opt": {"m": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
        }

    def test_save_restore(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=3)
        tree = self._tree()
        ck.save(5, tree, blocking=True)
        assert ck.latest_step() == 5
        out = ck.restore(5, tree)
        np.testing.assert_array_equal(out["w"], tree["w"])

    def test_retention_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, self._tree(s), blocking=True)
        assert ck.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=3)
        ck.save(7, self._tree(), blocking=False)
        ck.wait()
        assert ck.latest_step() == 7

    def test_atomic_publish_no_tmp_visible(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=3)
        ck.save(1, self._tree(), blocking=True)
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_restore_newest_of_many(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=5)
        trees = {s: self._tree(s) for s in (1, 2, 3)}
        for s, t in trees.items():
            ck.save(s, t, blocking=True)
        out = ck.restore(ck.latest_step(), trees[3])
        np.testing.assert_array_equal(out["w"], trees[3]["w"])


class TestDataPipeline:
    def test_synthetic_deterministic(self):
        src = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=1)
        a, b = src.batch(7), src.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = src.batch(8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_synthetic_range(self):
        src = SyntheticLM(vocab_size=50, seq_len=32, global_batch=2)
        t = src.batch(0)["tokens"]
        assert t.min() >= 1 and t.max() < 50
        assert t.dtype == np.int32

    def test_textfile(self, tmp_path):
        p = tmp_path / "corpus.txt"
        p.write_bytes(b"hello world, this is a test corpus for the lm." * 20)
        src = TextFileLM(str(p), seq_len=16, global_batch=3, seed=0)
        t = src.batch(0)["tokens"]
        assert t.shape == (3, 16)
        np.testing.assert_array_equal(t, src.batch(0)["tokens"])


class TestOptimizer:
    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0])}
        tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=1e9)
        lr = constant(0.1)
        opt = adamw_init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp ||p||^2
            params, opt, m = adamw_update(grads, opt, params, tcfg, lr)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip_applied(self):
        params = {"w": jnp.ones((4,))}
        tcfg = TrainConfig(grad_clip=1.0, weight_decay=0.0)
        opt = adamw_init(params)
        grads = {"w": jnp.full((4,), 100.0)}
        _, _, metrics = adamw_update(grads, opt, params, tcfg, constant(1e-3))
        assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)

    def test_global_norm(self):
        tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert float(global_norm(tree)) == pytest.approx(5.0)

    def test_schedule_warmup_cosine(self):
        fn = warmup_cosine(1.0, 10, 100)
        assert float(fn(jnp.asarray(0))) < 0.2
        assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
        # Cosine decays to the floor (0.1 * peak).
        assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-2)
        # Monotone decay after warmup.
        vals = [float(fn(jnp.asarray(s))) for s in (10, 40, 70, 100)]
        assert vals == sorted(vals, reverse=True)


class TestCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        c, _ = compress(x)
        y = decompress(c)
        # int8 round-to-nearest: error bounded by half the quantization step.
        assert float(jnp.max(jnp.abs(x - y))) <= float(c.scale) * 0.51

    def test_error_feedback_reduces_bias(self):
        """With error feedback, the accumulated compression error stays
        bounded (residual absorbs it) instead of growing."""
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(32,)), jnp.float32) * 0.01
        res = jnp.zeros_like(x)
        total_in, total_out = jnp.zeros_like(x), jnp.zeros_like(x)
        for _ in range(50):
            c, res = compress(x, residual=res)
            y = decompress(c)
            total_in = total_in + x
            total_out = total_out + y
        rel = float(jnp.linalg.norm(total_in - total_out)
                    / jnp.linalg.norm(total_in))
        assert rel < 0.05, rel


class TestFaultTolerance:
    def test_dead_host_detection(self):
        mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10.0)
        mon.beat("h0", 1.0, now=100.0)
        mon.beat("h1", 1.0, now=100.0)
        assert mon.dead_hosts(now=105.0) == []
        mon.beat("h0", 1.0, now=120.0)
        assert mon.dead_hosts(now=125.0) == ["h1"]

    def test_straggler_detection(self):
        mon = HeartbeatMonitor([f"h{i}" for i in range(4)])
        for i in range(4):
            for _ in range(10):
                mon.beat(f"h{i}", 1.0 if i else 5.0)  # h0 is slow
        assert mon.stragglers() == ["h0"]

    def test_elastic_plan_shrinks_dp(self):
        plan = ElasticPlan.plan(alive_chips=96, model_parallel=16, max_data=16)
        assert plan.model == 16
        assert plan.data == 4          # largest pow2 <= 96//16=6
        assert plan.dropped_chips == 96 - 64

    def test_elastic_plan_impossible(self):
        with pytest.raises(RuntimeError):
            ElasticPlan.plan(alive_chips=8, model_parallel=16, max_data=4)

    def test_failure_injector(self):
        inj = FailureInjector({3: ["h1"], 7: ["h0", "h2"]})
        assert inj.failures_at(3) == ["h1"]
        assert inj.failures_at(4) == []


class TestShardingRules:
    def test_spec_for_outside_mesh_is_replicated(self):
        from repro.distributed.sharding import spec_for

        spec = spec_for(("batch", "embed"))
        assert all(p is None for p in spec)

    def test_rules_inside_mesh(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import sharding_rules, spec_for
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(1)
        with mesh, sharding_rules(mesh):
            spec = spec_for(("batch", None, "heads"))
            assert isinstance(spec, P)
            assert len(spec) == 3

    def test_divisible_spec_drops_indivisible(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import divisible_spec
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(1)  # 1 device: everything divides
        spec = divisible_spec(mesh, ("batch",), (7,))
        assert isinstance(spec, P)

    def test_param_shardings_tree(self):
        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.distributed.sharding import sharding_rules, shardings_for
        from repro.launch.mesh import make_local_mesh
        from repro.models.model import model_specs
        from repro.models.params import abstract_params, logical_axes

        cfg = reduced(get_config("qwen2-7b"))
        specs = model_specs(cfg)
        mesh = make_local_mesh(1)
        with mesh, sharding_rules(mesh):
            sh = shardings_for(mesh, logical_axes(specs), abstract_params(specs))
        from jax.sharding import NamedSharding

        for leaf in jax.tree.leaves(
            sh, is_leaf=lambda x: isinstance(x, NamedSharding)
        ):
            assert isinstance(leaf, NamedSharding)
