"""The paper's primary contribution: linear-time self-attention approximation
by Modified Spectral Shifting (Verma, 2021), plus the Nystromformer baseline
it improves on. See DESIGN.md for the math and the faithfulness notes."""

from repro.core.attention import (
    SSConfig,
    attention,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)
from repro.core.landmarks import segment_means
from repro.core.matrix_approx import approximate_spsd, flat_tail_spsd
from repro.core.pinv import iterative_pinv, svd_pinv
from repro.core.spectral_shift import SSCore, ss_core

__all__ = [
    "SSConfig",
    "SSCore",
    "attention",
    "approximate_spsd",
    "flat_tail_spsd",
    "full_attention",
    "iterative_pinv",
    "nystrom_attention",
    "segment_means",
    "spectral_shift_attention",
    "ss_core",
    "svd_pinv",
]
