"""Two-phase (prefill/decode) request scheduler over the block pool.

Policy — deliberately simple and predictable:

* FCFS waiting queue. A request is admitted when a lane is free AND the
  allocator can cover its whole prompt (``ceil(prompt_len / block_size)``
  blocks). Decode growth allocates one block at a time, on demand.
* When decode growth finds the pool empty, the scheduler preempts the
  YOUNGEST running request (latest admission): its blocks are freed and the
  request goes back to the FRONT of the waiting queue, restarting from
  scratch on re-admission (recompute, vLLM's default). The pool is sized so
  one lane can always hold a full sequence, so a lone request never
  self-preempts forever.
* Per-request latency/throughput counters (arrival, admission, first token,
  finish, preemption count) are aggregated for ``engine.stats()``.

The scheduler owns host-side bookkeeping only — block tables live in the
``BlockAllocator``; device storage belongs to ``PagedKVCache``; the engine
drives the actual prefill/decode computations.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.paged import ZERO_BLOCK, BlockAllocator


@dataclasses.dataclass
class RequestTiming:
    arrived: int = -1
    admitted: int = -1
    first_token: int = -1
    finished: int = -1
    preemptions: int = 0
    new_tokens: int = 0

    @property
    def ttft(self) -> Optional[int]:
        if self.first_token < 0 or self.arrived < 0:
            return None
        return self.first_token - self.arrived


class Scheduler:
    def __init__(self, allocator: Optional[BlockAllocator], max_lanes: int,
                 blocks_per_lane: int):
        self.allocator = allocator  # None => model has no paged state
        self.max_lanes = max_lanes
        self.blocks_per_lane = blocks_per_lane
        self.waiting: deque = deque()
        # set by the engine: lane index -> Request to requeue on preemption
        self.requeue_cb = None
        self.lane_uid: list[Optional[int]] = [None] * max_lanes
        self.admit_order: dict[int, int] = {}  # uid -> admission tick
        self.timing: dict[int, RequestTiming] = {}
        self.tick_now = 0
        # aggregate counters
        self.total_preemptions = 0
        self.total_admitted = 0
        self.total_finished = 0

    # -- block tables ---------------------------------------------------------
    def table_row(self, lane: int) -> np.ndarray:
        """One lane's block table, ZERO_BLOCK-padded to blocks_per_lane."""
        row = np.full(self.blocks_per_lane, ZERO_BLOCK, np.int32)
        uid = self.lane_uid[lane]
        if self.allocator is not None and uid is not None:
            blocks = self.allocator.tables.get(uid, [])
            row[: len(blocks)] = blocks
        return row

    def tables(self) -> np.ndarray:
        """(max_lanes, blocks_per_lane) int32 block tables; ZERO_BLOCK pads
        unallocated slots."""
        return np.stack([
            self.table_row(lane) for lane in range(self.max_lanes)
        ])

    # -- queue ---------------------------------------------------------------
    def submit(self, req) -> None:
        self.waiting.append(req)
        t = self.timing.setdefault(req.uid, RequestTiming())
        if t.arrived < 0:
            t.arrived = self.tick_now

    def _blocks_for_prompt(self, req) -> int:
        if self.allocator is None:
            return 0
        return self.allocator.blocks_for_tokens(max(len(req.prompt), 1))

    def admit(self) -> list[tuple[int, object]]:
        """Admit FCFS while lanes and blocks allow. Returns [(lane, req)]."""
        admissions = []
        for lane in range(self.max_lanes):
            if self.lane_uid[lane] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            need = self._blocks_for_prompt(req)
            if self.allocator is not None:
                if not self.allocator.can_alloc(need):
                    break  # FCFS: don't let short requests starve the head
                self.allocator.alloc(req.uid, need)
            self.waiting.popleft()
            self.lane_uid[lane] = req.uid
            self.admit_order[req.uid] = self.tick_now
            self.timing[req.uid].admitted = self.tick_now
            self.total_admitted += 1
            admissions.append((lane, req))
        return admissions

    # -- decode-time growth ---------------------------------------------------
    def ensure_block(self, lane: int, pos: int) -> bool:
        """Guarantee the block covering ``pos`` exists for ``lane``. May
        preempt the youngest request. Returns False if ``lane`` itself was
        preempted (its step must be skipped this tick)."""
        uid = self.lane_uid[lane]
        if self.allocator is None or uid is None:
            return True
        have = len(self.allocator.tables.get(uid, []))
        need_idx = pos // self.allocator.block_size
        while need_idx >= have:
            if self.allocator.alloc(uid, 1) is not None:
                have += 1
                continue
            victim = self._youngest_lane()
            if victim is None:
                # Defensive: unreachable while this lane holds a uid (it is
                # itself a preemption candidate). Without the block the
                # step would scatter into the reserved zero block, so skip
                # the lane rather than corrupt its cache.
                return False
            self.preempt(victim)
            if victim == lane:
                return False
        return True

    def _youngest_lane(self) -> Optional[int]:
        running = [
            (self.admit_order[uid], lane)
            for lane, uid in enumerate(self.lane_uid)
            if uid is not None
        ]
        if not running:
            return None
        return max(running)[1]

    def preempt(self, lane: int) -> None:
        """Free a lane's blocks and requeue its request at the queue front.
        The engine's ``requeue_cb`` clears the lane and hands back the
        Request object (the scheduler never holds it)."""
        uid = self.lane_uid[lane]
        if uid is None:
            return
        if self.allocator is not None:
            self.allocator.free(uid)
        self.lane_uid[lane] = None
        self.admit_order.pop(uid, None)
        t = self.timing[uid]
        t.preemptions += 1
        # Tokens generated so far are discarded (recompute on re-admission)
        # and will be re-counted when re-emitted; first_token stands — the
        # user did see it.
        t.new_tokens = 0
        self.total_preemptions += 1
        req = self.requeue_cb(lane) if self.requeue_cb else None
        if req is not None:
            self.waiting.appendleft(req)

    def release(self, lane: int) -> None:
        """Normal retirement: free blocks, mark finished."""
        uid = self.lane_uid[lane]
        if uid is None:
            return
        if self.allocator is not None:
            self.allocator.free(uid)
        self.lane_uid[lane] = None
        self.admit_order.pop(uid, None)
        self.timing[uid].finished = self.tick_now
        self.total_finished += 1

    def note_token(self, uid: int) -> None:
        t = self.timing[uid]
        if t.first_token < 0:
            t.first_token = self.tick_now
        t.new_tokens += 1

    @property
    def idle(self) -> bool:
        """O(lanes) drain check for the serving hot loop."""
        return not self.waiting and all(u is None for u in self.lane_uid)

    # -- metrics --------------------------------------------------------------
    def stats(self) -> dict:
        ttfts = [t.ttft for t in self.timing.values() if t.ttft is not None]
        done = [t for t in self.timing.values() if t.finished >= 0]
        lat = [t.finished - t.arrived for t in done]
        out = {
            "queued": len(self.waiting),
            "active": sum(u is not None for u in self.lane_uid),
            "admitted": self.total_admitted,
            "finished": self.total_finished,
            "preemptions": self.total_preemptions,
            "new_tokens": sum(t.new_tokens for t in self.timing.values()),
            "ttft_ticks_p50": float(np.median(ttfts)) if ttfts else None,
            "latency_ticks_p50": float(np.median(lat)) if lat else None,
        }
        if self.allocator is not None:
            out["kv"] = self.allocator.stats()
        return out
