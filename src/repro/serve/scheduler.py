"""Request scheduler over the block pool: two-phase FCFS, or chunk-aware
continuous batching when the engine passes ``chunk_tokens > 0``.

Policy — deliberately simple and predictable:

* FCFS waiting queue. A request is admitted when a lane is free AND the
  allocator can cover its admission need: the whole prompt
  (``ceil(prompt_len / block_size)`` blocks) in two-phase mode, or just the
  FIRST CHUNK in chunked mode (later chunks grow on demand via
  ``ensure_prefill_blocks``, which never preempts — a starved chunk stalls
  a tick instead of evicting a decoding lane). Decode growth allocates one
  block at a time, on demand.
* When decode growth finds the pool empty, the scheduler preempts the
  YOUNGEST running request (latest admission): its blocks are freed and the
  request goes back to the FRONT of the waiting queue, restarting from
  scratch on re-admission (recompute, vLLM's default). The pool is sized so
  one lane can always hold a full sequence, so a lone request never
  self-preempts forever.
* Chunked-prefill exception to recompute: when the engine installs a
  ``park_cb``, a victim caught mid-chunked-prefill is PARKED instead — its
  blocks (holding already-committed chunks) stay allocated, the engine
  snapshots the lane's carried dense state, and re-admission resumes at the
  completed-chunk boundary. Parked blocks are the first thing reclaimed
  (oldest first, dropping the resume state back to full recompute) when the
  pool runs dry, so parking never deadlocks decode growth.
* Per-request latency/throughput counters (arrival, admission, first token,
  finish, preemption count) are aggregated for ``engine.stats()``. A
  preempted-then-resumed request's first post-resume token is recorded in
  its own ``serve_resume_ttft_seconds`` histogram — not in TTFT (the user
  already saw tokens, or the wait was requeue-induced) and not in ITL (the
  gap measures scheduler pressure, not steady-state token cadence).

The scheduler owns host-side bookkeeping only — block tables live in the
``BlockAllocator``; device storage belongs to ``PagedKVCache``; the engine
drives the actual prefill/decode computations.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.paged import ZERO_BLOCK, BlockAllocator
from repro.telemetry.metrics import (
    LATENCY_BUCKETS,
    TICK_BUCKETS,
    MetricsRegistry,
)


@dataclasses.dataclass
class RequestTiming:
    arrived: int = -1
    admitted: int = -1
    first_token: int = -1
    finished: int = -1
    preemptions: int = 0
    new_tokens: int = 0
    # wall-clock stamps (perf_counter seconds) for the latency histograms
    arrived_s: Optional[float] = None
    last_token_s: Optional[float] = None
    # set on preemption, cleared by the first post-resume token (which lands
    # in the resume_ttft histogram instead of ttft/itl)
    requeued_s: Optional[float] = None

    @property
    def ttft(self) -> Optional[int]:
        if self.first_token < 0 or self.arrived < 0:
            return None
        return self.first_token - self.arrived


class Scheduler:
    def __init__(self, allocator: Optional[BlockAllocator], max_lanes: int,
                 blocks_per_lane: int,
                 registry: Optional[MetricsRegistry] = None,
                 flight=None, chunk_tokens: int = 0, max_queue: int = 0):
        self.allocator = allocator  # None => model has no paged state
        self.max_lanes = max_lanes
        self.blocks_per_lane = blocks_per_lane
        # chunked-prefill admission: > 0 means a request only needs its
        # first chunk's blocks to get a lane (continuous batching)
        self.chunk_tokens = chunk_tokens
        # admission-queue bound: submit() beyond it is rejected with a
        # retry-after hint instead of growing the queue (and the per-uid
        # timing table) without limit. 0 = unbounded.
        self.max_queue = max_queue
        # Optional ChaosInjector (serve/chaos.py): "admission_stall" makes
        # admit() a no-op for the tick.
        self.chaos = None
        self.waiting: deque = deque()
        # uids preempted mid-chunked-prefill whose blocks stay allocated
        # (insertion-ordered: oldest parked is reclaimed first)
        self.parked: dict[int, int] = {}
        # set by the engine: park_cb(lane) -> bool snapshots a mid-prefill
        # lane's carried state (True = parked, keep its blocks);
        # park_drop_cb(uid) discards a snapshot when its blocks are
        # reclaimed (the request falls back to full recompute)
        self.park_cb = None
        self.park_drop_cb = None
        # Per-request flight recorder (PR 7): the scheduler stamps the
        # queue-side lifecycle events (submit/admit/preempt/requeue/finish);
        # the engine stamps the compute-side ones (prefill/decode/rebase).
        if flight is None:
            from repro.telemetry.flight import NullFlightRecorder

            flight = NullFlightRecorder()
        self.flight = flight
        # set by the engine: lane index -> Request to requeue on preemption
        self.requeue_cb = None
        # Prefix-caching hooks (engine-set, both optional):
        # prefix_probe(req) -> int returns how many leading prompt tokens a
        # cached prefix will cover at admission, so _blocks_for_prompt
        # charges the pool for the TAIL only (the shared blocks are already
        # resident and accounted once, under the cache's reference);
        # cow_cb(old, new) performs the device-side block copy when
        # ensure_block breaks the sharing of a refcounted block.
        self.prefix_probe = None
        self.cow_cb = None
        # uids whose admission attached a cached prefix: their first token
        # lands in the warm-TTFT histogram as well as the regular one
        self._warm_uids: set = set()
        self.lane_uid: list[Optional[int]] = [None] * max_lanes
        self.admit_order: dict[int, int] = {}  # uid -> admission tick
        self.timing: dict[int, RequestTiming] = {}
        self.tick_now = 0
        # Aggregates live in a metrics registry; ``stats()`` is a view over
        # it. The scheduler always uses a *real* registry (plain host
        # counters — same cost as the ints they replaced) so p50/p90/p99
        # work regardless of the ServeConfig.telemetry knob; the engine
        # passes its shared registry when telemetry is on so these land in
        # the same snapshot/JSONL dump as everything else.
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._admitted = r.counter("serve_admitted_total", help="requests admitted to a lane")
        self._finished = r.counter("serve_finished_total", help="requests retired normally")
        self._preempted = r.counter("serve_preempted_total", help="preemptions (youngest-victim)")
        self._requeued = r.counter("serve_requeued_total", help="preempted requests requeued at the head")
        self._tokens = r.counter("serve_tokens_total", help="decode tokens emitted (recounts recomputed tokens)")
        r.gauge("serve_queue_depth", help="requests waiting for a lane",
                fn=lambda: float(len(self.waiting)))
        r.gauge("serve_active_lanes", help="lanes holding a request",
                fn=lambda: float(sum(u is not None for u in self.lane_uid)))
        self._ttft_ticks = r.histogram(
            "serve_ttft_ticks", help="engine ticks from arrival to first token",
            buckets=TICK_BUCKETS)
        self._latency_ticks = r.histogram(
            "serve_latency_ticks", help="engine ticks from arrival to finish",
            buckets=TICK_BUCKETS)
        self._ttft_s = r.histogram(
            "serve_ttft_seconds", help="wall seconds from arrival to first token",
            buckets=LATENCY_BUCKETS)
        self._itl_s = r.histogram(
            "serve_itl_seconds", help="wall seconds between consecutive tokens of one request",
            buckets=LATENCY_BUCKETS)
        self._resume_ttft_s = r.histogram(
            "serve_resume_ttft_seconds",
            help="wall seconds from requeue to the first post-resume token "
                 "(kept out of both ttft and itl)",
            buckets=LATENCY_BUCKETS)
        self._warm_ttft_s = r.histogram(
            "serve_ttft_warm_seconds",
            help="wall seconds from arrival to first token for requests "
                 "admitted onto a cached prefix (also counted in "
                 "serve_ttft_seconds)",
            buckets=LATENCY_BUCKETS)
        self._cow_copies = r.counter(
            "prefix_cow_copies_total",
            help="shared blocks copied on first divergent write")
        self._rejected = r.counter(
            "serve_rejected_total",
            help="submissions refused by the max_queue admission bound")
        self._cancelled = r.counter(
            "serve_cancelled_total",
            help="requests terminated by client cancellation")
        self._deadline_expired = r.counter(
            "serve_deadline_expired_total",
            help="requests terminated by their deadline_ticks budget")

    # Aggregate counters as attributes, for backward compatibility.
    @property
    def total_preemptions(self) -> int:
        return int(self._preempted.value)

    @property
    def total_admitted(self) -> int:
        return int(self._admitted.value)

    @property
    def total_finished(self) -> int:
        return int(self._finished.value)

    # -- block tables ---------------------------------------------------------
    def table_row(self, lane: int) -> np.ndarray:
        """One lane's block table, ZERO_BLOCK-padded to blocks_per_lane."""
        row = np.full(self.blocks_per_lane, ZERO_BLOCK, np.int32)
        uid = self.lane_uid[lane]
        if self.allocator is not None and uid is not None:
            blocks = self.allocator.tables.get(uid, [])
            row[: len(blocks)] = blocks
        return row

    def tables(self) -> np.ndarray:
        """(max_lanes, blocks_per_lane) int32 block tables; ZERO_BLOCK pads
        unallocated slots."""
        return np.stack([
            self.table_row(lane) for lane in range(self.max_lanes)
        ])

    # -- queue ---------------------------------------------------------------
    def submit(self, req) -> bool:
        """Queue a request. Returns False (recording nothing but the
        rejection) when the ``max_queue`` bound is hit — backpressure is
        explicit: the flight event carries a ``retry_after_ticks`` hint
        proportional to the backlog, and no RequestTiming entry is created
        (a rejected uid never reaches the latency histograms)."""
        if self.max_queue > 0 and len(self.waiting) >= self.max_queue:
            self._rejected.inc()
            self.flight.record(req.uid, "reject", tick=self.tick_now,
                               queue_depth=len(self.waiting),
                               retry_after_ticks=max(1, len(self.waiting)))
            return False
        self.waiting.append(req)
        t = self.timing.setdefault(req.uid, RequestTiming())
        if t.arrived < 0:
            t.arrived = self.tick_now
            t.arrived_s = time.perf_counter()
            self.flight.record(req.uid, "submit",
                               prompt_len=len(req.prompt),
                               tick=self.tick_now)
        return True

    def _blocks_for_prompt(self, req) -> int:
        if self.allocator is None:
            return 0
        if req.uid in self.parked:
            return 0  # resume: its committed-chunk blocks are still held
        n = max(len(req.prompt), 1)
        if self.prefix_probe is not None:
            # Shared-prefix admission: the cached blocks are already
            # resident (held by the prefix cache's reference), so they are
            # charged against the pool exactly once — admission only
            # allocates the uncached tail. A full hit needs zero blocks.
            shared = int(self.prefix_probe(req))
            if shared >= len(req.prompt):
                return 0
            if shared:
                tail = len(req.prompt) - shared
                if self.chunk_tokens > 0:
                    tail = min(tail, self.chunk_tokens)
                return self.allocator.blocks_for_tokens(tail)
        if self.chunk_tokens > 0:
            # chunked admission only needs the first chunk resident; later
            # chunks grow via ensure_prefill_blocks
            n = min(n, self.chunk_tokens)
        return self.allocator.blocks_for_tokens(n)

    def admit(self) -> list[tuple[int, object]]:
        """Admit FCFS while lanes and blocks allow. Returns [(lane, req)]."""
        if self.chaos is not None and self.chaos.fire("admission_stall"):
            return []
        admissions = []
        for lane in range(self.max_lanes):
            if self.lane_uid[lane] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            need = self._blocks_for_prompt(req)
            if self.allocator is not None:
                if not self.allocator.can_alloc(need):
                    break  # FCFS: don't let short requests starve the head
                if need and self.allocator.alloc(req.uid, need) is None:
                    # can_alloc promised room but the allocation still came
                    # up short (injected alloc_fail, or an eviction sweep
                    # that freed less than promised) — stall the admission
                    # for this tick rather than seat a block-less lane.
                    break
            self.parked.pop(req.uid, None)
            self.waiting.popleft()
            self.lane_uid[lane] = req.uid
            self.admit_order[req.uid] = self.tick_now
            t = self.timing[req.uid]
            t.admitted = self.tick_now
            self._admitted.inc()
            self.flight.record(req.uid, "admit", lane=lane,
                               tick=self.tick_now,
                               queued_ticks=self.tick_now - t.arrived)
            admissions.append((lane, req))
        return admissions

    # -- decode-time growth ---------------------------------------------------
    def ensure_block(self, lane: int, pos: int) -> bool:
        """Guarantee the block covering ``pos`` exists for ``lane``. May
        preempt the youngest request. Returns False if ``lane`` itself was
        preempted (its step must be skipped this tick)."""
        uid = self.lane_uid[lane]
        if self.allocator is None or uid is None:
            return True
        have = len(self.allocator.tables.get(uid, []))
        need_idx = pos // self.allocator.block_size
        while need_idx >= have:
            if self.allocator.alloc(uid, 1) is not None:
                have += 1
                continue
            if self.reclaim_parked():
                continue  # freed a parked request's blocks; retry alloc
            victim = self._youngest_lane()
            if victim is None:
                # Defensive: unreachable while this lane holds a uid (it is
                # itself a preemption candidate). Without the block the
                # step would scatter into the reserved zero block, so skip
                # the lane rather than corrupt its cache.
                return False
            self.preempt(victim)
            if victim == lane:
                return False
            # A parked victim freed nothing (it keeps its blocks) — the
            # next iteration's reclaim_parked() takes them, so the loop
            # still makes progress every pass.
        # The covering block exists. If it is SHARED (refcount > 1: the
        # partial last block of an attached cached prefix), this lane's
        # write would corrupt every other holder's view — break the
        # sharing first: allocate a fresh block, copy the device rows
        # (cow_cb), drop one reference on the original. First divergent
        # write only; the fresh block is private from then on.
        block = self.allocator.tables[uid][need_idx]
        while self.allocator.refcount(block) > 1:
            got = self.allocator.cow(uid, need_idx)
            if got is not None:
                if self.cow_cb is not None:
                    self.cow_cb(*got)
                self._cow_copies.inc()
                self.flight.record(uid, "cow", tick=self.tick_now,
                                   src=got[0], dst=got[1])
                break
            # Pool short for the private copy: same pressure ladder as
            # decode growth (reclaim parked, then preempt the youngest).
            if self.reclaim_parked():
                continue
            victim = self._youngest_lane()
            if victim is None:
                return False
            self.preempt(victim)
            if victim == lane:
                return False
        return True

    def ensure_prefill_blocks(self, lane: int, n_tokens: int) -> bool:
        """Grow ``lane``'s table to cover ``n_tokens`` prompt tokens for the
        next prefill chunk. NEVER preempts (decode lanes must not die for a
        prompt — the starvation invariant); reclaims parked blocks, then
        stalls (returns False) so the chunk retries next tick once decode
        retirements free blocks."""
        uid = self.lane_uid[lane]
        if self.allocator is None or uid is None:
            return True
        need = self.allocator.blocks_for_tokens(n_tokens)
        while len(self.allocator.tables.get(uid, [])) < need:
            short = need - len(self.allocator.tables.get(uid, []))
            if self.allocator.alloc(uid, short) is not None:
                return True
            if not self.reclaim_parked():
                return False
        return True

    def reclaim_parked(self) -> bool:
        """Free the OLDEST parked request's blocks (its resume snapshot is
        dropped — full recompute on re-admission). Returns True if blocks
        were reclaimed. Parked implies >= 1 committed chunk, hence >= 1
        block, so a True return always frees something."""
        if not self.parked:
            return False
        uid = next(iter(self.parked))
        del self.parked[uid]
        self.allocator.free(uid)
        if self.park_drop_cb is not None:
            self.park_drop_cb(uid)
        self.flight.record(uid, "park_drop", tick=self.tick_now)
        return True

    def _youngest_lane(self) -> Optional[int]:
        running = [
            (self.admit_order[uid], lane)
            for lane, uid in enumerate(self.lane_uid)
            if uid is not None
        ]
        if not running:
            return None
        return max(running)[1]

    def preempt(self, lane: int) -> None:
        """Evict a lane and requeue its request at the queue front. The
        engine's ``requeue_cb`` clears the lane and hands back the Request
        object (the scheduler never holds it). If the engine's ``park_cb``
        claims the lane (mid-chunked-prefill with committed chunks), the
        blocks stay allocated and re-admission resumes at the completed-
        chunk boundary; otherwise blocks are freed and re-admission
        recomputes from scratch."""
        uid = self.lane_uid[lane]
        if uid is None:
            return
        parked = bool(self.park_cb(lane)) if self.park_cb is not None else False
        if parked:
            self.parked[uid] = self.tick_now
        elif self.allocator is not None:
            self.allocator.free(uid)
        self.lane_uid[lane] = None
        self.admit_order.pop(uid, None)
        t = self.timing[uid]
        t.preemptions += 1
        # Tokens generated so far are discarded (recompute on re-admission)
        # and will be re-counted when re-emitted; first_token stands — the
        # user did see it.
        t.new_tokens = 0
        t.last_token_s = None  # decode restarts; don't count the gap as ITL
        t.requeued_s = time.perf_counter()  # first post-resume token ->
        self._preempted.inc()               # resume_ttft, not ttft/itl
        self.flight.record(uid, "preempt", lane=lane, tick=self.tick_now,
                           parked=parked)
        req = self.requeue_cb(lane) if self.requeue_cb else None
        if req is not None:
            self.waiting.appendleft(req)
            self._requeued.inc()
            self.flight.record(uid, "requeue", tick=self.tick_now)

    def release(self, lane: int) -> None:
        """Normal retirement: free blocks, mark finished."""
        uid = self.lane_uid[lane]
        if uid is None:
            return
        if self.allocator is not None:
            self.allocator.free(uid)
        self.lane_uid[lane] = None
        self.admit_order.pop(uid, None)
        t = self.timing[uid]
        t.finished = self.tick_now
        self._finished.inc()
        self._latency_ticks.observe(t.finished - t.arrived)
        self.flight.record(uid, "finish", tick=self.tick_now,
                           tokens=t.new_tokens,
                           latency_ticks=t.finished - t.arrived)

    # -- early termination (cancel / deadline) --------------------------------
    def remove_waiting(self, uid: int):
        """Pull a queued (not yet admitted) request out of the waiting
        queue. Returns the Request, or None if ``uid`` isn't queued."""
        for req in self.waiting:
            if req.uid == uid:
                self.waiting.remove(req)
                return req
        return None

    def discard(self, lane: int, outcome: str) -> None:
        """Terminate a seated lane WITHOUT the normal-finish accounting:
        free its blocks, clear the seat, and record the terminal outcome
        (``cancelled`` / ``deadline_expired``). The request does NOT land
        in serve_finished_total or the latency histograms — an aborted
        request's latency measures the abort policy, not the engine."""
        uid = self.lane_uid[lane]
        if uid is None:
            return
        if self.allocator is not None:
            self.allocator.free(uid)
        self.lane_uid[lane] = None
        self.admit_order.pop(uid, None)
        self.mark_terminal(uid, outcome)

    def mark_terminal(self, uid: int, outcome: str) -> None:
        """Stamp a cancel/deadline terminal state for ``uid`` (counted in
        its own counter, flight-recorded; timing.finished set so drain
        logic treats the uid as done)."""
        t = self.timing.get(uid)
        if t is not None:
            t.finished = self.tick_now
        if outcome == "cancelled":
            self._cancelled.inc()
            self.flight.record(uid, "cancel", tick=self.tick_now)
        elif outcome == "deadline_expired":
            self._deadline_expired.inc()
            self.flight.record(uid, "deadline", tick=self.tick_now)

    def mark_prefix_hit(self, uid: int) -> None:
        """Flag an admission that attached a cached prefix: its first token
        is additionally observed in ``serve_ttft_warm_seconds`` (warm vs
        cold TTFT is the prefix cache's headline win)."""
        self._warm_uids.add(uid)

    def note_token(self, uid: int) -> None:
        t = self.timing[uid]
        now = time.perf_counter()
        if t.requeued_s is not None:
            # First post-resume token: requeue-induced latency goes to its
            # own histogram so neither ttft (request may have streamed
            # tokens pre-preemption) nor itl (this gap is scheduler
            # pressure, not token cadence) is polluted.
            self._resume_ttft_s.observe(now - t.requeued_s)
            t.requeued_s = None
            if t.first_token < 0:
                t.first_token = self.tick_now
        elif t.first_token < 0:
            t.first_token = self.tick_now
            self._ttft_ticks.observe(t.first_token - t.arrived)
            if t.arrived_s is not None:
                self._ttft_s.observe(now - t.arrived_s)
                if uid in self._warm_uids:
                    self._warm_ttft_s.observe(now - t.arrived_s)
        elif t.last_token_s is not None:
            self._itl_s.observe(now - t.last_token_s)
        # Warm marking is one-shot: every branch above leaves first_token
        # set, so the flag is spent once any token has been observed. A
        # standalone statement — folding it into the if-chain above would
        # detach the ITL elif from the requeue/first-token branches.
        self._warm_uids.discard(uid)
        t.last_token_s = now
        t.new_tokens += 1
        self._tokens.inc()

    @property
    def idle(self) -> bool:
        """O(lanes) drain check for the serving hot loop."""
        return not self.waiting and all(u is None for u in self.lane_uid)

    # -- metrics --------------------------------------------------------------
    def stats(self) -> dict:
        """View over the registry (plus live queue/lane state). Percentiles
        come from the fixed-bucket histograms: tick-valued ones use unit
        buckets up to 64 ticks, so typical test-scale distributions report
        exact values; all are None until the first observation."""
        th, lh = self._ttft_ticks, self._latency_ticks
        out = {
            "queued": len(self.waiting),
            "active": sum(u is not None for u in self.lane_uid),
            "admitted": self.total_admitted,
            "finished": self.total_finished,
            "preemptions": self.total_preemptions,
            "new_tokens": sum(t.new_tokens for t in self.timing.values()),
            "ttft_ticks_p50": th.percentile(50),
            "ttft_ticks_p90": th.percentile(90),
            "ttft_ticks_p99": th.percentile(99),
            "latency_ticks_p50": lh.percentile(50),
            "latency_ticks_p90": lh.percentile(90),
            "latency_ticks_p99": lh.percentile(99),
            "ttft_s_p50": self._ttft_s.percentile(50),
            "ttft_s_p99": self._ttft_s.percentile(99),
            "itl_s_p50": self._itl_s.percentile(50),
            "itl_s_p99": self._itl_s.percentile(99),
            "resume_ttft_s_p50": self._resume_ttft_s.percentile(50),
            "resume_ttft_s_p99": self._resume_ttft_s.percentile(99),
            "ttft_warm_s_p50": self._warm_ttft_s.percentile(50),
            "ttft_warm_s_p99": self._warm_ttft_s.percentile(99),
            "cow_copies": int(self._cow_copies.value),
            "parked": len(self.parked),
            "rejected": int(self._rejected.value),
            "cancelled": int(self._cancelled.value),
            "deadline_expired": int(self._deadline_expired.value),
        }
        if self.allocator is not None:
            out["kv"] = self.allocator.stats()
        return out
