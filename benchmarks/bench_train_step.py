"""Training fwd+bwd benchmark: fused custom-VJP kernels vs the jnp path.

For each sequence length the same attention fwd+bwd cell (loss = sum(out^2),
grads w.r.t. q/k/v) runs through ``spectral_shift_attention`` (jnp reference
— materializes the (n, c) factor F and saves it for backward) and
``ss_attention_fused`` (Pallas custom-VJP — saves only the (c, 1) online-
softmax stats and recomputes the streams). Reported per cell:

    fwdbwd_ms     best wall-clock of a jitted value_and_grad call
    peak_temp_mb  XLA CompiledMemoryStats.temp_size_in_bytes of that program
    residual_mb   bytes of the saved VJP residuals (jax.vjp closure) — the
                  tensors that must live across fwd->bwd and set the
                  training memory profile

plus jnp/fused ratio rows. A model-level cell (reduced decoder via
``make_grad_step``) exercises the full dispatch wiring end to end.

On CPU the fused path runs the kernels in interpret mode — wall-clock and
XLA temp there measure interpreter overhead (dense block emulation), not
kernel behavior (the dispatch registry routes CPU to jnp for exactly this
reason). ``residual_mb`` is the backend-independent evidence of the memory
win: the custom VJP saves the (c, 1) online-softmax stats instead of the
(n, c) factor F. TPU is the compile target. ``REPRO_BENCH_SMOKE=1``
shrinks the sweep to one tiny cell for CI.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.core.attention import SSConfig, spectral_shift_attention
from repro.kernels.ops import ss_attention_fused


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def _measure_ms(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))  # compile + warmup
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _peak_temp_mb(fn, args) -> float:
    try:
        stats = jax.jit(fn).lower(*args).compile().memory_analysis()
        return stats.temp_size_in_bytes / 2**20
    except Exception:
        return float("nan")


def _residual_mb(loss_fn, args) -> float:
    """Bytes saved across the fwd->bwd boundary (the vjp closure)."""
    _, vjp_fn = jax.vjp(loss_fn, *args)
    return sum(
        x.nbytes for x in jax.tree.leaves(vjp_fn) if hasattr(x, "nbytes")
    ) / 2**20


def _attention_cell(rows, n, c, d, causal, reps, interpret):
    b = 4  # flattened batch*heads
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, n, d)) * 0.5
    k = jax.random.normal(keys[1], (b, n, d)) * 0.5
    v = jax.random.normal(keys[2], (b, n, d))
    cfg = SSConfig(num_landmarks=c, causal=causal)

    losses = {
        "jnp": lambda q, k, v: jnp.sum(
            spectral_shift_attention(q, k, v, cfg) ** 2
        ),
        "fused": lambda q, k, v: jnp.sum(
            ss_attention_fused(q, k, v, cfg, interpret=interpret) ** 2
        ),
    }
    kind = "causal" if causal else "bidir"
    ms, res = {}, {}
    for name, loss in losses.items():
        case = f"n{n}_{kind}_{name}"
        fn = jax.value_and_grad(loss, argnums=(0, 1, 2))
        ms[name] = _measure_ms(jax.jit(fn), (q, k, v), reps)
        res[name] = _residual_mb(loss, (q, k, v))
        rows.append(f"train_step,{case},fwdbwd_ms,{ms[name]:.2f}")
        rows.append(f"train_step,{case},peak_temp_mb,{_peak_temp_mb(fn, (q, k, v)):.2f}")
        rows.append(f"train_step,{case},residual_mb,{res[name]:.2f}")
    rows.append(
        f"train_step,n{n}_{kind},jnp_over_fused_time,"
        f"{ms['jnp'] / ms['fused']:.3f}"
    )
    rows.append(
        f"train_step,n{n}_{kind},jnp_over_fused_residual_mem,"
        f"{res['jnp'] / res['fused']:.3f}"
    )


def _model_cell(rows, seq_len, reps):
    """Full reduced-decoder fwd+bwd through the dispatch wiring."""
    import dataclasses

    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.models.model import model_specs
    from repro.models.params import init_params
    from repro.train.train_step import make_grad_step

    base = reduced(get_config("qwen2-7b"), num_landmarks=32, remat="ss_stats")
    params = init_params(model_specs(base), jax.random.PRNGKey(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (2, seq_len), 0, base.vocab_size
    )
    batch = {"tokens": tokens}
    for impl in ("spectral_shift", "spectral_shift_fused"):
        cfg = dataclasses.replace(base, attention_impl=impl)
        fn = jax.jit(make_grad_step(cfg))
        t = _measure_ms(fn, (params, batch), reps)
        rows.append(f"train_step,model_{impl}_n{seq_len},fwdbwd_ms,{t:.2f}")


def run(rows: list[str]) -> None:
    interpret = jax.default_backend() == "cpu"
    if _smoke():
        _attention_cell(rows, 512, 32, 64, False, reps=1, interpret=interpret)
        _model_cell(rows, 128, reps=1)
        return
    c, d, reps = 64, 64, 3
    for n in (1024, 4096, 16384):
        _attention_cell(rows, n, c, d, False, reps, interpret)
    _attention_cell(rows, 4096, c, d, True, reps, interpret)
    _model_cell(rows, 512, reps=2)
