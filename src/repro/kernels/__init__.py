"""Pallas TPU kernels for the paper's O(n) attention hot spots.

``ss_attention.py`` holds the two pl.pallas_call kernels (BlockSpec VMEM
tiling), ``ops.py`` the jitted wrappers, ``ref.py`` the pure-jnp oracles.
Validated in interpret mode on CPU; TPU v5e is the compile target.
"""

from repro.kernels.ops import nystrom_attention_fused, ss_attention_fused
from repro.kernels.ss_attention import landmark_summary, query_side

__all__ = [
    "landmark_summary",
    "nystrom_attention_fused",
    "query_side",
    "ss_attention_fused",
]
