"""Telemetry subsystem: histogram/percentile math, span nesting + JSONL
round-trip, the zero-overhead null path, scheduler p90/p99 views, the
drift-monitor-vs-bench_drift equivalence, and the JSONL dump contract the
CI artifact relies on."""
from __future__ import annotations

import dataclasses
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import Scheduler
from repro.telemetry import (
    DriftMonitor,
    MetricsRegistry,
    NullRegistry,
    NullTracer,
    Telemetry,
    Tracer,
    bv_row_residual,
    spectrum_mass,
)
from repro.telemetry.metrics import (
    LATENCY_BUCKETS,
    RATIO_BUCKETS,
    TICK_BUCKETS,
    Histogram,
    exp_buckets,
)


# ==========================================================================
# metrics.py
# ==========================================================================
def test_exp_buckets_shape():
    b = exp_buckets(1.0, 1000.0, per_decade=3)
    assert b[0] == 1.0 and b[-1] >= 1000.0
    assert np.allclose(np.diff(np.log10(b)), 1 / 3)
    with pytest.raises(ValueError):
        exp_buckets(0.0, 1.0)


def test_histogram_bucket_math():
    h = Histogram(bounds=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    # bucket i covers (bounds[i-1], bounds[i]]; overflow catches 100.0
    assert h.counts == [2, 1, 1, 0, 1]
    assert h.count == 5 and h.sum == pytest.approx(106.0)
    assert h.mean == pytest.approx(21.2)


def test_histogram_percentiles():
    h = Histogram(bounds=tuple(float(i) for i in range(1, 65)))
    assert h.percentile(50) is None  # empty
    for v in [1] * 50 + [10] * 40 + [60] * 10:
        h.observe(v)
    # percentile = upper bound of the bucket holding the target rank
    assert h.percentile(50) == 1.0
    assert h.percentile(90) == 10.0
    assert h.percentile(99) == 60.0
    # single-valued distributions are exact (the scheduler contract)
    h2 = Histogram(bounds=TICK_BUCKETS)
    for _ in range(7):
        h2.observe(30)
    assert h2.percentile(50) == 30.0 == h2.percentile(99)
    # overflow observations report the largest finite bound
    h3 = Histogram(bounds=(1.0, 2.0))
    h3.observe(99.0)
    assert h3.percentile(50) == 2.0


def test_registry_families_and_kinds():
    r = MetricsRegistry()
    c = r.counter("reqs_total", labels=("impl",))
    c.labels(impl="paged").inc(2)
    c.labels(impl="gather").inc()
    assert c.labels(impl="paged").value == 2.0
    with pytest.raises(ValueError):
        c.labels(wrong="x")
    # idempotent re-registration returns the same family
    assert r.counter("reqs_total", labels=("impl",)) is c
    with pytest.raises(ValueError):
        r.gauge("reqs_total")  # kind mismatch
    r.gauge("depth", fn=lambda: 7.0)
    snap = r.snapshot()
    assert snap["reqs_total"]["impl=paged"]["value"] == 2.0
    assert snap["depth"]["value"] == 7.0


# ==========================================================================
# tracing.py
# ==========================================================================
def test_span_nesting_and_jsonl_roundtrip():
    r = MetricsRegistry()
    tr = Tracer(r)
    with tr.span("tick", lane=0):
        with tr.span("inner"):
            pass
    with tr.span("tick", lane=1):
        pass
    assert len(tr.events) == 3
    by_name = {e["name"]: e for e in tr.events}
    assert by_name["inner"]["depth"] == 1
    assert by_name["tick"]["depth"] == 0
    # inner closed first, so it records first; durations nest
    assert tr.events[0]["name"] == "inner"
    assert tr.events[1]["dur_s"] >= tr.events[0]["dur_s"]
    fh = io.StringIO()
    assert tr.dump_jsonl(fh) == 3
    lines = [json.loads(x) for x in fh.getvalue().splitlines()]
    assert all(l["kind"] == "span" for l in lines)
    assert lines[1]["labels"] == {"lane": 0}
    # spans feed the span_seconds histogram family
    fam = r.get("span_seconds")
    assert fam.labels(span="tick").count == 2


def test_tracer_bounded_buffer():
    tr = Tracer(max_events=2)
    for _ in range(4):
        with tr.span("x"):
            pass
    assert len(tr.events) == 2 and tr.dropped == 2
    assert tr.summary() == {"events": 2, "dropped": 2}


# ==========================================================================
# the disabled path
# ==========================================================================
def test_null_registry_emits_nothing():
    r = NullRegistry()
    c = r.counter("x")
    c.inc(5)
    h = r.histogram("h", buckets=(1.0,))
    h.observe(3)
    assert c.value == 0.0 and h.percentile(50) is None
    assert r.snapshot() == {} and list(r.iter_samples()) == []
    assert c.labels(anything="goes") is c
    nt = NullTracer()
    with nt.span("a"):
        pass
    assert nt.summary()["events"] == 0
    assert nt.dump_jsonl(io.StringIO()) == 0


def test_disabled_telemetry_dump_writes_nothing(tmp_path):
    t = Telemetry(enabled=False)
    with t.span("x"):
        pass
    p = tmp_path / "t.jsonl"
    assert t.dump_jsonl(p) == 0
    assert not p.exists()
    assert t.snapshot() == {"metrics": {}, "spans": {"events": 0, "dropped": 0}}


# ==========================================================================
# scheduler percentile views (satellite: p50-only fix + empty edge case)
# ==========================================================================
def _dummy(uid):
    return Request(uid, [5, 6, 7], max_new_tokens=4)


def test_scheduler_stats_empty():
    s = Scheduler(None, max_lanes=2, blocks_per_lane=4)
    st = s.stats()
    for k in ("ttft_ticks_p50", "ttft_ticks_p90", "ttft_ticks_p99",
              "latency_ticks_p50", "latency_ticks_p90", "latency_ticks_p99",
              "ttft_s_p50", "itl_s_p99"):
        assert st[k] is None, k
    assert st["admitted"] == 0 and st["queued"] == 0


def test_scheduler_percentiles_p90_p99():
    s = Scheduler(None, max_lanes=1, blocks_per_lane=4)
    s.requeue_cb = lambda lane: None
    # ten sequential requests with TTFTs 1..10 ticks
    for uid in range(10):
        s.tick_now = uid * 100
        s.submit(_dummy(uid))
        [(lane, _)] = s.admit()
        s.tick_now = uid * 100 + (uid + 1)  # first token after uid+1 ticks
        s.note_token(uid)
        s.note_token(uid)  # second token: exercises the ITL histogram
        s.release(lane)
    st = s.stats()
    assert st["ttft_ticks_p50"] == 5.0
    assert st["ttft_ticks_p90"] == 9.0
    assert st["ttft_ticks_p99"] == 10.0
    assert st["finished"] == 10
    assert st["itl_s_p50"] is not None
    fam = s.registry.get("serve_itl_seconds")
    assert fam.count == 10


# ==========================================================================
# drift monitor == bench_drift's offline formula (small case)
# ==========================================================================
def test_drift_probe_matches_offline_rebase_numbers():
    """Run the frozen-mode protocol with the decode_state primitives; at a
    segment boundary the monitor's pre-vs-post residual must equal the
    offline recompute-based drift (bench_drift's per-row formula) on the
    two rebased rows — the rebase IS the exact recompute."""
    from repro.serve.decode_state import (
        landmark_counts,
        landmark_means,
        rebase_rows,
        recompute_stats,
        segment_len,
        stream_append,
    )

    B, H, S, D, C = 1, 2, 32, 8, 8
    seg = segment_len(S, C)
    scale = D ** -0.5
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, S, D)) * 0.5
    k = q  # self-similar regime: non-trivial drift
    v = jax.random.normal(ks[2], (B, H, S, D))

    stats = (jnp.zeros((B, H, C, 1)), jnp.zeros((B, H, C, 1)),
             jnp.zeros((B, H, C, D)))
    q_sums = jnp.zeros((B, H, C, D))
    checked = 0
    for t in range(S):
        onehot = jax.nn.one_hot(t // seg, C, dtype=jnp.float32)
        q_sums = q_sums + onehot[:, None] * q[:, :, t][:, :, None, :]
        counts = landmark_counts(jnp.asarray(t), S, C)
        q_l = landmark_means(q_sums, counts)
        active = t // seg
        stats = stream_append(stats, q_l, k[:, :, t], v[:, :, t], scale,
                              row_mask=jnp.arange(C) <= active)
        if t > 0 and t % seg == 0:
            rows = [max(active - 1, 0), active]
            pre = tuple(np.asarray(x) for x in stats)
            stats = rebase_rows(stats, q_l, k, v, t, scale,
                                jnp.stack([rows[0], rows[1]]))
            post = tuple(np.asarray(x) for x in stats)
            monitor = bv_row_residual((pre[1], pre[2]), (post[1], post[2]),
                                      rows)
            # offline: bench_drift's _drift_at per-row formula against the
            # exact one-shot recompute, restricted to the rebased rows
            m_r, l_r, acc_r = recompute_stats(q_l, k, v, t, scale,
                                              row_valid=counts > 0)
            bv_f = pre[2] / np.maximum(pre[1], 1e-30)
            bv_e = np.asarray(acc_r) / np.maximum(np.asarray(l_r), 1e-30)
            per_row = np.linalg.norm(bv_f - bv_e, axis=-1) / np.maximum(
                np.linalg.norm(bv_e, axis=-1), 1e-30)
            offline = float(np.max(per_row[..., rows]))
            assert monitor == pytest.approx(offline, rel=1e-5)
            checked += 1
    assert checked >= 2
    # registry plumbing: observations land in the residual histogram
    r = MetricsRegistry()
    mon = DriftMonitor(r)
    mon.observe(0.01)
    mon.observe(0.02)
    hist = r.get("drift_rebase_residual")
    assert hist.count == 2 and r.get("drift_rebase_residual_last").value == 0.02


def test_spectrum_mass_extremes():
    C = 8
    m = np.zeros((1, C, 1))
    l = np.ones((1, C, 1))
    top1, eff = spectrum_mass(m, l, reached=C)  # perfectly even mass
    assert top1 == pytest.approx(1 / C)
    assert eff == pytest.approx(1.0)
    l1 = np.full((1, C, 1), 1e-12)
    l1[0, 3, 0] = 1.0  # all mass on one landmark
    top1, eff = spectrum_mass(m, l1, reached=C)
    assert top1 == pytest.approx(1.0, abs=1e-6)
    assert eff == pytest.approx(1 / C, rel=1e-3)


# ==========================================================================
# engine integration: JSONL contract + zero-overhead disabled path
# ==========================================================================
@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0,
        decode_streaming="frozen",
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n, max_new=20):
    rng = np.random.default_rng(11)
    return [
        Request(u, rng.integers(3, cfg.vocab_size,
                                int(rng.integers(8, 20))).tolist(),
                max_new_tokens=max_new)
        for u in range(n)
    ]


CORE_FAMILIES = (
    "serve_ttft_ticks", "serve_latency_ticks", "serve_ttft_seconds",
    "serve_itl_seconds", "serve_admitted_total", "serve_tokens_total",
    "serve_ticks_total", "serve_rebases_total", "span_seconds",
    "pool_utilization", "pool_fragmentation",
    "autotune_plan_resolutions_total", "drift_rebase_residual",
    "spectrum_mass_top1_ema",
)


def test_engine_telemetry_jsonl_contract(qwen, tmp_path):
    """The CI artifact contract: an enabled frozen-mode run dumps JSONL
    that parses and contains every core metric family plus per-tick spans.
    Guards against silent metric renames."""
    cfg, params = qwen
    serve = ServeConfig(max_lanes=2, max_seq=64, block_size=8, telemetry=True)
    eng = ServeEngine(cfg, params, serve=serve)
    for r in _reqs(cfg, 3):
        eng.submit(r)
    eng.run()
    st = eng.stats()
    assert st["rebases"] > 0
    assert st["telemetry"]["events"] > 0
    path = tmp_path / "telemetry.jsonl"
    n = eng.telemetry.dump_jsonl(path, meta={"bench": "test"})
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert len(lines) == n
    assert lines[0]["kind"] == "meta"
    names = {l["name"] for l in lines if l["kind"] == "metric"}
    for fam in CORE_FAMILIES:
        assert fam in names, f"core metric family {fam} missing from dump"
    spans = [l for l in lines if l["kind"] == "span"]
    assert {"serve_tick", "decode_dispatch", "device_sync"} <= {
        s["name"] for s in spans
    }
    # TTFT/ITL histograms expose p50/p99 in the dump
    ttft = next(l for l in lines
                if l["kind"] == "metric" and l["name"] == "serve_ttft_ticks")
    assert ttft["count"] > 0 and ttft["p50"] is not None and "p99" in ttft
    drift = next(l for l in lines
                 if l["kind"] == "metric"
                 and l["name"] == "drift_rebase_residual")
    assert drift["count"] == st["rebases"]


def test_engine_disabled_identical_and_clean(qwen):
    """telemetry=False: greedy outputs token-identical to an enabled run,
    no telemetry keys in stats(), percentile views still populated."""
    cfg, params = qwen
    reqs = _reqs(cfg, 2, max_new=10)
    on = ServeConfig(max_lanes=2, max_seq=64, block_size=8, telemetry=True)
    off = dataclasses.replace(on, telemetry=False)
    out_on = out_off = None
    for serve in (on, off):
        eng = ServeEngine(cfg, params, serve=serve)
        for r in reqs:
            eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
        out = eng.run()
        if serve.telemetry:
            out_on = out
        else:
            out_off = out
            st = eng.stats()
            assert "telemetry" not in st
            assert eng.telemetry.metrics.snapshot() == {}
            assert isinstance(eng.telemetry.metrics, NullRegistry)
            # satellite-1 views work without the telemetry knob
            assert st["ttft_ticks_p99"] is not None
            assert st["latency_ticks_p90"] is not None
    assert out_on == out_off
