"""Impl / block-size dispatch for spectral-shifting attention.

One registry answers "which implementation, which block size?" for every
attention call, replacing ad-hoc ``impl == "spectral_shift_fused"``
branching in model code:

    key  = (backend, n_bucket, c, d, dtype, causal, family, seq_shards)
    plan = Plan(impl = fused | jnp | interpret | sharded, block_n, block_c,
                source)

``family="decode"`` keys serving's single-step shape (n = cache horizon);
``seq_shards`` keys context-parallel cells, whose plans route through the
shard_map driver in ``kernels/sharded.py``.

Resolution order: in-memory registry -> on-disk autotune cache -> measured
autotune (only when explicitly enabled) -> backend heuristic. Plans are
resolved at *trace* time — shapes are static under jit, so a jitted train
step consults the registry once per compiled shape and bakes the winning
kernel in.

The measured-autotune mode times real candidate executions (jnp reference
vs fused kernels across the (block_n, block_c) grid — ``block_c`` tiles the
B-side kernel's landmark rows, see kernels/ss_attention.py) on synthetic
data of the exact shape and persists winners to a JSON cache
(``REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/ss_autotune.json``) so
subsequent processes skip the measurement. ``n`` is bucketed to the next
power of two to keep the cache dense across nearby sequence lengths.

``decode`` keys measure through their own harness (``autotune_decode``):
the gather-route jnp one-row recompute vs the gather-free paged kernel
(kernels/paged_decode.py) across the ``block_table`` view-slot-bucketing
grid at the serve shape — ``ServeEngine`` warms this key at construction,
so a tuned deployment's ticks follow the measured winner's geometry.

Cache payloads are written at version 3 (plans carry ``block_table``; v2
added ``block_c``); older caches load unchanged with the missing fields
defaulting to 0 (the former behavior).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.attention import SSConfig, spectral_shift_attention
from repro.telemetry.metrics import NullRegistry

_IMPLS = ("fused", "jnp", "interpret", "sharded", "paged")
_FAMILIES = ("self", "decode")

# Telemetry sink. The import-time default is the no-op registry — plan
# resolution happens at trace time on hot paths, and with telemetry off the
# counters must cost nothing. ServeEngine/Trainer install their shared
# registry via set_metrics() when ServeConfig.telemetry is enabled.
_METRICS = NullRegistry()


def set_metrics(registry) -> None:
    """Install a metrics registry for plan-resolution counters (process-
    wide, like the plan registry itself). Pass ``NullRegistry()`` to
    detach."""
    global _METRICS
    _METRICS = registry


def _count_resolution(outcome: str) -> None:
    # outcome: memory|disk (cache tier hits), miss_sweep (measured
    # autotune ran), miss_heuristic (backend default used)
    _METRICS.counter(
        "autotune_plan_resolutions_total",
        help="get_plan outcomes by resolution tier",
        labels=("outcome",),
    ).labels(outcome=outcome).inc()


@dataclasses.dataclass(frozen=True)
class PlanKey:
    backend: str      # "cpu" | "tpu" | "gpu"
    n: int            # sequence length, bucketed to next power of two
    c: int            # landmark count
    d: int            # head dim
    dtype: str        # canonical dtype name, e.g. "float32" / "bfloat16"
    causal: bool
    family: str = "self"   # "self" = full-sequence attention; "decode" =
                           # one-step query against a cache horizon of n
    seq_shards: int = 1    # context parallelism: devices the sequence axis
                           # is sharded over (1 = single-device kernels)

    def encode(self) -> str:
        kind = "causal" if self.causal else "bidir"
        s = f"{self.backend}|n{self.n}|c{self.c}|d{self.d}|{self.dtype}|{kind}"
        if self.family != "self":
            s += f"|{self.family}"
        if self.seq_shards > 1:
            s += f"|sp{self.seq_shards}"
        return s

    @staticmethod
    def decode(s: str) -> "PlanKey":
        parts = s.split("|")
        backend, n, c, d, dtype, kind = parts[:6]
        family, seq_shards = "self", 1
        for extra in parts[6:]:  # optional suffixes; legacy keys have none
            if extra.startswith("sp"):
                seq_shards = int(extra[2:])
            elif extra in _FAMILIES:
                family = extra
            else:
                raise ValueError(f"unknown PlanKey suffix {extra!r}")
        return PlanKey(
            backend=backend, n=int(n[1:]), c=int(c[1:]), d=int(d[1:]),
            dtype=dtype, causal=(kind == "causal"), family=family,
            seq_shards=seq_shards,
        )


@dataclasses.dataclass(frozen=True)
class Plan:
    impl: str            # "fused" | "jnp" | "interpret" | "sharded" |
                         # "paged" (decode family: the gather-free
                         # block-table kernel; "jnp" = the gather route)
    block_n: int = 512
    block_c: int = 0     # landmark-row tile for the B-side kernel (0 = all
                         # rows resident; only honored when it divides c)
    block_table: int = 0  # decode family: view-slot bucketing quantum for
                          # the paged decode kernel — the engine rounds the
                          # block-table slot count (kernel grid size) up to
                          # a multiple of this instead of the next power of
                          # two (0 = power-of-two default). Trades compiled
                          # tick-program count against wasted masked grid
                          # steps.
    source: str = "heuristic"  # heuristic | registered | cache | autotuned

    def __post_init__(self):
        if self.impl not in _IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; want one of {_IMPLS}")


_lock = threading.Lock()
_REGISTRY: dict[PlanKey, Plan] = {}
_CACHE_LOADED: set[str] = set()
_CACHE_OVERRIDE: Optional[str] = None


def _bucket(n: int) -> int:
    """Next power of two >= n (min 128): nearby lengths share one plan."""
    b = 128
    while b < n:
        b *= 2
    return b


def make_key(
    n: int, c: int, d: int, dtype, causal: bool, backend: Optional[str] = None,
    family: str = "self", seq_shards: int = 1,
) -> PlanKey:
    """``family="decode"`` keys a single-step (n_q=1) query against a cache
    horizon of ``n`` tokens; ``seq_shards`` keys context-parallel cells by
    how many devices the sequence axis spans."""
    if family not in _FAMILIES:
        raise ValueError(f"unknown key family {family!r}; want one of {_FAMILIES}")
    return PlanKey(
        backend=backend or jax.default_backend(),
        n=_bucket(n),
        c=c,
        d=d,
        dtype=jnp.dtype(dtype).name,
        causal=causal,
        family=family,
        seq_shards=max(int(seq_shards), 1),
    )


def cache_path() -> str:
    if _CACHE_OVERRIDE:
        return _CACHE_OVERRIDE
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "ss_autotune.json"),
    )


def set_cache_path(path: Optional[str]) -> None:
    """Process-wide cache-file override (``ModelConfig.autotune_cache``):
    every subsequent load/save — including trace-time ``_default_tune``
    winners — round-trips through this file. ``None``/"" restores the
    env-var/default resolution."""
    global _CACHE_OVERRIDE
    _CACHE_OVERRIDE = path or None


def register_plan(key: PlanKey, plan: Plan) -> None:
    with _lock:
        _REGISTRY[key] = plan


def clear_registry() -> None:
    global _CACHE_OVERRIDE
    with _lock:
        _REGISTRY.clear()
        _CACHE_LOADED.clear()
        _CACHE_OVERRIDE = None


def load_cache(path: Optional[str] = None) -> int:
    """Merge plans from the on-disk cache into the registry; returns count."""
    path = path or cache_path()
    if not os.path.exists(path):
        return 0
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return 0
    plans = payload.get("plans", {})
    loaded = 0
    with _lock:
        for ks, pd in plans.items():
            try:
                key = PlanKey.decode(ks)
                plan = Plan(
                    impl=pd["impl"], block_n=int(pd["block_n"]),
                    # Version-1 caches predate block_c, version <=2 predate
                    # block_table; absent means untiled / pow2-bucketed.
                    block_c=int(pd.get("block_c", 0)),
                    block_table=int(pd.get("block_table", 0)),
                    source="cache",
                )
            except (ValueError, KeyError):
                continue
            # In-process plans (registered/autotuned this run) win over disk.
            _REGISTRY.setdefault(key, plan)
            loaded += 1
        _CACHE_LOADED.add(path)
    return loaded


def save_cache(path: Optional[str] = None) -> str:
    """Write all non-heuristic registry plans to disk (atomic, merging)."""
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    existing: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f).get("plans", {})
        except (OSError, json.JSONDecodeError):
            existing = {}
    with _lock:
        for key, plan in _REGISTRY.items():
            if plan.source == "heuristic":
                continue
            existing[key.encode()] = {
                "impl": plan.impl, "block_n": plan.block_n,
                "block_c": plan.block_c, "block_table": plan.block_table,
            }
    tmp = f"{path}.tmp.{os.getpid()}"
    # Version 3: plans carry block_table (v2 added block_c). Readers accept
    # every version (missing fields default to 0), so old caches stay
    # usable in place.
    with open(tmp, "w") as f:
        json.dump({"version": 3, "plans": existing}, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path


def heuristic_plan(key: PlanKey) -> Plan:
    """Backend defaults when nothing measured is available."""
    if key.family == "decode":
        # "jnp" = the gather route's dense-view decode math; "paged" = the
        # gather-free block-table kernel (kernels/paged_decode.py). On a
        # real accelerator the paged kernel wins by skipping the per-tick
        # view gather; on CPU interpret-mode Pallas loses to jnp, so the
        # gather route stays the default there.
        impl = "jnp" if key.backend == "cpu" else "paged"
        return Plan(impl=impl, block_n=min(512, key.n), source="heuristic")
    if key.backend == "cpu":
        # Interpret-mode Pallas is an order of magnitude slower than the jnp
        # reference on CPU; fused only pays off on a real accelerator. Holds
        # for context-parallel cells too (the jnp route partitions via GSPMD).
        return Plan(impl="jnp", block_n=min(512, key.n), source="heuristic")
    # Block size from the PER-DEVICE stream length: under context
    # parallelism each shard streams only n / seq_shards keys.
    n_loc = max(key.n // key.seq_shards, 128)
    if n_loc <= 1024:
        block = 256
    elif n_loc <= 8192:
        block = 512
    else:
        block = 1024
    impl = "sharded" if key.seq_shards > 1 else "fused"
    return Plan(impl=impl, block_n=block, source="heuristic")


def get_plan(key: PlanKey, *, autotune_enabled: bool = False,
             tune_fn: Optional[Callable[[PlanKey], Plan]] = None) -> Plan:
    """Registry -> disk cache -> measured autotune (opt-in) -> heuristic."""
    with _lock:
        plan = _REGISTRY.get(key)
    if plan is not None:
        _count_resolution("memory")
        return plan
    if cache_path() not in _CACHE_LOADED:
        load_cache()
        with _lock:
            plan = _REGISTRY.get(key)
        if plan is not None:
            _count_resolution("disk")
            return plan
    if autotune_enabled:
        if key.seq_shards > 1:
            # Measured autotune cannot reproduce the multi-device program;
            # measuring here would register the winner under a DIFFERENT
            # key (no seq_shards) and re-run the timing sweep on every
            # trace of the requested key. Heuristics (or pre-registered
            # plans) steer context-parallel cells.
            _count_resolution("miss_heuristic")
            return heuristic_plan(key)
        _count_resolution("miss_sweep")
        if key.family == "decode":
            # Decode keys get their own harness: gather-route jnp recompute
            # vs the paged kernel across the (block_n, block_table) grid at
            # the serve shape, registered under the decode key itself.
            return (tune_fn or _default_decode_tune)(key)
        return (tune_fn or _default_tune)(key)
    _count_resolution("miss_heuristic")
    return heuristic_plan(key)


# --------------------------------------------------------------------------
# Measured autotune.
# --------------------------------------------------------------------------
def _time_call(fn, *args, reps: int = 2) -> float:
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(
    n: int,
    c: int,
    d: int,
    dtype=jnp.float32,
    causal: bool = False,
    *,
    backend: Optional[str] = None,
    block_candidates: tuple[int, ...] = (256, 512, 1024),
    block_c_candidates: Optional[tuple[int, ...]] = None,
    reps: int = 2,
    save: bool = True,
    cache_file: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> Plan:
    """Measure jnp vs fused across the (block_n, block_c) candidate grid on
    synthetic data of the exact shape; register and (optionally) persist the
    winner. ``block_c_candidates`` defaults to the untiled kernel plus the
    divisor tiles c/2 and c/4 (when whole) — tiling trades smaller VMEM
    accumulators for re-streaming K/V per landmark tile."""
    from repro.kernels.ops import ss_attention_fused

    _METRICS.counter(
        "autotune_sweeps_total", help="measured autotune sweeps run",
        labels=("family",),
    ).labels(family="self").inc()
    key = make_key(n, c, d, dtype, causal, backend=backend)
    if interpret is None:
        interpret = key.backend == "cpu"
    if block_c_candidates is None:
        block_c_candidates = (0,) + tuple(
            c // f for f in (2, 4) if c % f == 0 and c // f >= 8
        )
    cfg = SSConfig(num_landmarks=c, causal=causal)
    rng = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(rng, 3)
    q = (jax.random.normal(kq, (1, n, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (1, n, d)) * 0.5).astype(dtype)
    v = jax.random.normal(kv, (1, n, d)).astype(dtype)

    # Tag the sweep so telemetry/accounting.py attributes its (expected,
    # numerous) backend compiles to "autotune_sweep" instead of whatever
    # hot-loop program the engine/trainer is currently tagged with.
    from repro.telemetry.accounting import tagged_program

    jnp_fn = jax.jit(lambda q, k, v: spectral_shift_attention(q, k, v, cfg))
    with tagged_program("autotune_sweep"):
        results: list[tuple[float, Plan]] = [
            (_time_call(jnp_fn, q, k, v, reps=reps),
             Plan(impl="jnp", block_n=min(512, n), source="autotuned"))
        ]
        fused_impl = "interpret" if interpret else "fused"
        for block in dict.fromkeys(min(bc, n) for bc in block_candidates):
            for bc_c in dict.fromkeys(block_c_candidates):
                fn = functools.partial(
                    ss_attention_fused, cfg=cfg, block_n=block, block_c=bc_c,
                    interpret=interpret,
                )
                try:
                    t = _time_call(fn, q, k, v, reps=reps)
                except Exception:
                    continue  # candidate doesn't lower on this backend/shape
                results.append((
                    t,
                    Plan(impl=fused_impl, block_n=block, block_c=bc_c,
                         source="autotuned"),
                ))
    _, plan = min(results, key=lambda r: r[0])
    register_plan(key, plan)
    if save:
        save_cache(cache_file)
    return plan


def _default_tune(key: PlanKey) -> Plan:
    return autotune(
        key.n, key.c, key.d, dtype=key.dtype, causal=key.causal,
        backend=key.backend,
    )


def autotune_decode(
    n: int,
    c: int,
    d: int,
    dtype=jnp.float32,
    *,
    backend: Optional[str] = None,
    block_size: int = 16,
    block_table_candidates: tuple[int, ...] = (0, 2, 4, 8),
    reps: int = 2,
    save: bool = True,
    cache_file: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> Plan:
    """Measured autotune for the ``decode`` key family: the per-tick
    horizon read at the serve shape (cache horizon ``n``, one active row
    per kv head).

    Candidates: the gather route (assemble the dense block view, then the
    jnp one-row recompute — ``impl="jnp"``) vs the gather-free paged kernel
    (``impl="paged"``) across the ``block_table`` grid. ``block_table`` is
    the view-slot bucketing quantum (see ``Plan``); each candidate is timed
    at a mid-growth and a full view so quanta that round to larger masked
    grids pay for it honestly. The kernel's key-block size is pinned to the
    pool's ``block_size`` by the storage layout, so — unlike the self
    family — ``block_n`` has no measured dimension here; it is carried at
    the heuristic value for any blockwise gather-route scans. The winner
    registers (and persists) under the decode key itself.

    Callers must pass the deployment's real ``block_size``
    (``ServeEngine`` threads ``ServeConfig.block_size`` through its
    ``tune_fn``): ``PlanKey`` does not encode block size, so deployments
    that share a shape key but differ in block size overwrite each
    other's measured winner — last tuned wins, a deliberate granularity
    trade-off, but never measure at a geometry you don't serve."""
    from repro.kernels.paged_decode import paged_row_stats_lanes
    from repro.serve.decode_state import recompute_stats
    from repro.serve.paged import bucket_view_slots

    _METRICS.counter(
        "autotune_sweeps_total", help="measured autotune sweeps run",
        labels=("family",),
    ).labels(family="decode").inc()
    key = make_key(n, c, d, dtype, True, backend=backend, family="decode")
    if interpret is None:
        interpret = key.backend == "cpu"
    bs = block_size
    n_slots_full = -(-n // bs)
    rng = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(rng, 3)
    q = (jax.random.normal(kq, (1, 1, 1, d)) * 0.5).astype(jnp.float32)
    k_pool = (jax.random.normal(kk, (1, n_slots_full + 1, bs, d)) * 0.5).astype(dtype)
    v_pool = jax.random.normal(kv, (1, n_slots_full + 1, bs, d)).astype(dtype)
    table = jnp.arange(1, n_slots_full + 1, dtype=jnp.int32)
    views = sorted({max(n_slots_full // 2, 1), n_slots_full})
    scale = 1.0 / (d ** 0.5)

    def time_gather(nv: int) -> float:
        tb = table[:nv]

        def fn(q_, kp, vp):
            kvw = jnp.take(kp, tb, axis=1).reshape(1, 1, nv * bs, d)
            vvw = jnp.take(vp, tb, axis=1).reshape(1, 1, nv * bs, d)
            return recompute_stats(q_, kvw, vvw, nv * bs - 2, scale)

        return _time_call(jax.jit(fn), q, k_pool, v_pool, reps=reps)

    # Same compile attribution as the self-family sweep above.
    from repro.telemetry.accounting import tagged_program

    with tagged_program("autotune_sweep"):
        results: list[tuple[float, Plan]] = [(
            sum(time_gather(nv) for nv in views),
            Plan(impl="jnp", block_n=min(512, n), source="autotuned"),
        )]
        for bt in dict.fromkeys(block_table_candidates):
            t = 0.0
            try:
                for nv in views:
                    nv_r = bucket_view_slots(nv, n_slots_full, bt)
                    tb = jnp.pad(table[:nv], (0, nv_r - nv))[None]  # ZERO_BLOCK
                    kvv = jnp.asarray([nv * bs - 1], jnp.int32)

                    def fn(q_, kp, vp, tb=tb, kvv=kvv):
                        return paged_row_stats_lanes(
                            q_, (kp,), vp, tb, kvv, scale=scale, block_size=bs,
                            interpret=interpret,
                        )

                    t += _time_call(jax.jit(fn), q, k_pool, v_pool, reps=reps)
            except Exception:
                continue  # candidate doesn't lower on this backend/shape
            results.append((
                t,
                Plan(impl="paged", block_n=min(512, n), block_table=bt,
                     source="autotuned"),
            ))
    _, plan = min(results, key=lambda r: r[0])
    register_plan(key, plan)
    if save:
        save_cache(cache_file)
    return plan


def _default_decode_tune(key: PlanKey) -> Plan:
    return autotune_decode(
        key.n, key.c, key.d, dtype=key.dtype, backend=key.backend,
    )


# --------------------------------------------------------------------------
# Model-facing entry point.
# --------------------------------------------------------------------------
def dispatch_ss_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig,
    *,
    scale: Optional[float] = None,
    backend: str = "auto",
    autotune_enabled: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    """Route one attention call through the dispatch registry.

    ``backend``: "auto" resolves a plan per shape key; "fused" / "jnp" /
    "interpret" / "sharded" force that implementation. Shapes (..., n, d)
    with arbitrary leading dims. Fully differentiable on every route.

    Mesh-aware: when the active ``sharding_rules`` context maps the sequence
    axis onto >1 devices, the shape key carries ``seq_shards`` and every
    kernel-backed impl routes through the shard_map context-parallel driver
    (kernels/sharded.py) instead of the single-device kernels — seq-sharded
    cells keep the fused path rather than falling back to jnp.
    """
    from repro.distributed.sharding import active_seq_sharding
    from repro.kernels.ops import ss_attention_fused

    n, d = q.shape[-2], q.shape[-1]
    mesh, seq_axes, lead_axes = active_seq_sharding()
    n_shards = 1
    if seq_axes:
        for a in seq_axes:
            n_shards *= int(mesh.shape[a])
    # Sharded self-attention only: decode/cross rectangular shapes keep the
    # single-device routing (their key axis isn't the sharded one).
    sharded_site = n_shards > 1 and n == k.shape[-2]
    if backend == "auto":
        key = make_key(
            n, cfg.num_landmarks, d, q.dtype, cfg.causal,
            seq_shards=n_shards if sharded_site else 1,
        )
        plan = get_plan(key, autotune_enabled=autotune_enabled)
        impl, block_n, block_c = plan.impl, plan.block_n, plan.block_c
    elif backend in _IMPLS:
        impl, block_n, block_c = backend, 512, 0
    else:
        raise ValueError(
            f"unknown attention backend {backend!r}; want 'auto' or one of {_IMPLS}"
        )
    if impl == "paged":
        raise ValueError(
            "'paged' plans serve the decode key family (block-pool serving "
            "ticks); self-attention sites cannot route through it"
        )
    if impl == "jnp":
        return spectral_shift_attention(q, k, v, cfg, scale=scale)
    if sharded_site and impl in ("fused", "interpret", "sharded"):
        from repro.kernels.sharded import ss_attention_fused_sharded

        return ss_attention_fused_sharded(
            q, k, v, cfg, mesh=mesh, seq_axes=seq_axes, lead_axes=lead_axes,
            scale=scale, block_n=block_n,
            interpret=True if impl == "interpret" else interpret,
        )
    if impl == "sharded":
        # A sharded plan outside a seq-sharded context degenerates to the
        # single-device kernels (one shard).
        impl = "fused"
    return ss_attention_fused(
        q, k, v, cfg, scale=scale, block_n=block_n, block_c=block_c,
        interpret=True if impl == "interpret" else interpret,
    )
