"""Decode-path consistency: teacher-forced decode against the full-sequence
forward, per family; landmark-state bookkeeping; cache structure."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.model import model_forward, model_specs
from repro.models.params import init_params
from repro.serve.decode import (
    _landmark_counts,
    _lmk_add,
    decode_step,
    ss_decode_attention,
)
from repro.serve.kv_cache import cache_specs

S_MAX = 48


def _setup(arch, decode_impl="full", seed=0):
    cfg = reduced(get_config(arch))
    # Dropless MoE for decode-vs-forward comparison: capacity dropping is a
    # function of sequence length, so token-by-token decode and full-sequence
    # forward legitimately differ when tokens overflow expert capacity.
    cfg = dataclasses.replace(
        cfg, decode_attention_impl=decode_impl, capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(seed))
    cache = init_params(cache_specs(cfg, 2, S_MAX), jax.random.PRNGKey(1))
    return cfg, params, cache


def _teacher_force(cfg, params, cache, tokens):
    """Feed tokens one by one through decode_step; stack per-step logits."""
    step = jax.jit(lambda c, t: decode_step(params, cfg, c, t))
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = step(cache, tokens[:, i : i + 1])
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-20b", "hymba-1.5b",
                                  "xlstm-350m", "deepseek-v2-lite-16b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode (full attention) == full-sequence forward."""
    cfg, params, cache = _setup(arch)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 12)), jnp.int32)
    dec_logits, _ = _teacher_force(cfg, params, cache, tokens)
    fwd_logits, _ = model_forward(params, cfg, {"tokens": tokens})
    atol = 2e-2 if cfg.family in ("hybrid", "ssm") else 1e-3
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(fwd_logits, np.float32),
        atol=atol, rtol=atol,
    )


def test_decode_position_advances():
    cfg, params, cache = _setup("qwen2-7b")
    assert int(cache["pos"]) == 0
    tok = jnp.ones((2, 1), jnp.int32)
    _, cache = decode_step(params, cfg, cache, tok)
    _, cache = decode_step(params, cfg, cache, tok)
    assert int(cache["pos"]) == 2


def test_ss_decode_no_nans_every_position():
    """SS decode attention is finite from the very first token (partially
    filled landmark state) to a full cache."""
    cfg, params, cache = _setup("qwen2-7b", decode_impl="spectral_shift")
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, S_MAX - 1)), jnp.int32)
    logits, _ = _teacher_force(cfg, params, cache, tokens)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


def test_ss_decode_approximates_full_decode():
    """Attention-level: SS decode vs exact decode error is bounded and is
    consistent with the bidirectional jnp SS path given the same landmarks."""
    from repro.core.attention import SSConfig, spectral_shift_attention
    from repro.serve.decode import full_decode_attention

    rng = np.random.default_rng(0)
    B, H, S, D, c = 1, 2, 64, 16, 16
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), num_landmarks=c,
        include_shift_identity=False,
    )
    ks = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) * 0.5
    vs = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    qs = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) * 0.5
    scale = 1 / np.sqrt(D)
    q_sum = jnp.zeros((B, H, c, D))
    k_sum = jnp.zeros((B, H, c, D))
    add = jax.vmap(jax.vmap(_lmk_add, (0, 0, None, None)), (0, 0, None, None))
    errs = []
    for pos in range(S):
        q_sum = add(q_sum, qs[:, :, pos], jnp.asarray(pos), S)
        k_sum = add(k_sum, ks[:, :, pos], jnp.asarray(pos), S)
        q = qs[:, :, pos : pos + 1]
        out_ss = ss_decode_attention(
            q, ks, vs, q_sum, k_sum, jnp.asarray(pos), cfg, scale
        )
        out_f = full_decode_attention(q, ks, vs, jnp.asarray(pos), scale)
        errs.append(float(
            jnp.linalg.norm(out_ss - out_f)
            / jnp.maximum(jnp.linalg.norm(out_f), 1e-9)
        ))
    assert np.mean(errs[S // 2 :]) < 0.3, np.mean(errs[S // 2 :])

    # Consistency: decode-path SS == jnp SS given identical landmark means.
    pos = S - 1
    seg = S // c
    counts = jnp.clip(pos + 1 - jnp.arange(c) * seg, 0, seg).astype(jnp.float32)
    out_jnp = spectral_shift_attention(
        qs[:, :, pos : pos + 1], ks, vs,
        SSConfig(num_landmarks=c, method="iterative",
                 include_shift_identity=False),
        q_landmarks=q_sum / counts[:, None],
        k_landmarks=k_sum / counts[:, None],
    )
    out_dec = ss_decode_attention(
        qs[:, :, pos : pos + 1], ks, vs, q_sum, k_sum, jnp.asarray(pos), cfg,
        scale,
    )
    np.testing.assert_allclose(out_jnp, out_dec, atol=1e-5)


class TestLandmarkBookkeeping:
    def test_counts(self):
        # seq_max=48, c=4 -> segment length 12.
        counts = _landmark_counts(jnp.asarray(13), 48, 4)
        np.testing.assert_array_equal(counts, [12, 2, 0, 0])
        counts = _landmark_counts(jnp.asarray(47), 48, 4)
        np.testing.assert_array_equal(counts, [12, 12, 12, 12])

    def test_incremental_sums_match_segment_means(self):
        """Running landmark sums after n tokens == segment_means of those
        tokens (the invariant that keeps decode landmarks fresh)."""
        from repro.core.landmarks import segment_means

        rng = np.random.default_rng(0)
        n, c, d, s_max = 24, 4, 8, 24
        xs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        sums = jnp.zeros((c, d))
        for pos in range(n):
            sums = _lmk_add(sums, xs[pos], jnp.asarray(pos), s_max)
        counts = _landmark_counts(jnp.asarray(n - 1), s_max, c)
        means = sums / counts[:, None]
        ref = segment_means(xs[None], c)[0]
        np.testing.assert_allclose(means, ref, atol=1e-5)

    def test_ss_decode_attention_single_query(self):
        rng = np.random.default_rng(3)
        B, H, S, D, c = 1, 2, 32, 8, 4
        q = jnp.asarray(rng.normal(size=(B, H, 1, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
        q_lmk = jnp.asarray(rng.normal(size=(B, H, c, D)), jnp.float32)
        k_lmk = jnp.asarray(rng.normal(size=(B, H, c, D)), jnp.float32)
        cfg = reduced(get_config("qwen2-7b"))
        out = ss_decode_attention(
            q, k, v, q_lmk, k_lmk, jnp.asarray(S - 1), cfg, 1 / D**0.5
        )
        assert out.shape == (B, H, 1, D)
        assert not bool(jnp.any(jnp.isnan(out)))


def test_whisper_decode_runs():
    cfg, params, _ = _setup("whisper-base")
    rng = np.random.default_rng(0)
    # Whisper cache needs encoder features precomputed.
    from repro.serve.kv_cache import cache_specs as cs

    cache = init_params(cs(cfg, 2, S_MAX), jax.random.PRNGKey(1))
    frames = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    # Encode once, stash cross K/V in the cache the way engine prefill does.
    if "cross_k" in cache:
        tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)), jnp.int32)
        logits, cache = decode_step(params, cfg, cache, tokens)
        assert logits.shape[0] == 2
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
