"""Block-table-aware Pallas decode kernel: gather-free paged attention rows.

Serving keeps K/V in shared block pools (serve/paged.py); until this kernel
every decode tick *gathered* the lane's whole paged horizon into a transient
dense view — O(S*d) HBM traffic per token even when the decode math only
needed a handful of softmax rows. This module streams K/V **directly from
the pools**, block by block, guided by the lane's block table:

    paged_row_stats(q, k_pools, v_pool, table, kv_valid)
        -> (m, l, acc)  —  the online-softmax partial state of
           softmax(scale * q . K[0..kv_valid-1]) rows, where K/V are read
           through ``table`` from the pools.

That one primitive covers everything the gather used to feed:

* the ``decode_streaming="exact"`` *active-row recompute* — the single
  landmark row whose mean still drifts is recomputed over the horizon each
  tick (serve/decode_state.py); rows here are the per-kv-head group of
  active landmark means;
* the exact-attention decode path (``decode_attention_impl="full"``, and
  with it the degenerate <=c regime where spectral shifting reduces to
  exact attention) — a single query row per head, output ``acc / l``.

The caller flash-merges the *current* token's (k, v) into the returned
partials (``kernels.ops.flash_merge``): the pools hold keys ``0..pos-1``
when the kernel runs, because the paged tick commits the new token only
*after* the step (single-block scatter, ``PagedKVCache.make_paged_step``).

Block-table contract (scalar prefetch / SMEM)
---------------------------------------------
The block table and the per-lane valid-key bound ride into the kernel as
``PrefetchScalarGridSpec`` scalar-prefetch operands — small int32 arrays
placed in SMEM and available *before* the kernel body runs, so the K/V
BlockSpec index maps can dereference them:

    k block index for grid step (lane, head, slot) = table[lane, slot]

* ``table`` (lanes, n_slots) int32: pool-block ids in logical order. Slots
  past the lane's allocated range hold ``ZERO_BLOCK`` (= 0, the reserved
  all-zero block); they are *also* masked by ``kv_valid``, so the reserved
  block's contents are never load-bearing here.
* ``kv_valid`` (lanes,) int32: number of valid keys. Key j of slot i has
  global position ``i * block_size + j`` and enters the softmax iff it is
  ``< kv_valid[lane]`` — this one bound handles both the ragged last block
  and the ZERO_BLOCK tail.
* Rows with no valid key at all return the absorbing empty state
  ``(m=-inf, l=0, acc=0)``: ``flash_merge`` then re-anchors exactly at the
  first merged score, so even strongly negative token scores cannot
  underflow. Callers always merge at least the current token before using
  or storing the partials, so the -inf anchor never reaches cache leaves.

``q`` may carry the features of several key pools concatenated on the last
axis (``k_pools`` a tuple): scores are accumulated per pool without ever
concatenating pool storage — that is how absorbed-MLA decode (latent + rope
pools) runs gather-free.

vmap contract
-------------
The public ``paged_row_stats`` is single-lane and carries a
``jax.custom_batching.custom_vmap`` rule: under the serving engine's
per-lane ``vmap`` (pools broadcast with ``in_axes=None``) it lowers to ONE
multi-lane kernel launch with the lane axis as the leading grid dimension —
bypassing the generic Pallas batching rule, which would fall back to an
explicit per-lane loop for batched scalar-prefetch operands.

Kernels are validated on CPU in interpret mode; TPU is the compile target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# Kernel body: online softmax over table-selected pool blocks.
# --------------------------------------------------------------------------
def _paged_row_stats_kernel(
    tbl_ref,   # (lanes, n_slots) int32 SMEM (scalar prefetch)
    kvv_ref,   # (lanes,) int32 SMEM (scalar prefetch)
    *refs,
    scale: float,
    block_size: int,
    splits: tuple[int, ...],
):
    """Ref layout after the two scalar-prefetch operands:

        q (1, 1, r, d_tot), k_pool per split (1, 1, bs, d_p),
        v (1, 1, bs, dv),
        m_out (1, 1, r, 1), l_out (1, 1, r, 1), acc_out (1, 1, r, dv),
        m_scr (r, 1), l_scr (r, 1), acc_scr (r, dv)

    Grid (lanes, kv_heads, n_slots), slots innermost so the scratch
    accumulators persist across one lane-head's stream."""
    n_pools = len(splits)
    q_ref = refs[0]
    k_refs = refs[1:1 + n_pools]
    v_ref = refs[1 + n_pools]
    mo_ref, lo_ref, acco_ref, m_scr, l_scr, acc_scr = refs[2 + n_pools:]

    lane = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (r, d_tot)
    s = None
    off = 0
    for p, dp in enumerate(splits):
        k = k_refs[p][0, 0].astype(jnp.float32)            # (bs, d_p)
        part = jax.lax.dot_general(
            q[:, off:off + dp], k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (r, bs)
        s = part if s is None else s + part
        off += dp
    s = s * scale

    # Global key positions of this slot; one bound masks the ragged last
    # block and every ZERO_BLOCK tail slot alike.
    kv_pos = i * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_pos < kvv_ref[lane]
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                    # (r, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p_blk = jnp.exp(s - m_new)
    # A fully-masked block has m_new == s == -inf => exp(0) == 1; zero it.
    p_blk = jnp.where(mask, p_blk, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p_blk, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p_blk, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (r, dv)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        # Rows with zero valid keys keep the -inf anchor (m=NEG_INF, l=0,
        # acc=0): under flash_merge that anchor is ABSORBING, so merging
        # the current token re-anchors exactly at its score. A finite
        # anchor (e.g. the zeros state) would be kept by the merge's max
        # and can underflow exp(s - 0) for strongly negative scores —
        # callers always merge at least the current token before using or
        # storing these partials, so -inf never reaches the cache leaves.
        mo_ref[0, 0] = m_scr[...]
        lo_ref[0, 0] = l_scr[...]
        acco_ref[0, 0] = acc_scr[...]


def paged_row_stats_lanes(
    q: jnp.ndarray,           # (lanes, hkv, r, d_tot)
    k_pools,                  # tuple of (hkv, num_blocks, bs, d_p)
    v_pool: jnp.ndarray,      # (hkv, num_blocks, bs, dv)
    table: jnp.ndarray,       # (lanes, n_slots) int32
    kv_valid: jnp.ndarray,    # (lanes,) int32
    *,
    scale: float,
    block_size: int,
    interpret: bool = False,
):
    """Multi-lane kernel launch: grid (lanes, hkv, n_slots). Pools are
    shared (unbatched); each (lane, head) streams only the blocks its table
    names. Returns fp32 ``(m, l, acc)`` with shapes (lanes, hkv, r, 1) x2
    and (lanes, hkv, r, dv)."""
    k_pools = tuple(k_pools)
    lanes, hkv, r, d_tot = q.shape
    splits = tuple(int(p.shape[-1]) for p in k_pools)
    if sum(splits) != d_tot:
        raise ValueError(
            f"key-pool feature dims {splits} must sum to q's last dim {d_tot}"
        )
    dv = v_pool.shape[-1]
    n_slots = table.shape[1]
    bs = block_size

    q_idx = lambda l, h, i, tbl, kvv: (l, h, 0, 0)         # noqa: E731
    kv_idx = lambda l, h, i, tbl, kvv: (h, tbl[l, i], 0, 0)  # noqa: E731
    in_specs = [pl.BlockSpec((1, 1, r, d_tot), q_idx)]
    in_specs += [pl.BlockSpec((1, 1, bs, dp), kv_idx) for dp in splits]
    in_specs += [pl.BlockSpec((1, 1, bs, dv), kv_idx)]
    stat_spec = pl.BlockSpec((1, 1, r, 1), q_idx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(lanes, hkv, n_slots),
        in_specs=in_specs,
        out_specs=(stat_spec, stat_spec, pl.BlockSpec((1, 1, r, dv), q_idx)),
        scratch_shapes=[
            pltpu.VMEM((r, 1), jnp.float32),
            pltpu.VMEM((r, 1), jnp.float32),
            pltpu.VMEM((r, dv), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_row_stats_kernel, scale=scale, block_size=bs, splits=splits,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((lanes, hkv, r, 1), jnp.float32),
            jax.ShapeDtypeStruct((lanes, hkv, r, 1), jnp.float32),
            jax.ShapeDtypeStruct((lanes, hkv, r, dv), jnp.float32),
        ),
        interpret=interpret,
    )(
        jnp.asarray(table, jnp.int32),
        jnp.asarray(kv_valid, jnp.int32),
        q, *k_pools, v_pool,
    )


# --------------------------------------------------------------------------
# Single-lane entry point with a custom vmap rule (the decode step runs
# per lane under the engine's vmap; pools broadcast with in_axes=None).
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _lane_fn(n_pools: int, scale: float, block_size: int, interpret: bool):
    @jax.custom_batching.custom_vmap
    def fn(q, *rest):
        k_pools = rest[:n_pools]
        v_pool, table, kv_valid = rest[n_pools:]
        m, l, acc = paged_row_stats_lanes(
            q[None], k_pools, v_pool, table[None], kv_valid[None],
            scale=scale, block_size=block_size, interpret=interpret,
        )
        return m[0], l[0], acc[0]

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, q, *rest):
        qb, *rb = in_batched
        rb = list(rb)
        pools_b = rb[:n_pools] + [rb[n_pools]]
        tb, kvb = rb[n_pools + 1], rb[n_pools + 2]
        if any(pools_b):
            raise NotImplementedError(
                "paged_row_stats: K/V pools are shared storage and must be "
                "broadcast under vmap (in_axes=None), not lane-batched"
            )

        def bcast(x, batched):
            return x if batched else jnp.broadcast_to(
                x[None], (axis_size, *jnp.shape(x))
            )

        out = paged_row_stats_lanes(
            bcast(q, qb), rest[:n_pools], rest[n_pools],
            bcast(rest[n_pools + 1], tb), bcast(rest[n_pools + 2], kvb),
            scale=scale, block_size=block_size, interpret=interpret,
        )
        return out, (True, True, True)

    return fn


def paged_row_stats(
    q: jnp.ndarray,           # (hkv, r, d_tot)
    k_pools,                  # tuple of (hkv, num_blocks, bs, d_p)
    v_pool: jnp.ndarray,      # (hkv, num_blocks, bs, dv)
    table: jnp.ndarray,       # (n_slots,) int32
    kv_valid,                 # scalar int32 (may be traced)
    *,
    scale: float,
    block_size: int,
    interpret: bool = False,
):
    """Single-lane gather-free row stats (see module docstring). Returns
    fp32 ``(m, l, acc)`` of shapes (hkv, r, 1), (hkv, r, 1), (hkv, r, dv).

    vmap-ready: lane-batching ``q``/``table``/``kv_valid`` while pools ride
    in ``in_axes=None`` lowers to one multi-lane kernel launch."""
    fn = _lane_fn(len(tuple(k_pools)), float(scale), int(block_size),
                  bool(interpret))
    return fn(
        q, *tuple(k_pools), v_pool,
        jnp.asarray(table, jnp.int32),
        jnp.asarray(kv_valid, jnp.int32).reshape(()),
    )
