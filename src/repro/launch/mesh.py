"""Production mesh construction (assignment-mandated shapes).

Defined as functions so importing this module never touches jax device
state; only ``launch/dryrun.py`` forces the 512-device host platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1, axis_names=("data", "model")):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), axis_names)
