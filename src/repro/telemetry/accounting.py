"""XLA program accounting: recompile detection, cost models, numerics probes.

Three production failure modes that aggregate latency histograms cannot
see, each with its own detector here:

1. **Silent shape-bucket explosion.** Every distinct input shape a jitted
   function sees compiles a new XLA program; a bug in prefill bucketing or
   block-table padding turns a steady-state engine into a compile
   treadmill without changing any output. :class:`XLAAccounting.wrap`
   instruments a jitted callable: each call checks the jit cache size
   before/after and increments ``xla_compiles_total{program=}`` on a miss
   (plus ``xla_compile_seconds{program=}`` with the miss-call wall time).
   Steady-state decode must show this counter FLAT across ticks.

   A second, lower-level channel: :func:`install_compile_listener` hooks
   ``jax.monitoring``'s ``backend_compile`` duration event, attributing
   compiles to whichever :func:`tagged_program` region is active on the
   thread — this catches compiles inside code we don't wrap (autotune
   sweeps, library internals).

2. **Cost drift.** :func:`compiled_cost` pulls XLA's own
   ``cost_analysis()`` (flops / bytes accessed) for a lowered program, so
   bench_decode can cross-check its analytic bytes/token model against
   what the compiler actually scheduled (``xla_cost_bytes``).

3. **Numerical poisoning.** A single Inf in the landmark (m, l)
   online-softmax stats silently corrupts every later tick on that lane.
   :class:`NumericsProbe` counts non-finite values per probe site
   (``numerics_nonfinite_total{site=}``); the engine calls it every
   ``ServeConfig.numerics_probe_every`` ticks on logits and the (m, l)
   stream stats. Off (0) by default — the probe forces a device sync.

Like kernels/dispatch.py, this module routes through a module-level
registry holder so instrumentation is a no-op until telemetry is enabled.
"""
from __future__ import annotations

import contextlib
import functools
import threading
import time
from typing import Optional

import numpy as np

from repro.telemetry.metrics import NullRegistry

_METRICS = NullRegistry()
_LISTENER_INSTALLED = False
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_tls = threading.local()


def set_metrics(registry) -> None:
    """Point module-level accounting (the jax.monitoring listener) at a
    live registry. Pass ``None`` to restore the null registry."""
    global _METRICS
    _METRICS = registry if registry is not None else NullRegistry()


def current_program() -> str:
    """Name of the innermost active :func:`tagged_program` region."""
    stack = getattr(_tls, "programs", None)
    return stack[-1] if stack else "untagged"


@contextlib.contextmanager
def tagged_program(name: str):
    """Attribute any backend compile that fires inside this region to
    ``name`` (thread-local; regions nest, innermost wins)."""
    stack = getattr(_tls, "programs", None)
    if stack is None:
        stack = _tls.programs = []
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


def install_compile_listener() -> None:
    """Register the jax.monitoring backend-compile listener (idempotent —
    jax offers no unregister, so one process-wide hook routes through the
    module registry holder)."""
    global _LISTENER_INSTALLED
    if _LISTENER_INSTALLED:
        return
    try:
        from jax import monitoring
    except ImportError:  # pragma: no cover - jax always present here
        return

    def _on_duration(event: str, duration: float, **kw) -> None:
        if _COMPILE_EVENT not in event:
            return
        program = current_program()
        _METRICS.counter(
            "xla_backend_compiles_total",
            help="backend compiles observed via jax.monitoring",
            labels=("program",)).labels(program=program).inc()
        _METRICS.histogram(
            "xla_backend_compile_seconds",
            help="backend compile durations via jax.monitoring",
            buckets=(0.01, 0.1, 0.5, 1.0, 5.0, 30.0, 120.0),
        ).observe(duration)

    monitoring.register_event_duration_secs_listener(_on_duration)
    _LISTENER_INSTALLED = True


def _cache_size_fn(fn):
    """Resolve a jit-cache-size probe for ``fn``: jitted functions expose
    ``_cache_size`` directly; factory closures (serve/paged.py) expose the
    inner jitted function as ``fn._jitted``."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        inner = getattr(fn, "_jitted", None)
        probe = getattr(inner, "_cache_size", None)
    return probe


class XLAAccounting:
    """Per-program compile counters over wrapped jitted callables."""

    def __init__(self, registry):
        self._registry = registry
        self._compiles = registry.counter(
            "xla_compiles_total",
            help="jit cache misses per instrumented program",
            labels=("program",))
        self._calls = registry.counter(
            "xla_program_calls_total",
            help="calls per instrumented program",
            labels=("program",))
        self._compile_s = registry.histogram(
            "xla_compile_seconds",
            help="wall time of calls that triggered a compile",
            labels=("program",),
            buckets=(0.01, 0.1, 0.5, 1.0, 5.0, 30.0, 120.0))

    def wrap(self, fn, program: str):
        """Instrument a jitted callable (or a closure exposing
        ``_jitted``): count calls, detect cache-size growth as a compile,
        and tag the region so the backend-compile listener attributes
        correctly. Returns ``fn`` untouched when no cache probe exists."""
        probe = _cache_size_fn(fn)
        if probe is None:
            return fn
        calls = self._calls.labels(program=program)
        compiles = self._compiles.labels(program=program)
        compile_s = self._compile_s.labels(program=program)

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            calls.inc()
            before = probe()
            t0 = time.perf_counter()
            with tagged_program(program):
                out = fn(*args, **kwargs)
            if probe() > before:
                compiles.inc()
                compile_s.observe(time.perf_counter() - t0)
            return out

        wrapped._jitted = getattr(fn, "_jitted", fn)
        return wrapped

    def compiles(self, program: str) -> int:
        return int(self._compiles.labels(program=program).value)


def compiled_cost(fn, *args, **kwargs) -> dict:
    """XLA's own cost model for ``fn(*args, **kwargs)``:
    ``{"flops": float, "bytes": float}`` from ``cost_analysis()`` after
    lowering+compiling (AOT — does not execute). Returns zeros when the
    backend offers no analysis."""
    cost = fn.lower(*args, **kwargs).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return {"flops": 0.0, "bytes": 0.0}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


class NumericsProbe:
    """NaN/Inf counters per probe site. ``check`` pulls the array to host
    (device sync!) — gate call frequency at the call site."""

    def __init__(self, registry):
        self._nonfinite = registry.counter(
            "numerics_nonfinite_total",
            help="non-finite elements observed per probe site",
            labels=("site",))
        self._checks = registry.counter(
            "numerics_checks_total", help="numerics probe invocations")
        self.last_bad: Optional[str] = None

    def check(self, site: str, arr) -> int:
        """Count non-finite elements of ``arr`` under ``site``; returns
        the count and remembers the most recent offending site."""
        self._checks.inc()
        host = np.asarray(arr)
        if host.dtype.kind not in "fc":
            return 0
        bad = int(host.size - np.count_nonzero(np.isfinite(host)))
        if bad:
            self._nonfinite.labels(site=site).inc(bad)
            self.last_bad = site
        return bad


class NullNumericsProbe:
    """Disabled twin — never syncs, never counts."""

    last_bad = None

    def check(self, site: str, arr) -> int:
        return 0
