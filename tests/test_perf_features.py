"""Tests for the performance-loop features (EXPERIMENTS.md §Perf): they must
be mathematically identical to the baselines they replace."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core.attention import SSConfig, chunked_attention, full_attention, \
    spectral_shift_attention
from repro.core.landmarks import segment_means


class TestMatmulSegmentMeans:
    @pytest.mark.parametrize("n,m", [(256, 32), (250, 32), (64, 64), (512, 8)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_identical_to_reshape(self, n, m, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(0), (2, 3, n, 16))).astype(dtype)
        a = segment_means(x, m)
        b = segment_means(x, m, via_matmul=True)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-6 if dtype == jnp.float32 else 3e-2,
        )

    def test_ss_attention_same_output(self):
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 32)) * 0.5
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 32))
        a = spectral_shift_attention(q, q, v, SSConfig(num_landmarks=32))
        b = spectral_shift_attention(
            q, q, v, SSConfig(num_landmarks=32, landmark_via_matmul=True)
        )
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestUnrollScans:
    def test_chunked_attention_unrolled_identical(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (2, 200, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 200, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, 200, 16))
        a = chunked_attention(q, k, v, causal=True, block=64)
        b = chunked_attention(q, k, v, causal=True, block=64, unroll=True)
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_mlstm_unrolled_identical(self):
        from repro.models.ssm import mlstm_chunked

        key = jax.random.PRNGKey(0)
        B, H, S, D = 1, 2, 128, 8
        q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (B, H, S, D))
                   for i in range(3))
        ilog = jax.random.normal(jax.random.PRNGKey(3), (B, H, S)) * 0.1
        flog = jax.nn.log_sigmoid(
            jax.random.normal(jax.random.PRNGKey(4), (B, H, S)) + 2
        )
        a, _ = mlstm_chunked(q, k, v, ilog, flog, chunk=32)
        b, _ = mlstm_chunked(q, k, v, ilog, flog, chunk=32, unroll=True)
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_mamba_unrolled_identical(self):
        from repro.models.ssm import mamba_forward, mamba_specs
        from repro.models.params import init_params

        d, di, st = 16, 32, 8
        p = init_params(mamba_specs(d, di, st, 4, 8), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, d))
        a, _ = mamba_forward(p, x, st, chunk=32)
        b, _ = mamba_forward(p, x, st, chunk=32, unroll=True)
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestWorkingParams:
    def test_noop_when_dtypes_match(self):
        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.models.model import working_params

        cfg = reduced(get_config("qwen2-7b"))  # compute f32 == param f32
        tree = {"w": jnp.ones((2, 2), jnp.float32)}
        out = working_params(tree, cfg)
        assert out["w"].dtype == jnp.float32

    def test_casts_float_leaves_only(self):
        import dataclasses

        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.models.model import working_params

        cfg = dataclasses.replace(
            reduced(get_config("qwen2-7b")), compute_dtype="bfloat16"
        )
        tree = {"w": jnp.ones((2,), jnp.float32), "i": jnp.ones((2,), jnp.int32)}
        out = working_params(tree, cfg)
        assert out["w"].dtype == jnp.bfloat16
        assert out["i"].dtype == jnp.int32


@pytest.mark.slow
class TestEPMoE:
    def test_matches_gspmd_reference(self):
        run_subprocess("""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.distributed.sharding import sharding_rules
from repro.models.moe import moe_forward, moe_forward_ep, moe_specs
from repro.models.params import init_params

cfg = ModelConfig(moe=True, num_experts=8, top_k=2, moe_d_ff=32, d_model=16,
                  num_shared_experts=1, capacity_factor=100.0)
p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 12, 16)) * 0.5
mesh = jax.make_mesh((4, 2), ('data', 'model'))
ref, aux_ref = moe_forward(p, cfg, x)
with mesh, sharding_rules(mesh):
    ep, aux_ep = jax.jit(lambda p_, x_: moe_forward_ep(p_, cfg, x_))(p, x)
assert jnp.allclose(ref, ep, atol=2e-5), float(jnp.max(jnp.abs(ref - ep)))
assert abs(float(aux_ref) - float(aux_ep)) < 1e-5
g1 = jax.grad(lambda x_: jnp.sum(moe_forward(p, cfg, x_)[0] ** 2))(x)
with mesh, sharding_rules(mesh):
    g2 = jax.jit(jax.grad(
        lambda x_: jnp.sum(moe_forward_ep(p, cfg, x_)[0] ** 2)))(x)
assert jnp.allclose(g1, g2, atol=1e-4), float(jnp.max(jnp.abs(g1 - g2)))
print('OK')
""", num_devices=8)

    def test_capacity_drops_consistent(self):
        """With tight capacity both paths drop tokens; outputs stay finite
        and within the convex range of expert outputs."""
        run_subprocess("""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.distributed.sharding import sharding_rules
from repro.models.moe import moe_forward_ep, moe_specs
from repro.models.params import init_params

cfg = ModelConfig(moe=True, num_experts=8, top_k=2, moe_d_ff=32, d_model=16,
                  capacity_factor=0.5)
p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16))
mesh = jax.make_mesh((4, 2), ('data', 'model'))
with mesh, sharding_rules(mesh):
    out, aux = jax.jit(lambda p_, x_: moe_forward_ep(p_, cfg, x_))(p, x)
assert bool(jnp.all(jnp.isfinite(out)))
assert bool(jnp.isfinite(aux))
print('OK')
""", num_devices=8)


def test_ep_falls_back_without_mesh():
    from repro.configs.base import ModelConfig
    from repro.models.moe import moe_forward, moe_forward_ep, moe_specs
    from repro.models.params import init_params

    cfg = ModelConfig(moe=True, num_experts=4, top_k=2, moe_d_ff=16, d_model=8)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    a, _ = moe_forward(p, cfg, x)
    b, _ = moe_forward_ep(p, cfg, x)  # no mesh context -> fallback
    np.testing.assert_allclose(a, b, atol=1e-6)


class TestFusedModelPath:
    def test_fused_attention_impl_matches_jnp(self):
        """attention_impl='spectral_shift_fused' (Pallas kernels) == the jnp
        spectral_shift path on a bidirectional site (whisper encoder)."""
        import dataclasses

        from repro.configs.base import reduced
        from repro.configs.registry import get_config
        from repro.models.model import model_forward, model_specs
        from repro.models.params import init_params

        base = reduced(get_config("whisper-base"))
        params = init_params(model_specs(base), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(1, base.vocab_size, (2, 16)),
                                  jnp.int32),
            "frames": jnp.asarray(rng.normal(size=(2, 64, base.d_model)),
                                  jnp.float32),
        }
        outs = {}
        for impl in ("spectral_shift", "spectral_shift_fused"):
            cfg = dataclasses.replace(base, encoder_attention_impl=impl,
                                      num_landmarks=8)
            logits, _ = model_forward(params, cfg, batch)
            outs[impl] = np.asarray(logits, np.float32)
        # Online-softmax streaming reorders the fp32 accumulation; through
        # two encoder layers + decoder the noise floor is ~5e-4 on logits.
        np.testing.assert_allclose(
            outs["spectral_shift"], outs["spectral_shift_fused"],
            atol=1e-3, rtol=1e-3,
        )
