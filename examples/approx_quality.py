"""Approximation-quality explorer: error vs landmark count for the three
approximation models across matrix regimes (paper Fig 2 / Thm 1 hands-on).

    PYTHONPATH=src python examples/approx_quality.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import (
    SSConfig,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)
from repro.core.matrix_approx import (
    approximate_spsd,
    flat_tail_spsd,
    sample_columns,
)


def rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))


def main():
    print("=== Lemma-1 matrices (flat-tail SPSD, the paper's Thm-1 setting) ===")
    print("c     prototype   modified-SS(shifted)")
    K = flat_tail_spsd(256, 16, 0.5, seed=0)
    for c in (16, 32, 64):
        cols = sample_columns(256, c)
        e_p = rel(K, approximate_spsd(K, cols, "prototype"))
        e_s = rel(K, approximate_spsd(K, cols, "modified_ss_shifted",
                                      target_rank=16))
        print(f"{c:<5d} {e_p:<11.4f} {e_s:.2e}")

    print("\n=== softmax attention output, self-similar tokens (q == k) ===")
    print("c     nystrom     spectral-shift")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 1024, 48)) * 0.6
    v = jax.random.normal(jax.random.PRNGKey(1), (1, 1024, 48))
    exact = full_attention(x, x, v)
    for c in (32, 64, 128, 256):
        ny = nystrom_attention(x, x, v, num_landmarks=c)
        ss = spectral_shift_attention(
            x, x, v, SSConfig(num_landmarks=c, method="svd")
        )
        print(f"{c:<5d} {rel(exact, ny):<11.4f} {rel(exact, ss):.4f}")

    print("\n=== spectrum shape (cumulative eigenvalue mass, Fig 2) ===")
    n, c = 256, 32
    s = (x[0, :n, :] @ x[0, :n, :].T) / np.sqrt(48)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    attn = p / p.sum(-1, keepdims=True)
    cols = sample_columns(n, c)
    for name, m in [
        ("exact", attn),
        ("nystrom", approximate_spsd(attn, cols, "prototype")),
        ("spectral-shift", approximate_spsd(attn, cols, "modified_ss",
                                            target_rank=c // 2)),
    ]:
        sv = np.asarray(jnp.linalg.svd(m, compute_uv=False))
        cum = np.cumsum(sv) / sv.sum()
        marks = " ".join(f"{cum[i]:.2f}" for i in (7, 31, 63, 127, 255))
        print(f"{name:<15s} cum@[8,32,64,128,256] = {marks}")


if __name__ == "__main__":
    main()
