"""Model-level attention layers: GQA (with optional QKV bias) and MLA
(DeepSeek-V2 latent attention), wired to the paper's spectral-shifting
approximation through ``repro.core``.

Conventions
-----------
* hidden states: (B, S, D); per-head tensors: (B, H, S, Dh).
* ``mode``: "causal" (decoder train/prefill), "bidir" (encoder sites),
  "decode" (single step against a KV cache dict).
* GQA KV heads are broadcast to the query-head count before the core
  attention call; under TP the query heads are sharded over "model" and the
  broadcast stays local (no collective).
* Decode caches carry landmark *sums* so spectral-shift decode needs no
  O(n) landmark recomputation per token (counts are derived from ``pos``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import (
    SSConfig,
    chunked_attention,
    full_attention,
    spectral_shift_attention,
)
from repro.core.landmarks import segment_means
from repro.models.layers import apply_rotary, rotary_angles
from repro.models.params import ParamSpec


def ss_config_from(cfg: ModelConfig, causal: bool = False) -> SSConfig:
    return SSConfig(
        num_landmarks=cfg.num_landmarks,
        pinv_iters=cfg.pinv_iters,
        method=cfg.ss_method,
        include_shift_identity=cfg.include_shift_identity,
        causal=causal,
        landmark_via_matmul=cfg.landmark_via_matmul,
    )


def _core_attention(cfg: ModelConfig, impl: str, q, k, v, *, causal: bool):
    """q (B,H,S,Dh) vs k/v (B,H,S,Dh) -> (B,H,S,Dh)."""
    if impl == "full":
        return full_attention(q, k, v, causal=causal)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal,
                                 unroll=cfg.unroll_scans)
    if impl == "spectral_shift_fused":
        # Pallas-kernel-backed path, routed through the dispatch registry
        # (kernels/dispatch.py): plan = impl + block size per shape key,
        # resolved at trace time. Both the bidirectional and the
        # segment-causal variant run fused; grads flow through the
        # custom-VJP backward kernels. When the active sharding rules map
        # the sequence axis onto >1 devices, dispatch routes through the
        # shard_map context-parallel driver (kernels/sharded.py) — the key
        # carries seq_shards, so context-parallel cells keep the fused path.
        from repro.kernels.dispatch import dispatch_ss_attention

        return dispatch_ss_attention(
            q, k, v, ss_config_from(cfg, causal=causal),
            backend=cfg.attention_backend,
            autotune_enabled=cfg.autotune,
            interpret=cfg.kernels_interpret,
        )
    if impl in ("spectral_shift", "nystrom"):
        ss = ss_config_from(cfg, causal=causal)
        if impl == "nystrom":
            ss = SSConfig(
                num_landmarks=ss.num_landmarks, pinv_iters=ss.pinv_iters,
                method=ss.method, use_shift=False,
                include_shift_identity=False, causal=causal,
            )
        return spectral_shift_attention(q, k, v, ss)
    raise ValueError(f"unknown attention impl {impl!r}")


def _broadcast_kv(x: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """(B, Hkv, S, Dh) -> (B, H, S, Dh) by group broadcast."""
    b, hkv, s, d = x.shape
    if hkv == num_heads:
        return x
    g = num_heads // hkv
    x = jnp.broadcast_to(x[:, :, None], (b, hkv, g, s, d))
    return x.reshape(b, num_heads, s, d)


# ==========================================================================
# GQA attention
# ==========================================================================
def gqa_specs(cfg: ModelConfig) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    specs = {
        "w_q": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "w_k": ParamSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "w_v": ParamSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "w_o": ParamSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs.update(
            b_q=ParamSpec((h, dh), ("heads", "head_dim"), init="zeros"),
            b_k=ParamSpec((hkv, dh), ("kv_heads", "head_dim"), init="zeros"),
            b_v=ParamSpec((hkv, dh), ("kv_heads", "head_dim"), init="zeros"),
        )
    return specs


def gqa_project_qkv(p: dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    """x (B,S,D) -> q (B,H,S,Dh), k/v (B,Hkv,S,Dh), rotary applied."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bhse", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhe->bhse", x, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhe->bhse", x, p["w_v"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)[None, :, None, :]
        k = k + p["b_k"].astype(dt)[None, :, None, :]
        v = v + p["b_v"].astype(dt)[None, :, None, :]
    if cfg.rope_theta > 0:
        sin, cos = rotary_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
        sin, cos = sin[:, None], cos[:, None]  # (B,1,S,Dh/2)
        q, k = apply_rotary(q, sin, cos), apply_rotary(k, sin, cos)
    return q, k, v


def gqa_forward(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    impl: str,
    mode: str = "causal",
    cache: Optional[dict] = None,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence GQA attention; ``decode`` mode handled in serve/decode.py."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    k = _broadcast_kv(k, cfg.num_heads)
    v = _broadcast_kv(v, cfg.num_heads)
    out = _core_attention(cfg, impl, q, k, v, causal=(mode == "causal"))
    out = jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(x.dtype))
    return out, cache


def cross_attention_specs(cfg: ModelConfig) -> dict:
    return gqa_specs(cfg)


def cross_attention_forward(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    enc_out: jnp.ndarray,
    *,
    impl: str,
) -> jnp.ndarray:
    """Decoder-side cross attention over encoder output (no rotary, bidir)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bhse", x, p["w_q"].astype(dt))
    k = jnp.einsum("bsd,dhe->bhse", enc_out.astype(dt), p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhe->bhse", enc_out.astype(dt), p["w_v"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["b_q"].astype(dt)[None, :, None, :]
        k = k + p["b_k"].astype(dt)[None, :, None, :]
        v = v + p["b_v"].astype(dt)[None, :, None, :]
    k = _broadcast_kv(k, cfg.num_heads)
    v = _broadcast_kv(v, cfg.num_heads)
    if (impl in ("spectral_shift", "spectral_shift_fused", "nystrom")
            and x.shape[1] != enc_out.shape[1]):
        # Cross attention with n_q != n_k: landmark counts must match; take
        # both landmark sets from their own sequences. The rectangular score
        # matrix has no diagonal, so the + delta*I output term is disabled
        # (the decode-convention branch in spectral_shift_attention is for
        # suffix queries of the SAME sequence, not cross attention).
        import dataclasses as _dc

        ss = _dc.replace(ss_config_from(cfg), include_shift_identity=False)
        q_l = segment_means(q, ss.num_landmarks, via_matmul=ss.landmark_via_matmul)
        k_l = segment_means(k, ss.num_landmarks, via_matmul=ss.landmark_via_matmul)
        out = spectral_shift_attention(q, k, v, ss, q_landmarks=q_l, k_landmarks=k_l)
    else:
        out = _core_attention(cfg, impl, q, k, v, causal=False)
    return jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(dt))


# ==========================================================================
# MLA — Multi-head Latent Attention (DeepSeek-V2 family)
# ==========================================================================
def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    dh = cfg.resolved_head_dim          # nope dim per head (== value dim)
    dr = cfg.rope_head_dim
    r = cfg.kv_lora_rank
    return {
        "w_q_nope": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "w_q_rope": ParamSpec((d, h, dr), ("embed", "heads", "head_dim")),
        "w_dkv": ParamSpec((d, r), ("embed", "kv_lora")),
        "w_k_rope": ParamSpec((d, dr), ("embed", "head_dim")),
        "w_uk": ParamSpec((r, h, dh), ("kv_lora", "heads", "head_dim")),
        "w_uv": ParamSpec((r, h, dh), ("kv_lora", "heads", "head_dim")),
        "w_o": ParamSpec((h, dh, d), ("heads", "head_dim", "embed")),
        "norm_kv": ParamSpec((r,), ("kv_lora",), init="ones"),
    }


def mla_latents(p: dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    """x (B,S,D) -> latent c_kv (B,S,r) [RMS-normed], k_rope (B,1,S,dr)."""
    from repro.models.layers import rms_norm

    dt = x.dtype
    c_kv = rms_norm(x @ p["w_dkv"].astype(dt), p["norm_kv"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_k_rope"].astype(dt))[:, None]
    sin, cos = rotary_angles(positions, cfg.rope_head_dim, cfg.rope_theta)
    k_rope = apply_rotary(k_rope, sin[:, None], cos[:, None])
    return c_kv, k_rope


def mla_forward(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    impl: str,
    mode: str = "causal",
) -> jnp.ndarray:
    """Full-sequence MLA: materialize per-head K/V from the latent."""
    dt = x.dtype
    dh, dr = cfg.resolved_head_dim, cfg.rope_head_dim
    c_kv, k_rope = mla_latents(p, cfg, x, positions)

    q_nope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_nope"].astype(dt))
    q_rope = jnp.einsum("bsd,dhe->bhse", x, p["w_q_rope"].astype(dt))
    sin, cos = rotary_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rotary(q_rope, sin[:, None], cos[:, None])

    k_nope = jnp.einsum("bsr,rhe->bhse", c_kv, p["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhe->bhse", c_kv, p["w_uv"].astype(dt))

    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:1], h, *k_rope.shape[2:]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # Match the standard MLA scale: 1/sqrt(dh + dr).
    scale = (dh + dr) ** -0.5
    if impl == "full":
        out = full_attention(q, k, v, causal=(mode == "causal"), scale=scale)
    elif impl == "chunked":
        out = chunked_attention(q, k, v, causal=(mode == "causal"),
                                scale=scale, unroll=cfg.unroll_scans)
    else:
        ss = ss_config_from(cfg, causal=(mode == "causal"))
        out = spectral_shift_attention(q, k, v, ss, scale=scale)
    return jnp.einsum("bhse,hed->bsd", out, p["w_o"].astype(dt))
