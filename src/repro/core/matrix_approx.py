"""SPSD matrix approximation models from the paper's lineage.

Three models over the same sampled columns ``C = K[:, cols]`` and core
``A = K[cols][:, cols]``:

* ``prototype``  (Nystrom / Williams & Seeger 2001, paper §2.2):
      K ~= C A^+ C^T
* ``modified_ss`` (paper §4, K~ = K branch — the eq. (10) form):
      K ~= C U_ss C^T + d I,  U_ss = A^+ (I - d A^+), d fitted from the
      sampled core only (O(c^3), no access to the full matrix)
* ``modified_ss_shifted`` (paper §4, K~ = K - d I branch): the shifted
      columns are still column-only computable (C~ = C - d P, A~ = A - d I);
      exact under Lemma 1's flat-tail spectrum.

Used by the Theorem-1 accuracy benchmark, the Figure-2 spectrum benchmark
and the hypothesis property tests. Everything here is O(n^2) on purpose —
it operates on explicit matrices to *measure* approximation error; the
linear-time attention path lives in ``core/attention.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.pinv import svd_pinv
from repro.core.spectral_shift import ss_core


def sample_columns(n: int, c: int) -> jnp.ndarray:
    """Deterministic uniform (segment-stride) column indices, c of n."""
    stride = n // c
    return jnp.arange(c) * stride


def approximate_spsd(
    k_mat: jnp.ndarray,
    cols: jnp.ndarray,
    model: str = "modified_ss",
    *,
    target_rank: int | None = None,
    rank_tol: float = 1e-3,
) -> jnp.ndarray:
    """Approximate SPSD ``k_mat`` (n, n) from columns ``cols`` per ``model``."""
    n = k_mat.shape[-1]
    c = cols.shape[0]
    c_mat = k_mat[:, cols]              # C  (n, c)
    a_mat = c_mat[cols, :]              # A  (c, c)

    if model == "prototype":
        pinv, _, _ = svd_pinv(a_mat, rank_tol=rank_tol)
        return c_mat @ pinv @ c_mat.T

    if model == "modified_ss":
        core = ss_core(
            a_mat, method="svd", rank_tol=rank_tol, target_rank=target_rank
        )
        approx = c_mat @ core.u @ c_mat.T
        return approx + core.delta[..., 0, 0] * jnp.eye(n, dtype=approx.dtype)

    if model == "modified_ss_shifted":
        # The K~ = K - d I branch of paper §4. Crucially this still needs
        # ONLY the sampled columns: C~ = C - d P and A~ = A - d I_c, where
        # P[:, j] is the j-th selection column. Under a Lemma-1 spectrum
        # this reconstructs K exactly (tested).
        core = ss_core(
            a_mat, method="svd", rank_tol=rank_tol, target_rank=target_rank
        )
        delta = core.delta[..., 0, 0]
        sel = jnp.zeros((n, c), dtype=k_mat.dtype).at[cols, jnp.arange(c)].set(1.0)
        c_shift = c_mat - delta * sel
        a_shift = a_mat - delta * jnp.eye(c, dtype=k_mat.dtype)
        pinv, _, _ = svd_pinv(a_shift, rank_tol=rank_tol)
        return c_shift @ pinv @ c_shift.T + delta * jnp.eye(n, dtype=k_mat.dtype)

    raise ValueError(f"unknown approximation model: {model!r}")


def flat_tail_spsd(
    n: int, head_rank: int, theta: float, seed: int = 0, head_max: float = 8.0
) -> jnp.ndarray:
    """Synthesize the Lemma-1 spectrum: top-k head + exactly-flat tail theta."""
    import numpy as np

    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    lam = np.concatenate(
        [np.linspace(head_max, 1.0, head_rank), theta * np.ones(n - head_rank)]
    )
    return jnp.asarray((q * lam) @ q.T, dtype=jnp.float32)
