"""Chrome Trace Event Format export for Perfetto / chrome://tracing.

Takes one :class:`~repro.telemetry.Telemetry` bundle and renders its two
event stores onto a single timeline:

* the PR 6 host span buffer (``telemetry.tracer.events``) as nested
  duration events on a ``host`` process track — every engine tick's
  admit / prefill / decode_dispatch / device_sync / sample_emit spans;
* the flight recorder's per-request lifelines (``telemetry.flight``) as
  one thread track per request: an enveloping ``request`` slice from
  submit to finish, with ``queued`` / ``prefill`` / ``prefill_chunk`` /
  ``decode`` slices nested inside and instant markers for preempt /
  requeue / rebase / finish. In continuous-batching mode the interleaving
  is the diagnosis view: ``prefill_chunk`` runs on one request track
  overlap ``decode`` runs on the others, and a gap between chunk runs is
  a budget stall or a park;
* flight counter samples (pool occupancy, fragmentation, queue depth) as
  Perfetto counter tracks.

Both stores share one ``perf_counter`` origin, so host spans and request
lifelines line up: a long ``prefill`` host span visually stalls every
active request track — the continuous-batching diagnosis view.

Load the written JSON at https://ui.perfetto.dev (drag & drop) or
``chrome://tracing`` (Load button). Timestamps are microseconds.

For device-side (XLA) timelines, :func:`profile_session` wraps
``jax.profiler.trace`` so the same run also emits a TensorBoard/XProf
profile — link the two by wall clock.
"""
from __future__ import annotations

import contextlib
import json
from typing import Optional

_US = 1e6

# pid assignments: one "process" per data source.
PID_HOST = 0
PID_REQUESTS = 1
PID_COUNTERS = 2


def _dur_events(out, *, pid, tid, name, t0_us, t1_us, depth, args=None):
    b = {"ph": "B", "pid": pid, "tid": tid, "name": name,
         "ts": round(t0_us, 3), "_depth": depth}
    if args:
        b["args"] = args
    e = {"ph": "E", "pid": pid, "tid": tid, "name": name,
         "ts": round(max(t1_us, t0_us), 3), "_depth": depth}
    out.append(b)
    out.append(e)


def _instant(out, *, pid, tid, name, t_us, args=None):
    ev = {"ph": "i", "pid": pid, "tid": tid, "name": name,
          "ts": round(t_us, 3), "s": "t", "_depth": 0}
    if args:
        ev["args"] = args
    out.append(ev)


def _meta(out, *, pid, name, tid=None, value=""):
    ev = {"ph": "M", "pid": pid, "name": name, "ts": 0,
          "args": {"name": value}}
    if tid is not None:
        ev["tid"] = tid
    out.append(ev)


def _host_events(tracer, out) -> None:
    for ev in tracer.events:
        t0 = ev["t"] * _US
        _dur_events(
            out, pid=PID_HOST, tid=0, name=ev["name"],
            t0_us=t0, t1_us=t0 + ev["dur_s"] * _US,
            depth=ev["depth"], args=ev.get("labels"))


def _lifeline_events(line, out) -> None:
    """One request's lifeline → an enveloping ``request`` slice with
    sequential ``queued``/``prefill``/``decode`` slices nested inside."""
    events = line.events
    if not events:
        return
    tid = line.uid
    t_first = events[0]["t"] * _US
    t_last = max(ev.get("t1", ev["t"]) for ev in events) * _US

    slices = []      # (name, t0_us, t1_us, args)
    instants = []    # (name, t_us, args)
    open_name: Optional[str] = None
    open_t0 = 0.0
    open_args: Optional[dict] = None

    def close(t1_us, default_args=None):
        nonlocal open_name, open_args
        if open_name is not None:
            slices.append((open_name, open_t0,
                           max(t1_us, open_t0), open_args or default_args))
            open_name = None
            open_args = None

    for ev in events:
        t = ev["t"] * _US
        kind = ev["kind"]
        if kind == "submit":
            open_name, open_t0 = "queued", t
            open_args = {"prompt_len": ev.get("prompt_len")}
        elif kind == "admit":
            close(t)
            instants.append(("admit", t, {"lane": ev.get("lane")}))
        elif kind == "prefill_start":
            close(t)
            open_name, open_t0 = "prefill", t
            open_args = {"bucket": ev.get("bucket")}
        elif kind == "prefill_end":
            close(t, {"bucket": ev.get("bucket")})
        elif kind == "prefill_chunk":
            close(t)
            t1 = ev.get("t1", ev["t"]) * _US
            slices.append(("prefill_chunk", t, max(t1, t),
                           {"tick0": ev.get("tick0"), "tick1": ev.get("tick1"),
                            "chunk0": ev.get("chunk0"),
                            "chunk1": ev.get("chunk1"),
                            "tok0": ev.get("tok0"), "tok1": ev.get("tok1"),
                            "lane": ev.get("lane"), "chunks": ev.get("n")}))
        elif kind == "decode":
            close(t)
            t1 = ev.get("t1", ev["t"]) * _US
            slices.append(("decode", t, max(t1, t),
                           {"tick0": ev.get("tick0"), "tick1": ev.get("tick1"),
                            "pos0": ev.get("pos0"), "pos1": ev.get("pos1"),
                            "ticks": ev.get("n")}))
        elif kind == "prefix_attach":
            # Prefix-cache hit at admission: the shared span never prefills,
            # so the lifeline shows an instant (full hit: first token comes
            # straight from cached logits; partial: chunked prefill resumes
            # at the attach boundary, its chunks render as usual).
            close(t)
            instants.append(("prefix_attach", t, {
                "lane": ev.get("lane"), "blocks": ev.get("blocks"),
                "tokens": ev.get("tokens"), "mode": ev.get("mode")}))
        elif kind == "cow":
            instants.append(("cow", t, {"src": ev.get("src"),
                                        "dst": ev.get("dst")}))
        elif kind == "preempt":
            close(t)
            instants.append(("preempt", t, {"lane": ev.get("lane"),
                                            "parked": ev.get("parked")}))
        elif kind == "park_drop":
            instants.append(("park_drop", t, None))
        elif kind == "requeue":
            close(t)
            open_name, open_t0, open_args = "queued", t, {"requeue": True}
        elif kind == "rebase":
            instants.append(("rebase", t, None))
        elif kind == "reject":
            # Bounded-queue backpressure: the uid never entered the engine.
            instants.append(("reject", t, {
                "queue_depth": ev.get("queue_depth"),
                "retry_after_ticks": ev.get("retry_after_ticks")}))
        elif kind in ("cancel", "deadline"):
            close(t)
            instants.append((kind, t, {"tick": ev.get("tick")}))
        elif kind == "quarantine":
            # Numerics guard: stats rebuilt in place from cached K/V.
            instants.append(("quarantine", t, {
                "lane": ev.get("lane"), "trips": ev.get("trips")}))
        elif kind == "demote":
            instants.append(("demote", t, {"trips": ev.get("trips")}))
        elif kind in ("chaos", "watchdog"):
            # Engine-scoped events (uid -1): chaos injections carry their
            # site, watchdog fires their escalation rung.
            instants.append((kind, t, {
                k: v for k, v in ev.items() if k not in ("kind", "t", "t1")}))
        elif kind == "finish":
            close(t)
            instants.append(
                ("finish", t, {"tokens": ev.get("tokens"),
                               "reason": ev.get("reason")}))
    close(t_last)  # clamp any still-open slice at the lifeline's end

    _dur_events(out, pid=PID_REQUESTS, tid=tid, name="request",
                t0_us=t_first, t1_us=t_last, depth=0,
                args={"uid": line.uid, "dropped_events": line.dropped})
    for name, t0, t1, args in slices:
        _dur_events(out, pid=PID_REQUESTS, tid=tid, name=name,
                    t0_us=t0, t1_us=min(t1, t_last), depth=1, args=args)
    for name, t, args in instants:
        _instant(out, pid=PID_REQUESTS, tid=tid, name=name, t_us=t, args=args)


def _counter_events(flight, out) -> None:
    for name, samples in flight.counters.items():
        for t, v in samples:
            out.append({"ph": "C", "pid": PID_COUNTERS, "tid": 0,
                        "name": name, "ts": round(t * _US, 3),
                        "args": {"value": v}, "_depth": 0})


def _sort_key(ev):
    # At equal ts: close deepest-first, then metadata/instants/counters,
    # then open shallowest-first — keeps every track's B/E stack balanced.
    ph = ev["ph"]
    depth = ev.get("_depth", 0)
    if ph == "E":
        return (ev["ts"], 0, -depth)
    if ph == "B":
        return (ev["ts"], 2, depth)
    return (ev["ts"], 1, 0)


def chrome_trace(telemetry, meta: Optional[dict] = None) -> dict:
    """Render a Telemetry bundle as a Chrome Trace Event Format dict."""
    out: list[dict] = []
    _meta(out, pid=PID_HOST, name="process_name", value="host (engine loop)")
    _meta(out, pid=PID_HOST, tid=0, name="thread_name", value="tick spans")

    _host_events(telemetry.tracer, out)

    lifelines = telemetry.flight.lifelines()
    if lifelines:
        _meta(out, pid=PID_REQUESTS, name="process_name", value="requests")
        for line in lifelines:
            _meta(out, pid=PID_REQUESTS, tid=line.uid, name="thread_name",
                  value=f"req {line.uid}")
            _lifeline_events(line, out)

    if telemetry.flight.counters:
        _meta(out, pid=PID_COUNTERS, name="process_name", value="counters")
        _counter_events(telemetry.flight, out)

    out.sort(key=_sort_key)
    for ev in out:
        ev.pop("_depth", None)

    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    trace["metadata"] = dict(meta or {})
    trace["metadata"].setdefault("trace_schema", "repro-chrome-trace-v1")
    return trace


def validate_trace(trace: dict) -> list[str]:
    """Structural checks a viewer needs: per-(pid, tid) track, B/E events
    balance as a stack and timestamps never go backwards. Returns a list
    of violations (empty == valid)."""
    errors: list[str] = []
    stacks: dict[tuple, list[str]] = {}
    last_ts: dict[tuple, float] = {}
    for i, ev in enumerate(trace.get("traceEvents", [])):
        ph = ev.get("ph")
        if ph == "M":
            continue
        key = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event {i}: non-numeric ts {ts!r}")
            continue
        if ts < last_ts.get(key, float("-inf")):
            errors.append(
                f"event {i}: ts {ts} < previous {last_ts[key]} on track {key}")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                errors.append(f"event {i}: E without open B on track {key}")
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            errors.append(f"track {key}: {len(stack)} unclosed B events")
    return errors


def write_chrome_trace(path, telemetry, meta: Optional[dict] = None) -> int:
    """Write the trace JSON to ``path``; returns the event count. Merges
    the telemetry bundle's ``meta_defaults`` (provenance) into metadata."""
    defaults = dict(getattr(telemetry, "meta_defaults", {}) or {})
    defaults.update(meta or {})
    trace = chrome_trace(telemetry, meta=defaults)
    with open(path, "w") as fh:
        json.dump(trace, fh)
    return len(trace["traceEvents"])


@contextlib.contextmanager
def profile_session(logdir: str):
    """Optional device-side profile alongside the host trace: wraps
    ``jax.profiler.trace`` so XLA/device timelines land in ``logdir``
    (view with TensorBoard or xprof)."""
    import jax

    with jax.profiler.trace(logdir):
        yield logdir
