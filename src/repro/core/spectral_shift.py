"""Modified Spectral Shifting core (paper §4).

Given the landmark core ``A_s = L(Q~ K~^T / sqrt(d))`` (c x c), computes the
closed-form solution of paper eq. (3):

    delta_ss = ( tr(A_s) - tr(A_s^+ A_s^2) ) / ( c - rank(A_s) )
    U_ss     = A_s^+ - delta_ss (A_s^2)^+  =  A_s^+ (I - delta_ss A_s^+)

Two numerical paths (DESIGN.md §2.3):

* ``method="svd"`` — exact truncated pinv; rank = #(sigma > rank_tol*sigma_max),
  delta = mean of the *discarded* tail spectrum. This is Wang et al. (2016)'s
  truncated SS model and the CPU oracle.
* ``method="iterative"`` — paper eq. (11) pinv with finite iterations; the
  under-inverted tail acts as a soft truncation. Soft rank = tr(A Z*), the
  delta numerator/denominator are trace expressions of Z*. TPU fast path.

For a Lemma-1 spectrum (top-k + flat tail at theta) both paths give
delta -> theta, recovering the paper's exact-reconstruction regime.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.pinv import iterative_pinv, svd_pinv


class SSCore(NamedTuple):
    """Spectral-shift factors: ``S ~= F @ u @ B + delta * I_n``."""

    u: jnp.ndarray      # (..., c, c)  U_ss = Z (I - delta Z)
    delta: jnp.ndarray  # (..., 1, 1)  spectral shift
    z: jnp.ndarray      # (..., c, c)  the pseudoinverse estimate Z*


def _trace(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...ii->...", x)


def ss_core(
    a_s: jnp.ndarray,
    *,
    method: str = "iterative",
    pinv_iters: int = 6,
    rank_tol: float = 1e-3,
    target_rank: int | None = None,
    use_shift: bool = True,
) -> SSCore:
    """Compute ``(U_ss, delta_ss)`` from the landmark core ``a_s`` (..., c, c).

    ``use_shift=False`` forces delta=0, which makes the SS model degenerate to
    the Nystrom prototype model exactly (useful for ablations/Theorem-1
    benchmarks).
    """
    c = a_s.shape[-1]
    dtype = jnp.promote_types(a_s.dtype, jnp.float32)
    a32 = a_s.astype(dtype)

    if method == "svd":
        if target_rank is not None:
            # Lemma-1 regime: keep exactly the top ``target_rank`` spectrum,
            # delta = mean of the flat tail.
            u_svd, s, vt = jnp.linalg.svd(a32, full_matrices=False)
            keep = jnp.arange(c) < target_rank
            s_inv = jnp.where(keep, 1.0 / jnp.where(s > 1e-30, s, 1.0), 0.0)
            z = jnp.einsum("...ji,...j,...kj->...ik", vt, s_inv, u_svd)
        else:
            z, keep, s = svd_pinv(a32, rank_tol=rank_tol)
        z = z.astype(dtype)
        rank = jnp.sum(keep, axis=-1).astype(dtype)
        # tr(A) - tr(A^+ A^2) = sum of discarded singular values (SPSD view).
        tail = jnp.sum(jnp.where(keep, 0.0, s), axis=-1)
        denom = jnp.maximum(c - rank, 1.0)
        delta = tail / denom
    elif method == "iterative":
        z = iterative_pinv(a32, num_iters=pinv_iters).astype(dtype)
        az = jnp.matmul(a32, z)
        soft_rank = _trace(az)
        # tr(A^+ A^2) = tr(Z A A); numerator is the un-captured spectrum mass.
        tail = _trace(a32) - _trace(jnp.matmul(az, a32))
        denom = jnp.maximum(c - soft_rank, 1e-2)
        delta = jnp.maximum(tail, 0.0) / denom
    else:
        raise ValueError(f"unknown ss_core method: {method!r}")

    if not use_shift:
        delta = jnp.zeros_like(delta)
    delta = delta[..., None, None]
    u = jnp.matmul(z, jnp.eye(c, dtype=dtype) - delta * z)
    return SSCore(u=u.astype(a_s.dtype), delta=delta.astype(a_s.dtype), z=z.astype(a_s.dtype))
