"""Benchmark harness entry point: one module per paper table/figure plus the
roofline table. Prints ``name,case,metric,value`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [SUITE ...] [--smoke]

Every bench that keeps a machine-readable trajectory routes its artifact
through :func:`write_bench`, so all of them share one envelope::

    BENCH_<name>.json = {bench, schema, shape?, host, provenance, cells}

``provenance`` (git SHA, jax version) makes artifacts correlatable across
commits; ``benchmarks/regress.py`` diffs the working-tree envelopes against
the ones committed at HEAD and fails on regressions beyond per-metric
tolerance bands. ``--smoke`` selects each suite's reduced cell grid (the
same cells CI's perf-regress job runs), equivalent to REPRO_BENCH_SMOKE=1.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import (
    bench_accuracy,
    bench_complexity,
    bench_decode,
    bench_drift,
    bench_error_bound,
    bench_serve,
    bench_sharded_attn,
    bench_spectrum,
    bench_train_step,
    roofline,
)

SUITES = {
    "complexity": bench_complexity.run,      # paper Table 1
    "spectrum": bench_spectrum.run,          # paper Figure 2
    "accuracy": bench_accuracy.run,          # paper Theorem 1
    "error_bound": bench_error_bound.run,    # paper §7 eq. (12)
    "roofline": roofline.run,                # EXPERIMENTS.md §Roofline
    "serve": bench_serve.run,                # paged vs dense serving TTFT
    "decode": bench_decode.run,              # streaming/gather/paged decode
                                             # (also writes BENCH_decode.json)
    "drift": bench_drift.run,                # frozen-mode drift decomposition
    "train_step": bench_train_step.run,      # fused vs jnp fwd+bwd
    "sharded_attn": bench_sharded_attn.run,  # context-parallel fused vs jnp
}

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def write_bench(
    name: str,
    *,
    schema: str,
    cells,
    shape: dict | None = None,
    extra: dict | None = None,
    results_copy: str | None = None,
) -> str:
    """The one writer every bench's JSON artifact goes through.

    Emits ``BENCH_<name>.json`` at the repo top level with the shared
    envelope (``bench``/``schema``/``shape``/``host``/``provenance``/
    ``cells``) that ``benchmarks/regress.py`` understands, and optionally a
    byte-identical ``results/<results_copy>`` back-compat copy (for benches
    that historically wrote under ``results/``). ``cells`` is normally a
    ``{cell_name: {metric: value}}`` dict (sorted for stable diffs); list
    cells (remat_study) pass through untouched but are invisible to the
    regression gate. Returns the top-level path."""
    import jax

    from repro.telemetry.provenance import provenance

    payload: dict = {"bench": name, "schema": schema}
    if shape is not None:
        payload["shape"] = shape
    if extra:
        payload.update(extra)
    payload["host"] = jax.default_backend()
    payload["provenance"] = provenance()
    payload["cells"] = (
        dict(sorted(cells.items())) if isinstance(cells, dict) else cells
    )
    blob = json.dumps(payload, indent=2) + "\n"
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        f.write(blob)
    if results_copy:
        rp = os.path.join(REPO_ROOT, "results", results_copy)
        os.makedirs(os.path.dirname(rp), exist_ok=True)
        with open(rp, "w") as f:
            f.write(blob)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("suites", nargs="*", metavar="SUITE",
                    help=f"suites to run (default: all of {list(SUITES)})")
    ap.add_argument("--only", default=None, choices=list(SUITES),
                    help="legacy spelling of a single positional suite")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced cell grids (same as REPRO_BENCH_SMOKE=1)")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    selected = set(args.suites)
    if args.only:
        selected.add(args.only)
    unknown = selected - set(SUITES)
    if unknown:
        ap.error(f"unknown suite(s) {sorted(unknown)}; pick from {list(SUITES)}")

    rows: list[str] = []
    failures = 0
    for name, fn in SUITES.items():
        if selected and name not in selected:
            continue
        t0 = time.time()
        try:
            fn(rows)
            rows.append(f"suite,{name},elapsed_s,{time.time() - t0:.1f}")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            rows.append(f"suite,{name},ERROR,{type(e).__name__}: {e}")
    print("name,case,metric,value")
    print("\n".join(rows))
    if failures:
        print(f"# {failures} suite(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
