"""Frozen-mode streaming drift, measured against the paper's error
decomposition (bench_accuracy-style, not greedy spot checks).

``decode_streaming="frozen"`` scores each appended key with the landmark
mean *current at append time*; the active segment's mean keeps drifting
until the segment closes, when the engine's lazy rebase recomputes the two
boundary rows exactly. The approximation error of a frozen decode output
therefore decomposes into

    || out_frozen - out_full ||
       <=  || out_frozen - out_exact ||   (B-side staleness: THIS bench)
         + || out_exact  - out_full  ||   (the spectral-shift method error
                                           the paper bounds — Nystrom term
                                           + shift term)

and the claim worth pinning is that the staleness term is a small fraction
of the method term (drift_to_method_err << 1), bounded within one segment
and cleared at every rebase.

Cells simulate the engine's exact per-token protocol with the
serve/decode_state.py primitives (stream_append with means-at-append-time,
two-row ``rebase_rows`` at each segment boundary) over synthetic
trajectories in two token regimes — ``gaussian`` (independent tokens) and
``self_similar`` (K = Q, the diagonally-dominant regime attention actually
exhibits, bench_accuracy cell (b)) — and report, per horizon:

    bv_drift_pre_boundary  max relative BV-row drift at the last token of
                           a segment (maximum staleness, worst case);
    bv_drift_post_rebase   the same right after the boundary rebase (only
                           the still-active row may keep residual drift);
    out_drift_final        relative output error frozen-vs-exact at the
                           final position;
    method_err_final       relative output error exact-vs-full attention
                           (the paper's approximation error);
    drift_to_method_err    the decomposition ratio (<< 1 = drift is
                           negligible against the method's own error).

Numbers are committed under BENCH_drift.json (top level, shared envelope
via benchmarks/run.py's write_bench; a results/bench_drift.json copy keeps
the pre-PR7 location alive for existing readers).

    PYTHONPATH=src python -m benchmarks.run --only drift
    REPRO_BENCH_SMOKE=1 ... (one tiny horizon for CI)
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spectral_shift import ss_core
from repro.serve.decode_state import (
    landmark_counts,
    landmark_means,
    masked_softmax,
    rebase_rows,
    recompute_stats,
    segment_len,
    stream_append,
)

B, H, D, C = 1, 2, 32, 16

_cells: dict[str, dict] = {}


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def _tokens(regime: str, s: int, seed: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, s, D)) * 0.5
    if regime == "self_similar":
        k = q
    else:
        k = jax.random.normal(ks[1], (B, H, s, D)) * 0.5
    v = jax.random.normal(ks[2], (B, H, s, D))
    return q, k, v


@functools.partial(jax.jit, static_argnames=("s_max",))
def _frozen_trajectory(q, k, v, s_max: int):
    """Run the engine's frozen-mode protocol token by token: flash-append
    with the landmark means current at append time, two-row rebase at each
    segment boundary. Returns per-step stacked (q_sums, m, l, acc)."""
    seg = segment_len(s_max, C)
    scale = D ** -0.5
    zero_stats = (
        jnp.zeros((B, H, C, 1)), jnp.zeros((B, H, C, 1)),
        jnp.zeros((B, H, C, D)),
    )

    def body(carry, t):
        stats, q_sums = carry
        onehot = jax.nn.one_hot(t // seg, C, dtype=jnp.float32)
        q_sums = q_sums + onehot[:, None] * q[:, :, t][:, :, None, :]
        counts = landmark_counts(t, s_max, C)
        q_l = landmark_means(q_sums, counts)
        active = t // seg
        stats = stream_append(
            stats, q_l, k[:, :, t], v[:, :, t], scale,
            row_mask=jnp.arange(C) <= active,
        )
        stats = jax.lax.cond(
            jnp.logical_and(t > 0, t % seg == 0),
            lambda st: rebase_rows(
                st, q_l, k, v, t, scale,
                jnp.stack([jnp.maximum(active - 1, 0), active]),
            ),
            lambda st: tuple(x.astype(jnp.float32) for x in st),
            stats,
        )
        return (stats, q_sums), (q_sums, *stats)

    init = (zero_stats, jnp.zeros((B, H, C, D)))
    _, ys = jax.lax.scan(init=init, f=body, xs=jnp.arange(s_max))
    return ys  # each (S, B, H, C, ...)


def _bv(l, acc):
    return acc / jnp.maximum(l, 1e-30)


def _rel(a, b):
    return float(
        jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b), 1e-30)
    )


def _drift_at(q_sums_t, stats_t, k, v, t, s_max):
    """Max relative BV-row drift of the frozen stats vs the exact one-shot
    recompute with the same (time-t) landmark means, over reached rows."""
    scale = D ** -0.5
    counts = landmark_counts(jnp.asarray(t), s_max, C)
    q_l = landmark_means(q_sums_t, counts)
    m_r, l_r, acc_r = recompute_stats(q_l, k, v, t, scale,
                                      row_valid=counts > 0)
    reached = int(t // segment_len(s_max, C)) + 1
    bv_f = _bv(stats_t[1], stats_t[2])[..., :reached, :]
    bv_e = _bv(l_r, acc_r)[..., :reached, :]
    per_row = jnp.linalg.norm(bv_f - bv_e, axis=-1) / jnp.maximum(
        jnp.linalg.norm(bv_e, axis=-1), 1e-30
    )
    return float(jnp.max(per_row))


def _decode_out(q_vec, q_sums_t, bv, k_l_sums, counts, scale):
    """The spectral-shift decode output formula from a given BV table."""
    valid = counts > 0
    q_l = landmark_means(q_sums_t, counts)
    k_l = landmark_means(k_l_sums, counts)
    f = masked_softmax(
        jnp.einsum("bhd,bhcd->bhc", q_vec, k_l)[:, :, None, :] * scale,
        valid[None, None, None, :],
    )
    a_mask = valid[None, None, :, None] & valid[None, None, None, :]
    a_raw = masked_softmax(
        jnp.einsum("bhcd,bhed->bhce", q_l, k_l) * scale, a_mask
    )
    a = jnp.where(a_mask, a_raw, jnp.eye(C, dtype=jnp.float32))
    core = ss_core(a, method="iterative", pinv_iters=6, use_shift=True)
    out = jnp.einsum(
        "bhqc,bhcd->bhqd", f, jnp.einsum("bhce,bhed->bhcd", core.u, bv)
    )
    return out, core


def _cell(rows, regime: str, s_max: int) -> None:
    q, k, v = _tokens(regime, s_max, seed=7)
    seg = segment_len(s_max, C)
    scale = D ** -0.5
    ys = _frozen_trajectory(q, k, v, s_max)
    q_sums_all, m_all, l_all, acc_all = ys

    def stats_at(t):
        return (m_all[t], l_all[t], acc_all[t])

    # Worst-case staleness: the last token of each closed segment, right
    # before its rebase; post-rebase: the boundary token itself.
    pre = [t * seg - 1 for t in range(2, C) if t * seg - 1 < s_max]
    post = [t * seg for t in range(2, C) if t * seg < s_max]
    drift_pre = max(
        _drift_at(q_sums_all[t], stats_at(t), k, v, t, s_max) for t in pre
    )
    drift_post = max(
        _drift_at(q_sums_all[t], stats_at(t), k, v, t, s_max) for t in post
    )

    # Final-position outputs: frozen vs exact vs full attention.
    t = s_max - 1
    counts = landmark_counts(jnp.asarray(t), s_max, C)
    k_l_sums = jnp.einsum(
        "cs,bhsd->bhcd",
        jax.nn.one_hot(jnp.arange(s_max) // seg, C, dtype=jnp.float32).T,
        k,
    )
    q_vec = q[:, :, t]
    bv_frozen = _bv(l_all[t], acc_all[t])
    m_r, l_r, acc_r = recompute_stats(
        landmark_means(q_sums_all[t], counts), k, v, t, scale,
        row_valid=counts > 0,
    )
    out_f, core = _decode_out(q_vec, q_sums_all[t], bv_frozen, k_l_sums,
                              counts, scale)
    out_e, _ = _decode_out(q_vec, q_sums_all[t], _bv(l_r, acc_r), k_l_sums,
                           counts, scale)
    shift = core.delta * v[:, :, t][:, :, None, :]
    out_f = out_f + shift
    out_e = out_e + shift
    p = masked_softmax(
        jnp.einsum("bhd,bhsd->bhs", q_vec, k)[:, :, None, :] * scale,
        (jnp.arange(s_max) <= t)[None, None, None, :],
    )
    out_full = jnp.einsum("bhqs,bhsd->bhqd", p, v)

    out_drift = _rel(out_f, out_e)
    method_err = _rel(out_e, out_full)
    case = f"{regime}_S{s_max}_c{C}"
    metrics = {
        "bv_drift_pre_boundary": drift_pre,
        "bv_drift_post_rebase": drift_post,
        "out_drift_final": out_drift,
        "method_err_final": method_err,
        "drift_to_method_err": out_drift / max(method_err, 1e-30),
    }
    for name, val in metrics.items():
        rows.append(f"drift,{case},{name},{val:.5f}")
    _cells[case] = {kk: round(vv, 6) for kk, vv in metrics.items()}


def write_json() -> None:
    from benchmarks.run import write_bench  # lazy: avoids an import cycle

    write_bench(
        "drift",
        schema="regime_S{horizon}_c{landmarks} -> frozen-mode error "
               "decomposition (serve/decode_state.py protocol)",
        shape={"B": B, "H": H, "D": D, "C": C},
        cells=_cells,
        results_copy="bench_drift.json",  # pre-PR7 location, kept for readers
    )


def run(rows: list[str]) -> None:
    _cells.clear()
    horizons = (256,) if _smoke() else (256, 1024, 4096)
    for regime in ("gaussian", "self_similar"):
        for s in horizons:
            _cell(rows, regime, s)
    write_json()


if __name__ == "__main__":
    out: list[str] = []
    run(out)
    print("name,case,metric,value")
    print("\n".join(out))
