"""Per-request flight recorder: one bounded lifeline per request.

The PR 6 telemetry layer only aggregates (histograms, counters, span
buffers) — it can tell you the ITL p99 regressed but not *which request's
life* produced the tail. The flight recorder keeps the missing view: a
small, bounded record of every lifecycle event of each request —

    submit          entered the waiting queue (prompt length)
    admit           got a lane (+ lane index, queue ticks)
    prefill_start / prefill_end
                    batched prefill with its padding bucket — the shape
                    that decides which XLA program ran
    prefill_chunk   one continuous-batching prompt chunk (chunk index +
                    token range). Chunks of consecutive ticks AND
                    consecutive chunk indices coalesce into one run
                    ({tick0..tick1, chunk0..chunk1, tok0..tok1}) exactly
                    like decode runs — a run break marks a budget stall,
                    a park, or a decode-tick gap
    decode          per-tick decode membership. Consecutive ticks coalesce
                    into one run ({tick0..tick1, pos0..pos1}) at record
                    time, so steady decode costs O(1) memory per request
                    and a scheduling gap (skipped tick) is visible as a
                    run break
    preempt / requeue
                    victim eviction and head-of-queue requeue
    rebase          frozen-mode boundary rebase touched this lane
    prefix_attach   admission attached a cached prefix (shared block and
                    token counts + "full"/"partial" mode) — the shared
                    span never prefills, so no prefill slice precedes it
    cow             copy-on-write broke the sharing of one block before a
                    divergent decode write (src/dst block ids)
    finish          retirement (+ generated token count)

Bounds make it safe to leave on in production:

* at most ``max_requests`` lifelines are retained; a new request beyond
  that evicts the oldest lifeline FIFO (O(1), counted in
  ``flight_requests_evicted_total``);
* each lifeline holds at most ``max_events`` events; extra events are
  dropped and counted (``flight_events_dropped_total``), never grown;
* counter track samples (queue depth, pool occupancy/fragmentation —
  sampled once per engine tick for the trace viewer's counter tracks)
  live in fixed-size deques.

Timestamps share the owning :class:`~repro.telemetry.tracing.Tracer`'s
``perf_counter`` origin so lifelines and host spans line up on one
timeline in the Perfetto export (telemetry/export.py).
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Optional


class Lifeline:
    """One request's recorded life: an append-only, bounded event list."""

    __slots__ = ("uid", "events", "dropped")

    def __init__(self, uid: int):
        self.uid = uid
        self.events: list[dict] = []
        self.dropped = 0

    def kinds(self) -> list[str]:
        return [e["kind"] for e in self.events]


class FlightRecorder:
    def __init__(
        self,
        *,
        max_requests: int = 512,
        max_events: int = 256,
        max_counter_samples: int = 8192,
        registry=None,
        origin: Optional[float] = None,
    ):
        self.max_requests = max_requests
        self.max_events = max_events
        self._origin = time.perf_counter() if origin is None else origin
        self._req: OrderedDict[int, Lifeline] = OrderedDict()
        self.counters: dict[str, deque] = {}
        self._counter_maxlen = max_counter_samples
        if registry is not None:
            self._evicted = registry.counter(
                "flight_requests_evicted_total",
                help="lifelines evicted FIFO when max_requests was hit")
            self._dropped = registry.counter(
                "flight_events_dropped_total",
                help="lifeline events dropped at the per-request cap")
            self._events_total = registry.counter(
                "flight_events_total", help="lifeline events recorded")
        else:
            from repro.telemetry.metrics import _NULL_METRIC

            self._evicted = self._dropped = self._events_total = _NULL_METRIC

    # -- recording -------------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._origin

    def _line(self, uid: int) -> Lifeline:
        line = self._req.get(uid)
        if line is None:
            if len(self._req) >= self.max_requests:
                self._req.popitem(last=False)  # FIFO ring: oldest lifeline out
                self._evicted.inc()
            line = self._req[uid] = Lifeline(uid)
        return line

    def record(self, uid: int, kind: str, **data) -> None:
        """Append one lifecycle event. ``decode`` events with a ``tick``
        that extends the previous decode run coalesce in place (O(1))."""
        line = self._line(uid)
        t = self._now()
        if kind == "decode" and line.events:
            last = line.events[-1]
            if (last["kind"] == "decode"
                    and last.get("tick1") == data.get("tick", -2) - 1):
                last["tick1"] = data["tick"]
                last["pos1"] = data.get("pos", last.get("pos1"))
                last["t1"] = t
                last["n"] = last.get("n", 1) + 1
                self._events_total.inc()
                return
        if kind == "prefill_chunk" and line.events:
            last = line.events[-1]
            if (last["kind"] == "prefill_chunk"
                    and last.get("tick1") == data.get("tick", -2) - 1
                    and last.get("chunk1") == data.get("chunk", -2) - 1):
                last["tick1"] = data["tick"]
                last["chunk1"] = data["chunk"]
                last["tok1"] = data.get("tok1", last.get("tok1"))
                last["t1"] = t
                last["n"] = last.get("n", 1) + 1
                self._events_total.inc()
                return
        if len(line.events) >= self.max_events:
            line.dropped += 1
            self._dropped.inc()
            return
        ev = {"t": round(t, 9), "kind": kind}
        if kind == "decode":
            ev.update(
                tick0=data.get("tick"), tick1=data.get("tick"),
                pos0=data.get("pos"), pos1=data.get("pos"),
                t1=round(t, 9), n=1,
            )
        elif kind == "prefill_chunk":
            ev.update(
                tick0=data.get("tick"), tick1=data.get("tick"),
                chunk0=data.get("chunk"), chunk1=data.get("chunk"),
                tok0=data.get("tok0"), tok1=data.get("tok1"),
                lane=data.get("lane"), t1=round(t, 9), n=1,
            )
        elif data:
            ev.update(data)
        line.events.append(ev)
        self._events_total.inc()

    def counter_sample(self, name: str, value: float) -> None:
        """One point of a counter track (pool occupancy, queue depth, ...);
        fixed-size deque, oldest samples roll off silently."""
        dq = self.counters.get(name)
        if dq is None:
            dq = self.counters[name] = deque(maxlen=self._counter_maxlen)
        dq.append((round(self._now(), 9), float(value)))

    # -- reading ---------------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return True

    def lifeline(self, uid: int) -> Optional[Lifeline]:
        return self._req.get(uid)

    def lifelines(self) -> list[Lifeline]:
        return list(self._req.values())

    def summary(self) -> dict:
        return {
            "requests": len(self._req),
            "events": int(self._events_total.value),
            "dropped_events": int(self._dropped.value),
            "evicted_requests": int(self._evicted.value),
        }

    def dump_jsonl(self, fh) -> int:
        """One ``{"kind": "flight", "uid": ..., "events": [...]}`` line per
        retained lifeline; returns lines written."""
        import json

        n = 0
        for line in self._req.values():
            fh.write(json.dumps({
                "kind": "flight", "uid": line.uid,
                "dropped": line.dropped, "events": line.events,
            }) + "\n")
            n += 1
        return n


class NullFlightRecorder:
    """Disabled twin: records nothing, retains nothing."""

    enabled = False
    counters: dict = {}

    def record(self, uid: int, kind: str, **data) -> None:
        pass

    def counter_sample(self, name: str, value: float) -> None:
        pass

    def lifeline(self, uid: int):
        return None

    def lifelines(self) -> list:
        return []

    def summary(self) -> dict:
        return {"requests": 0, "events": 0, "dropped_events": 0,
                "evicted_requests": 0}

    def dump_jsonl(self, fh) -> int:
        return 0
