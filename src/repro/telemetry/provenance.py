"""Provenance stamps for telemetry and benchmark artifacts.

Every artifact this repo emits — the telemetry JSONL meta line, the
top-level ``BENCH_*.json`` envelopes, the Perfetto trace metadata, the
``results/bench_trajectory.jsonl`` history — carries the same small stamp:

    {"git_sha": ..., "jax": ..., "config_hash": ...?}

so traces, benches and regression verdicts are correlatable across
commits without guessing which tree produced them. ``config_hash`` is a
stable content hash over the dataclass configs that shaped the run
(ModelConfig / ServeConfig / ...), so two runs at the same SHA but
different knobs don't silently share an identity.

Everything here degrades gracefully: outside a git checkout the SHA falls
back to ``$GITHUB_SHA`` and then ``"unknown"`` — provenance must never be
the reason an artifact fails to write.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import subprocess


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    """HEAD commit of the repo containing this file (cached per process).

    A hung/slow git (TimeoutExpired — named explicitly even though it is a
    SubprocessError subclass, since a timeout here once looked like it
    could kill a bench envelope write) degrades to ``$GITHUB_SHA`` and
    then ``"unknown"``, like every other failure mode."""
    here = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=here, capture_output=True,
            text=True, timeout=10,
        )
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.TimeoutExpired, subprocess.SubprocessError):
        pass
    return os.environ.get("GITHUB_SHA", "unknown")


def config_hash(*cfgs) -> str:
    """Stable 12-hex content hash over any number of dataclass configs
    (non-dataclasses hash their repr). Field order never matters."""
    blobs = []
    for cfg in cfgs:
        if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
            payload = dataclasses.asdict(cfg)
        else:
            payload = repr(cfg)
        blobs.append(json.dumps(payload, sort_keys=True, default=str))
    digest = hashlib.sha256("\x00".join(blobs).encode())
    return digest.hexdigest()[:12]


def provenance(*cfgs) -> dict:
    """The standard stamp. Pass the run's configs (ModelConfig,
    ServeConfig, ...) to include their joint ``config_hash``."""
    import jax

    out = {"git_sha": git_sha(), "jax": jax.__version__}
    if cfgs:
        out["config_hash"] = config_hash(*cfgs)
    return out
