"""Unified telemetry: metrics registry + tick tracing + drift monitors.

``Telemetry`` is the one object the engine/trainer/benchmarks hold. It
bundles a :class:`~repro.telemetry.metrics.MetricsRegistry` and a
:class:`~repro.telemetry.tracing.Tracer` and exposes the two export paths
the rest of the stack (and CI) consume:

* ``snapshot()`` — nested dict of every metric sample plus span-buffer
  counters; cheap, safe to call mid-run.
* ``dump_jsonl(path)`` — one self-describing JSONL file: a ``meta`` line,
  one ``metric`` line per (name, label-set), one ``span`` line per traced
  event. This is the artifact CI uploads and the offline-analysis input.

``Telemetry(enabled=False)`` (or :func:`null_telemetry`) swaps in the
no-op registry/tracer pair: every instrumentation site still *calls*
telemetry, but each call is a shared-object no-op, nothing is retained,
and dumps write nothing — the zero-overhead contract behind the
``ServeConfig.telemetry`` knob.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.telemetry.metrics import (  # noqa: F401  (re-exports)
    LATENCY_BUCKETS,
    RATIO_BUCKETS,
    TICK_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    exp_buckets,
)
from repro.telemetry.monitors import (  # noqa: F401
    DriftMonitor,
    SpectrumMonitor,
    bv_from_stats,
    bv_row_residual,
    spectrum_mass,
)
from repro.telemetry.tracing import NullTracer, Tracer  # noqa: F401


class Telemetry:
    """Bundle of one metrics registry + one tracer with JSONL export."""

    def __init__(
        self,
        enabled: bool = True,
        *,
        registry: Optional[MetricsRegistry] = None,
        annotate: bool = False,
        max_events: int = 200_000,
    ):
        self.enabled = enabled
        if enabled:
            self.metrics = registry if registry is not None else MetricsRegistry()
            self.tracer = Tracer(
                self.metrics, annotate=annotate, max_events=max_events
            )
        else:
            self.metrics = NullRegistry()
            self.tracer = NullTracer()

    def span(self, name: str, **labels):
        return self.tracer.span(name, **labels)

    def step_span(self, name: str, step: int):
        return self.tracer.step_span(name, step)

    def snapshot(self) -> dict:
        return {"metrics": self.metrics.snapshot(), "spans": self.tracer.summary()}

    def dump_jsonl(self, path, meta: Optional[dict] = None) -> int:
        """Write the full telemetry state as JSONL; returns lines written.
        Disabled telemetry writes nothing (and creates no file)."""
        if not self.enabled:
            return 0
        n = 0
        with open(path, "w") as fh:
            head = {"kind": "meta", "schema": "repro-telemetry-v1"}
            if meta:
                head.update(meta)
            fh.write(json.dumps(head) + "\n")
            n += 1
            for name, kind, labels, sample in self.metrics.iter_samples():
                row = {"kind": "metric", "name": name, "type": kind}
                if labels:
                    row["labels"] = labels
                row.update(sample)
                fh.write(json.dumps(row) + "\n")
                n += 1
            n += self.tracer.dump_jsonl(fh)
        return n


def null_telemetry() -> Telemetry:
    return Telemetry(enabled=False)
