"""Streaming decode state: per-landmark online-softmax stats in the KV cache.

The only n-sized object in spectral-shift decode is the landmark-to-key
matrix ``B = softmax(Q~ K^T)`` and its value summary ``BV``. The legacy
(``decode_streaming="recompute"``) path rebuilds both over the whole cache
horizon every token — O(c*S*d) per tick — which forfeits the paper's O(n)
total-cost claim exactly where it matters. This module makes the linear
term *streamed*: the cache carries, per landmark row r, the online-softmax
partial state

    bv_m   (B, H, c, 1)   row anchor m_r        (a valid, not necessarily
                                                 maximal, exp anchor)
    bv_l   (B, H, c, 1)   l_r   = sum_j exp(s_rj - m_r)
    bv_acc (B, H, c, dv)  acc_r = sum_j exp(s_rj - m_r) * v_j

so ``BV[r] = acc_r / l_r``. The zeros state (0, 0, 0) is a valid empty
partial (the anchor need not be the true max — any finite anchor yields the
same normalized result), which lets the leaves share the cache's zeros
init, ``zero_lane_dense`` reset and prefill overwrite without a sentinel.

Per decode tick (``ss_decode_attention_streaming``):

* every *frozen* landmark row (segments before the active one — their
  landmark mean no longer moves) absorbs the new key/value with the shared
  flash-append (``kernels.ops.flash_merge``, the same algebra the
  context-parallel driver merges shards with): O(c*d) total;
* the *active* segment's row — whose landmark mean still drifts with each
  new token — is handled by ``ModelConfig.decode_streaming``:
    - ``"exact"``: recompute that one row over keys 0..pos every tick
      (O(S*d); a c-fold win over recompute, and mathematically identical to
      it — every stored row equals the softmax of today's landmark means);
    - ``"frozen"``: the active row streams too, scoring each key with the
      mean current at append time (bounded drift within one segment), and
      is *rebased* — exactly recomputed — at segment boundaries by
      ``rebase_streaming`` (the engine triggers it; amortized O(c*d)/token).

Invariant: rows past the active segment hold the zero state (appends are
row-masked, prefill seeding masks them), so they contribute nothing until
they become active and are founded by the exact recompute / rebase.

Prefill seeds these leaves in one shot (serve/prefill.py): the ``ss_fused``
path streams the prompt through the ``landmark_summary`` kernel once with
the cache's horizon-segmented landmark means and hands the kernel's
(m, l, BV) directly into the cache; the replay path uses the jnp
``recompute_stats``. Scheduler preemption recomputes through the same
prefill path on re-admission, so a preempted request's streaming state is
rebuilt exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.landmarks import onehot_segment_sums, segment_counts
from repro.core.spectral_shift import ss_core
from repro.kernels.ops import flash_merge

NEG_INF = -1e30

# Cache-leaf names of the streaming state, in every attention layer cache.
STREAM_LEAVES = ("bv_m", "bv_l", "bv_acc")

DECODE_STREAMING_MODES = ("recompute", "exact", "frozen")


# --------------------------------------------------------------------------
# Landmark bookkeeping (shared with serve/decode.py and serve/prefill.py;
# backed by the core/landmarks helpers so the formulas cannot drift).
# --------------------------------------------------------------------------
def segment_len(seq_max: int, c: int) -> int:
    return -(-seq_max // c)


def landmark_counts(pos: jnp.ndarray, seq_max: int, c: int) -> jnp.ndarray:
    """Tokens accumulated per landmark after ``pos+1`` tokens. (c,) fp32;
    zero for segments not yet reached (floor=0 keeps validity derivable)."""
    return segment_counts(pos + 1, c, segment_len(seq_max, c), floor=0)


def lmk_add(sums: jnp.ndarray, value: jnp.ndarray, pos: jnp.ndarray,
            seq_max: int) -> jnp.ndarray:
    """sums (..., c, d) += value (..., d) routed to segment(pos) — the
    single-token case of the shared ``onehot_segment_sums`` GEMM."""
    c = sums.shape[-2]
    seg = pos // segment_len(seq_max, c)
    onehot = jax.nn.one_hot(seg, c, dtype=value.dtype)[:, None]  # (c, 1)
    return sums + onehot_segment_sums(value[..., None, :], onehot).astype(
        sums.dtype
    )


def landmark_means(sums: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """fp32 means of running landmark sums; empty segments divide by 1."""
    return sums.astype(jnp.float32) / jnp.maximum(counts, 1.0)[:, None]


def masked_softmax(scores, mask):
    scores = scores.astype(jnp.float32)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    return p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)


# --------------------------------------------------------------------------
# Streaming-stat primitives.
# --------------------------------------------------------------------------
def stream_append(stats, q_l, k_new, v_new, scale: float, row_mask=None):
    """Flash-append one key/value to every landmark row's partial state.

    stats = (m, l, acc) with shapes (B, H, c, 1)/(B, H, c, 1)/(B, H, c, dv);
    q_l (B, H, c, d) fp32 landmark means; k_new (B, H, d); v_new (B, H, dv).
    The new element's own partial is (m=s, l=1, acc=v); ``row_mask`` (c,)
    bool keeps masked-out rows (segments not yet reached) untouched."""
    m, l, acc = (x.astype(jnp.float32) for x in stats)
    s = jnp.einsum(
        "bhcd,bhd->bhc", q_l, k_new.astype(jnp.float32)
    )[..., None] * scale                                   # (B, H, c, 1)
    m_n, l_n, acc_n = flash_merge(
        m, l, acc, s, jnp.ones_like(s),
        v_new[:, :, None, :].astype(jnp.float32),
    )
    if row_mask is not None:
        rm = row_mask[:, None]
        m_n = jnp.where(rm, m_n, m)
        l_n = jnp.where(rm, l_n, l)
        acc_n = jnp.where(rm, acc_n, acc)
    return m_n, l_n, acc_n


def recompute_stats(q_l, k, v, pos, scale: float, row_valid=None):
    """Exact (m, l, acc) of ``softmax(scale * q_l . K[0..pos])`` rows.

    q_l (B, H, c, d); k/v (B, H, S, d/dv); keys past ``pos`` masked out.
    ``row_valid`` (c,) bool zeroes rows for segments not yet reached, so
    the streaming invariant (future rows == zero state) holds."""
    s = jnp.einsum(
        "bhcd,bhsd->bhcs", q_l.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    key_mask = (jnp.arange(k.shape[2]) <= pos)[None, None, None, :]
    s = jnp.where(key_mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(key_mask, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bhcs,bhsd->bhcd", p, v.astype(jnp.float32))
    if row_valid is not None:
        rv = row_valid[:, None]
        m = jnp.where(rv, m, 0.0)
        l = jnp.where(rv, l, 0.0)
        acc = jnp.where(rv, acc, 0.0)
    return m, l, acc


def rebase_rows(stats, q_l, k, v, pos, scale: float, rows):
    """Exactly recompute the partial state of the (distinct) landmark rows
    ``rows`` ((R,) int32, possibly traced) over keys 0..pos; other rows pass
    through unchanged. O(R*S*d) — the amortized cost of the frozen mode."""
    m, l, acc = stats
    c = q_l.shape[2]
    q_sel = jnp.take(q_l, rows, axis=2)                   # (B, H, R, d)
    m_r, l_r, acc_r = recompute_stats(q_sel, k, v, pos, scale)
    onehot = (rows[:, None] == jnp.arange(c)[None, :]).astype(jnp.float32)
    hit = (jnp.sum(onehot, axis=0) > 0)[:, None]          # (c, 1)

    def put(old, new):
        upd = jnp.einsum("rc,bhrx->bhcx", onehot, new)
        return jnp.where(hit, upd, old.astype(jnp.float32))

    return put(m, m_r), put(l, l_r), put(acc, acc_r)


def rebase_span(stats, q_l, k, v, pos, scale: float, row_lo, row_hi,
                span: int):
    """Exactly recompute a *contiguous* window of landmark rows
    ``row_lo..row_hi`` (traced scalars) over keys 0..pos; other rows pass
    through unchanged. ``span`` is the static window capacity
    (``row_hi - row_lo + 1 <= span``); rows past ``row_hi`` or ``c`` are
    masked out of the scatter, so the window may hang off either bound.

    This is ``rebase_rows`` for the chunked-prefill case where the row set
    is a traced range rather than concrete indices: consecutive rows are
    distinct by construction, and the clamped tail duplicates are masked,
    so the onehot scatter never double-adds (``rebase_rows`` would)."""
    m, l, acc = stats
    c = q_l.shape[2]
    rows = row_lo + jnp.arange(span)                      # (span,) traced
    q_sel = jnp.take(q_l, jnp.minimum(rows, c - 1), axis=2)
    m_r, l_r, acc_r = recompute_stats(q_sel, k, v, pos, scale)
    live = (rows <= row_hi) & (rows < c)
    onehot = (
        (rows[:, None] == jnp.arange(c)[None, :]) & live[:, None]
    ).astype(jnp.float32)
    hit = (jnp.sum(onehot, axis=0) > 0)[:, None]          # (c, 1)

    def put(old, new):
        upd = jnp.einsum("rc,bhrx->bhcx", onehot, new)
        return jnp.where(hit, upd, old.astype(jnp.float32))

    return put(m, m_r), put(l, l_r), put(acc, acc_r)


def mask_stats_rows(stats, keep):
    """Zero the partial state of rows where ``keep`` (c,) is False."""
    m, l, acc = stats
    km = keep[:, None]
    return (
        jnp.where(km, m, 0.0),
        jnp.where(km, l, 0.0),
        jnp.where(km, acc, 0.0),
    )


# --------------------------------------------------------------------------
# The streaming decode attention step.
# --------------------------------------------------------------------------
def ss_decode_attention_streaming(
    q: jnp.ndarray,        # (B, H, 1, d)
    k_new: jnp.ndarray,    # (B, H, d)   this tick's key (heads broadcast)
    v_new: jnp.ndarray,    # (B, H, dv)  this tick's value
    k_cache,               # (B, Hkv, S, d) view incl. the new key at ``pos``
                           # — or None on the gather-free paged route
    v_cache,               # (B, Hkv, S, dv) (raw KV heads; Hkv divides H)
    q_lmk_sum: jnp.ndarray,  # (B, H, c, d)  updated running sums
    k_lmk_sum: jnp.ndarray,  # (B, H, c, d)
    stats,                 # (bv_m, bv_l, bv_acc) pre-append cache leaves
    pos: jnp.ndarray,      # scalar int32: index of the current token
    cfg: ModelConfig,
    scale: float,
    seq_max: int | None = None,
    mode: str = "exact",
    active_stats_fn=None,
):
    """One spectral-shift decode step with streamed B-side state.

    Same output formula as ``ss_decode_attention`` — F U_ss BV + delta*v —
    but BV comes from the cached (m, l, acc) stats instead of an O(c*S*d)
    recompute. Returns ``(out (B, H, 1, dv), (m, l, acc))``; the caller
    commits the new stats to the cache. ``k_cache``/``v_cache`` are only
    read by the ``"exact"`` active-row recompute (the ``"frozen"`` tick
    never touches the horizon) and are taken with their RAW kv-head count —
    the per-query-head active rows group onto the kv heads, so no
    O(H*S*d) head-broadcast is ever materialized on the hot path.

    ``active_stats_fn`` (optional) REPLACES that dense active-row
    recompute: called with the active landmark-mean row ``q_act``
    (B, H, 1, d), it must return the exact softmax partials over keys
    ``0..pos`` as ``(m (B,H,1,1), l (B,H,1,1), acc (B,H,1,dv))``. The
    gather-free paged route (serve/decode.py) supplies a closure over the
    block-table Pallas kernel here, with ``k_cache``/``v_cache`` = None —
    no dense horizon view ever exists on that route."""
    if mode not in ("exact", "frozen"):
        raise ValueError(
            f"unknown decode_streaming mode {mode!r}; want 'exact' or "
            f"'frozen' (or route 'recompute' to ss_decode_attention)"
        )
    if k_cache is None:
        if seq_max is None:
            raise ValueError("k_cache=None (paged route) requires seq_max")
        if mode == "exact" and active_stats_fn is None:
            raise ValueError(
                "exact mode without a cache view needs active_stats_fn"
            )
        s_max = seq_max
    else:
        s_len = k_cache.shape[2]
        s_max = s_len if seq_max is None else seq_max
    c = q_lmk_sum.shape[2]
    counts = landmark_counts(pos, s_max, c)
    valid = counts > 0
    q_l = landmark_means(q_lmk_sum, counts)
    k_l = landmark_means(k_lmk_sum, counts)

    f = masked_softmax(
        jnp.einsum("bhqd,bhcd->bhqc", q.astype(jnp.float32), k_l) * scale,
        valid[None, None, None, :],
    )  # (B, H, 1, c)
    a_mask = valid[None, None, :, None] & valid[None, None, None, :]
    a_raw = masked_softmax(
        jnp.einsum("bhcd,bhed->bhce", q_l, k_l) * scale, a_mask
    )
    eye = jnp.eye(c, dtype=jnp.float32)
    a = jnp.where(a_mask, a_raw, eye)  # invalid block pinned to identity
    core = ss_core(
        a, method="iterative", pinv_iters=cfg.pinv_iters,
        use_shift=cfg.include_shift_identity,
    )

    active = pos // segment_len(s_max, c)
    m, l, acc = stream_append(
        stats, q_l, k_new, v_new, scale, row_mask=jnp.arange(c) <= active
    )
    if mode == "exact":
        # The active segment's landmark mean moved with this token, so its
        # whole row of scores is stale: recompute that ONE row exactly.
        # Query heads group onto the raw kv heads (GQA) so the einsums run
        # against the cache as stored instead of a broadcast copy.
        b, h = q_l.shape[:2]
        q_act = jax.lax.dynamic_slice_in_dim(q_l, active, 1, axis=2)
        if active_stats_fn is not None:
            m_a, l_a, acc_a = active_stats_fn(q_act)
        else:
            hkv = k_cache.shape[1]
            q_g = q_act.reshape(b, hkv, h // hkv, q_l.shape[-1])
            m_a, l_a, acc_a = recompute_stats(q_g, k_cache, v_cache, pos,
                                              scale)
            m_a = m_a.reshape(b, h, 1, 1)
            l_a = l_a.reshape(b, h, 1, 1)
            acc_a = acc_a.reshape(b, h, 1, acc.shape[-1])
        hit = (jnp.arange(c) == active)[:, None]          # (c, 1)
        m = jnp.where(hit, m_a, m)
        l = jnp.where(hit, l_a, l)
        acc = jnp.where(hit, acc_a, acc)

    bv = acc / jnp.maximum(l, 1e-30)                      # (B, H, c, dv)
    out = jnp.einsum(
        "bhqc,bhcd->bhqd", f, jnp.einsum("bhce,bhed->bhcd", core.u, bv)
    )
    if cfg.include_shift_identity:
        out = out + core.delta * v_new[:, :, None, :].astype(jnp.float32)
    return out.astype(q.dtype), (m, l, acc)


# --------------------------------------------------------------------------
# Frozen-mode lazy rebase (engine-triggered at segment boundaries).
# --------------------------------------------------------------------------
def _rebase_attn_layer(cfg: ModelConfig, lcache: dict, pos, seq_max, mla):
    """Recompute rows {active-1, active} of one attention layer's streaming
    stats from its cached K/V view. ``pos`` is the boundary position just
    written (pos % seg == 0, pos > 0): row active-1 just froze with its
    final landmark mean (clearing the drift its active phase accumulated),
    and row active is founded over the whole horizon so subsequent appends
    extend an exact base."""
    from repro.models.attention import _broadcast_kv

    c = cfg.num_landmarks
    if mla:
        s_len = lcache["latent"].shape[1]
        h = cfg.num_heads
        k_eff = jnp.concatenate(
            [lcache["latent"], lcache["rope"]], axis=-1
        )[:, None]                                        # (B, 1, S, de)
        kb = jnp.broadcast_to(k_eff, (k_eff.shape[0], h, *k_eff.shape[2:]))
        lat = lcache["latent"][:, None]
        vb = jnp.broadcast_to(lat, (lat.shape[0], h, *lat.shape[2:]))
        scale = (cfg.resolved_head_dim + cfg.rope_head_dim) ** -0.5
    else:
        s_len = lcache["k"].shape[2]
        kb = _broadcast_kv(lcache["k"], cfg.num_heads)
        vb = _broadcast_kv(lcache["v"], cfg.num_heads)
        scale = cfg.resolved_head_dim ** -0.5
    s_max = s_len if seq_max is None else seq_max
    counts = landmark_counts(pos, s_max, c)
    q_l = landmark_means(lcache["q_lmk"], counts)
    active = pos // segment_len(s_max, c)
    rows = jnp.stack([jnp.maximum(active - 1, 0), active])
    stats = tuple(lcache[name] for name in STREAM_LEAVES)
    m, l, acc = rebase_rows(stats, q_l, kb, vb, pos, scale, rows)
    return dict(lcache, bv_m=m, bv_l=l, bv_acc=acc)


def rebase_streaming(cfg: ModelConfig, cache, pos, seq_max=None):
    """Apply the frozen-mode boundary rebase to every attention layer of a
    decode cache tree (dense views; the paged engine gathers first — see
    ``PagedKVCache.make_rebase_step``). No-op for attention-free stacks."""
    if cfg.family == "ssm":
        return cache

    def one(lc):
        if cfg.family == "hybrid":
            return dict(
                lc,
                attn=_rebase_attn_layer(cfg, lc["attn"], pos, seq_max, False),
            )
        return _rebase_attn_layer(cfg, lc, pos, seq_max, cfg.mla)

    layers = cache["layers"]
    if isinstance(layers, list):
        new_layers = [one(lc) for lc in layers]
    else:
        new_layers = jax.vmap(one)(layers)  # scan_layers: stacked leaves
    return dict(cache, layers=new_layers)


def make_rebase_fn(cfg: ModelConfig, seq_max: int):
    """Boundary-rebase closure ``fn(cache, pos) -> cache`` (vmap-ready)."""

    def fn(cache, pos):
        return rebase_streaming(cfg, cache, pos, seq_max=seq_max)

    return fn


# --------------------------------------------------------------------------
# Prefix-cache attach: landmark-sum re-segmentation + full stat reseed.
#
# A cached prefix's streaming stats are only valid at the segmentation they
# were computed under (the horizon ``seq_max`` and landmark count ``c`` fix
# ``segment_len``). Within one engine every lane shares that segmentation,
# so a "reseg" attach is a pure host-side passthrough of the cached dense
# snapshot — bitwise identical to the state a cold prefill would have left,
# which is what keeps frozen-mode outputs greedy-identical. When the cached
# segmentation DIFFERS (a cross-engine cache, or ``prefix_attach=
# "recompute"`` forcing re-derivation), the functions below rebuild the
# canonical state from what the shared blocks + snapshot actually carry:
#
# * the landmark running SUMS re-segment exactly whenever each target
#   window is a union of source windows (``seg_to % seg_from == 0`` — the
#   canonical storage segmentation is the finer one), as one O(c^2*d)
#   routing GEMM (``resegment_sums``, generalizing the ``rebase_span``
#   scatter from a row window to a row *regrouping*);
# * the per-row softmax partials (m, l, acc) cannot be merged across rows
#   (each row scores with its own landmark mean), so they are re-founded
#   exactly over the shared K/V via ``recompute_stats`` — the same math the
#   prefill handoff seeds them with, token-identity-tested against it.
# --------------------------------------------------------------------------
def resegment_sums(sums: jnp.ndarray, seg_from: int, seg_to: int):
    """Re-segment per-landmark running sums (..., c, d) from segment length
    ``seg_from`` to ``seg_to``. Exact when every target window is a union
    of source windows (``seg_to % seg_from == 0``: target row t is the sum
    of source rows t*m..(t+1)*m-1, m = seg_to/seg_from; source rows past c
    hold zeros by the streaming invariant, so truncation loses nothing up
    to the source horizon). Coarse-to-fine is information-lossy and
    rejected — re-derive through the prefill path instead."""
    if seg_to == seg_from:
        return sums
    if seg_to % seg_from:
        raise ValueError(
            f"cannot re-segment sums from segment length {seg_from} to "
            f"{seg_to}: target windows must be unions of source windows "
            f"(seg_to % seg_from == 0)"
        )
    c = sums.shape[-2]
    m = seg_to // seg_from
    route = (
        (jnp.arange(c)[:, None] // m) == jnp.arange(c)[None, :]
    ).astype(jnp.float32)                                  # (c_src, c_tgt)
    return jnp.einsum(
        "sc,...sd->...cd", route, sums.astype(jnp.float32)
    ).astype(sums.dtype)


def _reseed_attn_layer(cfg: ModelConfig, lcache: dict, pos, seq_max, mla,
                       seg_from):
    """Re-found one attention layer's streaming state at the canonical
    segmentation: re-segment the landmark sums if the source segmentation
    differs, then exactly recompute EVERY reached row's (m, l, acc) over
    keys 0..pos — ``_rebase_attn_layer`` generalized from the two boundary
    rows to the full row set (the whole prefix is new to this lane)."""
    from repro.models.attention import _broadcast_kv

    c = cfg.num_landmarks
    if mla:
        s_len = lcache["latent"].shape[1]
        h = cfg.num_heads
        k_eff = jnp.concatenate(
            [lcache["latent"], lcache["rope"]], axis=-1
        )[:, None]                                        # (B, 1, S, de)
        kb = jnp.broadcast_to(k_eff, (k_eff.shape[0], h, *k_eff.shape[2:]))
        lat = lcache["latent"][:, None]
        vb = jnp.broadcast_to(lat, (lat.shape[0], h, *lat.shape[2:]))
        scale = (cfg.resolved_head_dim + cfg.rope_head_dim) ** -0.5
    else:
        s_len = lcache["k"].shape[2]
        kb = _broadcast_kv(lcache["k"], cfg.num_heads)
        vb = _broadcast_kv(lcache["v"], cfg.num_heads)
        scale = cfg.resolved_head_dim ** -0.5
    s_max = s_len if seq_max is None else seq_max
    seg_to = segment_len(s_max, c)
    q_sum, k_sum = lcache["q_lmk"], lcache["k_lmk"]
    if seg_from is not None and seg_from != seg_to:
        q_sum = resegment_sums(q_sum, seg_from, seg_to)
        k_sum = resegment_sums(k_sum, seg_from, seg_to)
    counts = landmark_counts(pos, s_max, c)
    q_l = landmark_means(q_sum, counts)
    m, l, acc = recompute_stats(q_l, kb, vb, pos, scale,
                                row_valid=counts > 0)
    return dict(lcache, q_lmk=q_sum, k_lmk=k_sum, bv_m=m, bv_l=l,
                bv_acc=acc)


def reseed_streaming(cfg: ModelConfig, cache, pos, seq_max=None,
                     seg_from=None):
    """Re-found every attention layer's streaming stats from its cached K/V
    at the canonical segmentation (dense views; the paged engine gathers
    first through ``PagedKVCache.make_rebase_step``). ``pos`` is the index
    of the LAST attached token. ``seg_from`` re-segments the landmark sums
    when the snapshot was stored under a different segment length. No-op
    for attention-free stacks."""
    if cfg.family == "ssm":
        return cache

    def one(lc):
        if cfg.family == "hybrid":
            return dict(
                lc,
                attn=_reseed_attn_layer(cfg, lc["attn"], pos, seq_max,
                                        False, seg_from),
            )
        return _reseed_attn_layer(cfg, lc, pos, seq_max, cfg.mla, seg_from)

    layers = cache["layers"]
    if isinstance(layers, list):
        new_layers = [one(lc) for lc in layers]
    else:
        new_layers = jax.vmap(one)(layers)  # scan_layers: stacked leaves
    return dict(cache, layers=new_layers)


def make_reseed_fn(cfg: ModelConfig, seq_max: int, seg_from=None):
    """Attach-reseed closure ``fn(cache, pos) -> cache`` (vmap-ready; rides
    the same ``make_rebase_step`` plumbing as the boundary rebase — pool
    K/V is read, only the lane-dense leaves commit)."""

    def fn(cache, pos):
        return reseed_streaming(cfg, cache, pos, seq_max=seq_max,
                                seg_from=seg_from)

    return fn
