"""Fault tolerance & elasticity primitives for 1000+-node operation.

This container is one CPU, so hardware failures are *simulated*; the logic
here is the production control plane a real deployment wires to its
heartbeat transport:

* ``HeartbeatMonitor`` — per-host liveness + step-time EWMA straggler
  detection (flags hosts slower than ``straggler_factor`` x the fleet median).
* ``ElasticPlan`` — given the surviving host count, choose the largest
  runnable mesh (keeping the TP axis intact, shrinking DP), and map a saved
  checkpoint onto it (checkpoints are mesh-agnostic, see checkpointer.py).
* ``FailureInjector`` — deterministic chaos hooks used by the tests.

The trainer consumes these through ``repro.train.trainer.Trainer``: on a
detected failure it checkpoints (if possible), re-plans the mesh, restores,
and continues — the integration test exercises exactly that path on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class HostState:
    last_beat: float
    step_time_ewma: float = 0.0


class HeartbeatMonitor:
    def __init__(self, hosts: list[str], timeout_s: float = 60.0,
                 straggler_factor: float = 2.0, ewma: float = 0.9):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.ewma = ewma
        now = time.monotonic()
        self.hosts = {h: HostState(last_beat=now) for h in hosts}

    def beat(self, host: str, step_time_s: float, now: Optional[float] = None):
        st = self.hosts[host]
        st.last_beat = time.monotonic() if now is None else now
        st.step_time_ewma = (
            step_time_s
            if st.step_time_ewma == 0.0
            else self.ewma * st.step_time_ewma + (1 - self.ewma) * step_time_s
        )

    def dead_hosts(self, now: Optional[float] = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.timeout_s]

    def stragglers(self) -> list[str]:
        times = sorted(st.step_time_ewma for st in self.hosts.values()
                       if st.step_time_ewma > 0)
        if not times:
            return []
        median = times[len(times) // 2]
        return [
            h for h, st in self.hosts.items()
            if st.step_time_ewma > self.straggler_factor * median
        ]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Largest runnable (data, model) mesh for a surviving chip count."""

    data: int
    model: int
    dropped_chips: int

    @staticmethod
    def plan(alive_chips: int, model_parallel: int, max_data: int) -> "ElasticPlan":
        if alive_chips < model_parallel:
            raise RuntimeError(
                f"cannot keep TP={model_parallel} with {alive_chips} chips"
            )
        data = min(alive_chips // model_parallel, max_data)
        # Data-parallel degree must divide the global batch cleanly; keep the
        # largest power-of-two not exceeding it for stable microbatching.
        p = 1
        while p * 2 <= data:
            p *= 2
        used = p * model_parallel
        return ElasticPlan(data=p, model=model_parallel,
                           dropped_chips=alive_chips - used)


class FailureInjector:
    """Deterministic failure schedule for chaos tests: {step: [hosts]}."""

    def __init__(self, schedule: dict[int, list[str]]):
        self.schedule = schedule

    def failures_at(self, step: int) -> list[str]:
        return self.schedule.get(step, [])
