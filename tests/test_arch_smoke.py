"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only by the dry-run (abstract lowering)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig, reduced
from repro.configs.registry import ARCH_IDS, batch_specs, get_config
from repro.models.model import loss_fn, model_forward, model_specs
from repro.models.params import count_params, init_params
from repro.optim.adamw import adamw_init
from repro.optim.schedules import constant
from repro.train.train_step import make_train_step

B, S = 2, 64


def _concrete_batch(cfg, b=B, s=S, seed=0):
    """Concrete small inputs matching batch_specs' structure."""
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, size=(b, s)), jnp.int32
    )}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, 32, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, 1024)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def arch_state():
    """Cache (cfg, params) per arch across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = _concrete_batch(cfg)
    logits, aux = model_forward(params, cfg, batch)
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + extra, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, arch_state):
    cfg, params = arch_state(arch)
    tcfg = TrainConfig()
    step = make_train_step(cfg, tcfg, constant(1e-3))
    opt = adamw_init(params)
    batch = _concrete_batch(cfg)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # Parameters actually moved.
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        new_params, params,
    )
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_spectral_shift_attention_impl(arch, arch_state):
    """Every attention-bearing arch must also run with the paper's impl."""
    import dataclasses

    cfg, params = arch_state(arch)
    if cfg.family == "ssm":
        pytest.skip("attention-free (DESIGN.md §Arch-applicability)")
    cfg_ss = dataclasses.replace(
        cfg, attention_impl="spectral_shift",
        encoder_attention_impl="spectral_shift", num_landmarks=8,
    )
    batch = _concrete_batch(cfg_ss)
    logits, _ = model_forward(params, cfg_ss, batch)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the assigned hyper-parameters."""
    expected = {
        "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=29568, vocab_size=152064,
                          qkv_bias=True),
        "qwen2-7b": dict(num_layers=28, d_model=3584, num_heads=28,
                         num_kv_heads=4, d_ff=18944, vocab_size=152064,
                         qkv_bias=True),
        "deepseek-67b": dict(num_layers=95, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=22016, vocab_size=102400),
        "granite-20b": dict(num_layers=52, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "xlstm-350m": dict(num_layers=24, d_model=1024, vocab_size=50304,
                           family="ssm"),
        "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                             d_ff=2048, vocab_size=51865, encoder_layers=6),
        "hymba-1.5b": dict(num_layers=32, d_model=1600, num_heads=25,
                           num_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16, family="hybrid"),
        "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048,
                                     num_heads=16, d_ff=1408,
                                     vocab_size=102400, moe=True, top_k=6,
                                     mla=True, kv_lora_rank=512),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, vocab_size=163840, moe=True,
                                num_experts=384, top_k=8),
        "llava-next-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                               num_kv_heads=8, d_ff=20480, vocab_size=64000,
                               family="vlm"),
    }
    for arch, fields in expected.items():
        cfg = get_config(arch)
        for f, want in fields.items():
            got = getattr(cfg, f)
            assert got == want, f"{arch}.{f}: {got} != {want}"


def test_param_counts_plausible():
    """Full-config parameter counts are in the advertised ballpark."""
    targets = {  # (arch, nominal params, tolerance factor)
        "qwen2-72b": 72e9,
        "qwen2-7b": 7.6e9,
        "deepseek-67b": 67e9,
        "kimi-k2-1t-a32b": 1.0e12,
    }
    for arch, nominal in targets.items():
        n = count_params(model_specs(get_config(arch)))
        assert 0.8 * nominal < n < 1.35 * nominal, (arch, n, nominal)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_specs_all_cells(arch):
    """batch_specs builds abstract inputs for every assigned shape cell."""
    from repro.configs.base import SHAPE_PRESETS

    cfg = get_config(arch)
    for shape in SHAPE_PRESETS.values():
        specs, axes = batch_specs(cfg, shape)
        assert jax.tree.structure(specs) == jax.tree.structure(
            axes, is_leaf=lambda x: isinstance(x, tuple)
        ) or specs.keys() == axes.keys()


def test_paper_bert_config_smoke():
    """The paper's own evaluation setting (BERT-small + SS attention)."""
    import dataclasses

    cfg = reduced(get_config("paper-bert"))
    cfg = dataclasses.replace(cfg, attention_impl="spectral_shift",
                              num_landmarks=8)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    batch = _concrete_batch(cfg)
    logits, _ = model_forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
