"""Unit tests for the attention implementations (core/attention.py)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import (
    SSConfig,
    attention,
    chunked_attention,
    full_attention,
    nystrom_attention,
    spectral_shift_attention,
)


def _qkv(b=2, n=256, d=32, nk=None, seed=0, scale=0.5):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    nk = nk or n
    q = jax.random.normal(kq, (b, n, d)) * scale
    k = jax.random.normal(kk, (b, nk, d)) * scale
    v = jax.random.normal(kv, (b, nk, d))
    return q, k, v


def _softmax_ref(q, k, v, causal=False):
    d = q.shape[-1]
    s = np.einsum("...qd,...kd->...qk", np.asarray(q, np.float64),
                  np.asarray(k, np.float64)) / np.sqrt(d)
    if causal:
        nq, nk = q.shape[-2], k.shape[-2]
        mask = np.arange(nk)[None, :] <= (np.arange(nq)[:, None] + nk - nq)
        s = np.where(mask, s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("...qk,...kd->...qd", p, np.asarray(v, np.float64))


class TestFullAttention:
    def test_matches_softmax_reference(self):
        q, k, v = _qkv()
        out = full_attention(q, k, v)
        np.testing.assert_allclose(out, _softmax_ref(q, k, v), atol=1e-5)

    def test_causal_matches_reference(self):
        q, k, v = _qkv(n=64)
        out = full_attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            out, _softmax_ref(q, k, v, causal=True), atol=1e-5
        )

    def test_decode_convention(self):
        # n_q < n_k: queries are the LAST n_q positions of the context.
        q, k, v = _qkv(n=8, nk=64)
        out = full_attention(q, k, v, causal=True)
        qf, kf, vf = _qkv(n=64)
        full = full_attention(qf, k, v, causal=True)
        # Row i of out must equal row (64-8+i) computed with the same keys
        # and a matching query — check the mask logic via the reference.
        np.testing.assert_allclose(
            out, _softmax_ref(q, k, v, causal=True), atol=1e-5
        )


class TestChunkedAttention:
    @pytest.mark.parametrize("n,block", [(256, 64), (250, 64), (100, 256)])
    @pytest.mark.parametrize("causal", [False, True])
    def test_matches_full(self, n, block, causal):
        q, k, v = _qkv(n=n)
        out = chunked_attention(q, k, v, causal=causal, block=block)
        ref = full_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, atol=1e-4)

    def test_cross_length(self):
        q, k, v = _qkv(n=32, nk=256)
        np.testing.assert_allclose(
            chunked_attention(q, k, v, causal=True, block=64),
            full_attention(q, k, v, causal=True),
            atol=1e-4,
        )


class TestSpectralShiftAttention:
    def test_exact_when_short(self):
        # n <= num_landmarks: falls back to exact attention.
        q, k, v = _qkv(n=16)
        cfg = SSConfig(num_landmarks=32)
        np.testing.assert_allclose(
            spectral_shift_attention(q, k, v, cfg), full_attention(q, k, v),
            atol=1e-6,
        )

    def test_use_shift_false_is_nystrom(self):
        q, k, v = _qkv()
        cfg = SSConfig(num_landmarks=64, use_shift=False,
                       include_shift_identity=False)
        np.testing.assert_allclose(
            spectral_shift_attention(q, k, v, cfg),
            nystrom_attention(q, k, v, num_landmarks=64),
            atol=1e-6,
        )

    def test_approximates_softmax(self):
        # With c close to n the approximation should be tight.
        q, k, v = _qkv(n=256, scale=0.3)
        cfg = SSConfig(num_landmarks=128, method="svd")
        out = spectral_shift_attention(q, k, v, cfg)
        exact = full_attention(q, k, v)
        rel = float(
            jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact)
        )
        assert rel < 0.35, rel

    def test_more_landmarks_more_accurate(self):
        q, k, v = _qkv(n=512, scale=0.3)
        exact = full_attention(q, k, v)
        errs = []
        for c in (16, 64, 192):
            cfg = SSConfig(num_landmarks=c, method="svd",
                           include_shift_identity=False)
            out = spectral_shift_attention(q, k, v, cfg)
            errs.append(float(jnp.linalg.norm(out - exact)))
        assert errs[0] > errs[1] > errs[2], errs

    def test_eq10_literal_variant_runs(self):
        q, k, v = _qkv(n=128)
        cfg = SSConfig(num_landmarks=32, variant="eq10_literal")
        out = spectral_shift_attention(q, k, v, cfg)
        assert out.shape == q.shape
        assert not bool(jnp.any(jnp.isnan(out)))

    def test_segment_causal_variant(self):
        # Beyond-paper causal variant: a query must receive zero weight from
        # strictly-future landmark segments (checked via value sensitivity).
        q, k, v = _qkv(n=128, seed=3)
        cfg = SSConfig(num_landmarks=16, causal=True)
        out1 = spectral_shift_attention(q, k, v, cfg)
        # Perturb the FINAL segment of V; early queries must not change.
        v2 = v.at[:, -8:, :].add(100.0)
        out2 = spectral_shift_attention(q, k, v2, cfg)
        seg = 128 // 16
        np.testing.assert_allclose(
            out1[:, : 128 - seg], out2[:, : 128 - seg], atol=1e-4
        )

    def test_dtype_preserved(self):
        q, k, v = _qkv(n=128)
        q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
        out = spectral_shift_attention(q, k, v, SSConfig(num_landmarks=32))
        assert out.dtype == jnp.bfloat16

    def test_explicit_landmarks_decode(self):
        # Passing explicit landmarks must give a well-formed (c x c) core
        # even for a single decode query.
        q, k, v = _qkv(n=1, nk=256)
        from repro.core.landmarks import segment_means

        k_l = segment_means(k, 32)
        q_l = segment_means(k, 32)  # decode proxy: reuse key landmarks
        out = spectral_shift_attention(
            q, k, v, SSConfig(num_landmarks=32),
            q_landmarks=q_l, k_landmarks=k_l,
        )
        assert out.shape == (2, 1, 32)
        assert not bool(jnp.any(jnp.isnan(out)))


class TestDispatch:
    @pytest.mark.parametrize("impl", ["full", "chunked", "nystrom", "spectral_shift"])
    def test_dispatch(self, impl):
        q, k, v = _qkv(n=128)
        out = attention(q, k, v, impl, causal=True)
        assert out.shape == q.shape

    def test_unknown_impl_raises(self):
        q, k, v = _qkv(n=16)
        with pytest.raises(ValueError):
            attention(q, k, v, "does-not-exist")
