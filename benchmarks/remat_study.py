"""Remat-policy study: ``full`` vs ``dots`` vs ``ss_stats`` on the train
cells, pinning the per-arch defaults in ``configs/base.py::REMAT_DEFAULTS``.

Measures, per (seq_len, attention route, remat policy), on the reduced
dense decoder (the cells scale the 4k/32k train shapes down to what a CI
host executes; the *relative* ordering is the deliverable):

    fwdbwd_ms     best wall-clock of a jitted grad step (executed cells)
    peak_temp_mb  XLA CompiledMemoryStats.temp_size_in_bytes — the fwd->bwd
                  residual + workspace footprint (AOT, no execution, so the
                  32k cell is measured even where running it is impractical)

Routes: ``interpret`` forces the Pallas kernels (the only route that emits
the tagged ``ss_bv``/``ss_stats`` residuals — on CPU it measures interpreter
overhead, wall-clock there is NOT kernel-representative); ``jnp`` is the
route the dispatch heuristic actually picks on CPU.

    PYTHONPATH=src python -m benchmarks.remat_study [--quick]

Writes BENCH_remat_study.json (top level, shared write_bench envelope)
plus the pre-PR7 results/remat_study.json copy.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.train.train_step import make_grad_step

POLICIES = ("full", "dots", "ss_stats")


def _measure_ms(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _cell(base, params, seq_len: int, backend: str, remat: str,
          run_wall: bool, reps: int) -> dict:
    cfg = dataclasses.replace(
        base, attention_backend=backend, remat=remat,
        attention_impl="spectral_shift_fused",
    )
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (1, seq_len), 0, cfg.vocab_size
    )
    batch = {"tokens": tokens}
    fn = jax.jit(make_grad_step(cfg))
    out: dict = {"seq": seq_len, "backend": backend, "remat": remat}
    try:
        stats = fn.lower(params, batch).compile().memory_analysis()
        out["peak_temp_mb"] = round(stats.temp_size_in_bytes / 2**20, 2)
    except Exception as e:  # pragma: no cover - backend-dependent
        out["peak_temp_mb"] = None
        out["error"] = f"{type(e).__name__}: {e}"
    if run_wall and "error" not in out:
        out["fwdbwd_ms"] = round(_measure_ms(fn, (params, batch), reps), 1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small seqs only (smoke)")
    args = ap.parse_args()

    base = reduced(get_config("qwen2-7b"), num_landmarks=32)
    params = init_params(model_specs(base), jax.random.PRNGKey(0))
    cells = []
    seqs = (512,) if args.quick else (4096, 32768)
    for seq in seqs:
        for backend in ("interpret", "jnp"):
            # wall-clock only where a run is practical on the host: the
            # interpret route at 32k is compile-only (AOT memory numbers).
            run_wall = seq <= 4096 and not (backend == "interpret" and seq > 4096)
            for remat in POLICIES:
                cells.append(_cell(base, params, seq, backend, remat,
                                   run_wall, reps=2))
                print(cells[-1], flush=True)

    from benchmarks.run import write_bench  # lazy: avoids an import cycle

    path = write_bench(
        "remat_study",
        schema="list cells: (seq, backend, remat) -> "
               "{fwdbwd_ms?, peak_temp_mb}",
        extra={
            "config": "reduced(qwen2-7b, num_landmarks=32), batch 1, "
                      "attention_impl=spectral_shift_fused",
            "note": "interpret = forced Pallas kernels (tagged ss_stats "
                    "residuals; CPU wall-clock measures interpreter "
                    "overhead); jnp = the route the CPU heuristic picks (no "
                    "tagged residuals, ss_stats degenerates to full "
                    "recompute).",
        },
        cells=cells,
        results_copy="remat_study.json",  # pre-PR7 location, kept for readers
    )
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
