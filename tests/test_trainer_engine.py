"""Integration tests: end-to-end training (loss goes down, checkpoint/restart
is bit-exact), continuous-batching serve engine."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig, reduced
from repro.configs.registry import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer

SHAPE = ShapeConfig("train_4k", 64, 4, "train")


def _cfg(arch="qwen2-7b", **kw):
    cfg = reduced(get_config(arch))
    return dataclasses.replace(cfg, **kw) if kw else cfg


class TestTrainerIntegration:
    def test_loss_decreases(self, tmp_path):
        tcfg = TrainConfig(
            learning_rate=3e-3, checkpoint_dir=str(tmp_path), total_steps=40,
            warmup_steps=4,
        )
        tr = Trainer(_cfg(), tcfg, SHAPE, make_local_mesh(1))
        hist = tr.run(30, log_every=1000)
        first5 = np.mean([h["loss"] for h in hist[:5]])
        last5 = np.mean([h["loss"] for h in hist[-5:]])
        assert last5 < first5 - 0.1, (first5, last5)

    def test_checkpoint_restart_bitexact(self, tmp_path):
        """Interrupt + restore == uninterrupted (deterministic data + CPU)."""
        mk = lambda d: TrainConfig(
            checkpoint_dir=str(d), checkpoint_every=5, total_steps=20, seed=3
        )
        # Uninterrupted 10 steps.
        t1 = Trainer(_cfg(), mk(tmp_path / "a"), SHAPE, make_local_mesh(1))
        t1.run(10, log_every=1000)
        # 5 steps, drop trainer, restore from checkpoint and continue.
        t2 = Trainer(_cfg(), mk(tmp_path / "b"), SHAPE, make_local_mesh(1))
        t2.run(5, log_every=1000)
        t2.ckpt.wait()
        del t2
        t3 = Trainer(_cfg(), mk(tmp_path / "b"), SHAPE, make_local_mesh(1))
        assert t3.step == 5  # restored
        t3.run(5, log_every=1000)
        for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t3.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=1e-6,
            )

    def test_microbatch_accumulation_matches(self, tmp_path):
        """2 microbatches == 1 full batch (same grads up to fp32 assoc)."""
        t_full = Trainer(
            _cfg(), TrainConfig(checkpoint_dir=str(tmp_path / "f"),
                                microbatches=1, seed=0),
            SHAPE, make_local_mesh(1),
        )
        t_micro = Trainer(
            _cfg(), TrainConfig(checkpoint_dir=str(tmp_path / "m"),
                                microbatches=2, seed=0),
            SHAPE, make_local_mesh(1),
        )
        h_full = t_full.run(3, log_every=1000)
        h_micro = t_micro.run(3, log_every=1000)
        for a, b in zip(jax.tree.leaves(t_full.params),
                        jax.tree.leaves(t_micro.params)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=5e-4,
            )

    def test_straggler_flagged(self, tmp_path):
        from repro.distributed.fault_tolerance import HeartbeatMonitor

        mon = HeartbeatMonitor([f"h{i}" for i in range(4)])
        tr = Trainer(
            _cfg(), TrainConfig(checkpoint_dir=str(tmp_path)), SHAPE,
            make_local_mesh(1), monitor=mon,
        )
        tr.run(2, log_every=1000)
        # Manually skew one host and verify detection wiring.
        for _ in range(20):
            mon.beat("h3", 50.0)
        assert mon.stragglers() == ["h3"]


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        cfg = _cfg()
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
        return cfg, params

    def test_all_requests_finish(self, engine_setup):
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, max_lanes=3, max_seq=64)
        rng = np.random.default_rng(0)
        for uid in range(7):  # more requests than lanes
            eng.submit(Request(uid, rng.integers(3, 100, 5).tolist(),
                               max_new_tokens=6))
        out = eng.run()
        assert sorted(out) == list(range(7))
        assert all(1 <= len(v) <= 6 for v in out.values())

    def test_continuous_batching_overlap(self, engine_setup):
        """Later requests are admitted while earlier ones still decode."""
        cfg, params = engine_setup
        eng = ServeEngine(cfg, params, max_lanes=2, max_seq=64)
        eng.submit(Request(0, [5, 6, 7], max_new_tokens=12))
        eng.submit(Request(1, [8, 9], max_new_tokens=2))
        eng.submit(Request(2, [10, 11], max_new_tokens=2))
        saw_overlap = False
        for _ in range(200):
            eng.tick()
            st = eng.stats()
            if st["finished"] >= 1 and st["active"] >= 1:
                saw_overlap = True
            if st["finished"] == 3 and st["active"] == 0 and st["queued"] == 0:
                break
        assert saw_overlap
        assert len(eng.finished) == 3

    def test_greedy_deterministic(self, engine_setup):
        cfg, params = engine_setup
        runs = []
        for _ in range(2):
            eng = ServeEngine(cfg, params, max_lanes=1, max_seq=64, seed=0)
            eng.submit(Request(0, [4, 5, 6], max_new_tokens=8))
            runs.append(eng.run()[0])
        assert runs[0] == runs[1]

    def test_lane_isolation(self, engine_setup):
        """A lane's output must not depend on what other lanes run."""
        cfg, params = engine_setup
        eng1 = ServeEngine(cfg, params, max_lanes=2, max_seq=64, seed=0)
        eng1.submit(Request(0, [4, 5, 6], max_new_tokens=6))
        solo = eng1.run()[0]
        eng2 = ServeEngine(cfg, params, max_lanes=2, max_seq=64, seed=0)
        eng2.submit(Request(0, [4, 5, 6], max_new_tokens=6))
        eng2.submit(Request(1, [30, 31, 32, 33], max_new_tokens=6))
        both = eng2.run()[0]
        assert solo == both
