"""Paper Figure 2: spectrum analysis. Cumulative-eigenvalue curves of the
exact self-attention matrix, the Nystrom (prototype) approximation and the
Spectral-Shift approximation.

The paper's claim: the SS approximation has NO long flat tail of zero
eigenvalues (it is not low-rank), so its cumulative curve tracks the exact
matrix, while the prototype curve saturates at rank c.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.matrix_approx import approximate_spsd, sample_columns

N, C = 256, 32


def _attention_matrix(seed=0, n=N, d=24, scale=0.8):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d)) * scale
    s = x @ x.T / np.sqrt(d)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    return p / p.sum(-1, keepdims=True)


def cumulative_spectrum(m: jnp.ndarray) -> np.ndarray:
    sv = np.asarray(jnp.linalg.svd(m, compute_uv=False))
    return np.cumsum(sv) / sv.sum()


def run(csv_rows: list[str]) -> None:
    attn = _attention_matrix()
    cols = sample_columns(N, C)
    mats = {
        "exact": attn,
        "nystrom": approximate_spsd(attn, cols, "prototype"),
        "spectral_shift": approximate_spsd(attn, cols, "modified_ss"),
    }
    curves = {k: cumulative_spectrum(m) for k, m in mats.items()}
    # Numeric rank (99% of spectral mass).
    for name, cum in curves.items():
        r99 = int(np.searchsorted(cum, 0.99)) + 1
        csv_rows.append(f"spectrum,{name},rank99,{r99}")
    for idx in (8, 32, 64, 128, 255):
        for name, cum in curves.items():
            csv_rows.append(f"spectrum_cumulative,{name},i={idx},{cum[idx]:.4f}")
    # Verdict: SS keeps a long spectrum (rank99 far beyond c), Nystrom can't.
    r_ny = int(np.searchsorted(curves["nystrom"], 0.99)) + 1
    r_ss = int(np.searchsorted(curves["spectral_shift"], 0.99)) + 1
    csv_rows.append(f"spectrum_verdict,ss_rank_gain,x,{r_ss / max(r_ny, 1):.1f}")


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("\n".join(rows))
