"""Whisper-base [arXiv:2212.04356]: encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed frame embeddings, per the assignment).
The encoder is the paper's exact bidirectional setting, so its self-attention
uses spectral shifting by default."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, cross_attention=True,
    d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, act="gelu", rope_theta=0.0,
    scan_layers=False,
    attention_impl="chunked", encoder_attention_impl="spectral_shift",
    num_landmarks=32,
)
