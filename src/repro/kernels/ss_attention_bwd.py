"""Flash-style Pallas backward kernels for the spectral-shifting GEMMs.

Mirrors the two forward streams in ``ss_attention.py`` in reverse, never
materializing a (c, n) or (n, c) intermediate:

* ``landmark_summary_bwd`` — given the saved online-softmax statistics
  ``(m, l)`` and the forward output ``BV``, reconstructs each key block's
  softmax factor ``P = exp(s - m) / l`` exactly (no second reduction pass)
  and streams

      dV_blk = P^T g,   dK_blk = (P ∘ (gV^T - D))^T Q~ * scale,
      dQ~   += (P ∘ (gV^T - D)) K_blk * scale,

  where ``D = rowsum(g ∘ BV)`` is the standard flash-backward dot-product
  correction, computed once in jnp from saved tensors (O(c·dv)).

* ``query_side_bwd`` — the softmax axis (c) is block-resident, so P is
  recomputed per query block (no stats needed) and dQ/dV stream out while
  dK~ / dM / ddelta accumulate in fp32 VMEM scratch across the grid.

Both kernels accept the same ``seg``-based segment-causal masks and dynamic
``kv_offset``/``kv_valid``/``q_offset`` bounds as their forward counterparts
(see ss_attention.py): under context parallelism the backward runs per shard
against the *global* softmax statistics, so reconstruction stays exact. Grid
= (batch, n_blocks), n innermost so scratch accumulators persist across the
stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ss_attention import (
    _b_side_mask,
    _bounds_array,
    _query_side_probs,
)

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# B-side backward: dQ~, dK, dV of BV = softmax(Q~ K^T * scale) @ V.
# --------------------------------------------------------------------------
def _landmark_summary_bwd_kernel(
    *refs,
    scale: float,
    n_valid: int,
    block_n: int,
    seg: int,
    dyn: bool,
):
    """Ref layout: [bounds (1,2) SMEM if dyn], q (1,c,d), k (1,bn,d),
    v (1,bn,dv), g (1,c,dv), m (1,c,1), l (1,c,1), dcoef (1,c,1),
    dq (1,c,d), dk (1,bn,d), dv (1,bn,dv), dq_scr (c,d)."""
    if dyn:
        bounds_ref, *refs = refs
        kv_offset = bounds_ref[0, 0]
        # Clamp by the local pre-block-padding length — see the forward
        # kernel: the zero tail padded to a block multiple can sit below
        # the global valid end on non-final shards.
        kv_valid = jnp.minimum(bounds_ref[0, 1], kv_offset + n_valid)
    else:
        kv_offset = 0
        kv_valid = n_valid if n_valid % block_n else None
    (q_ref, k_ref, v_ref, g_ref, m_ref, l_ref, dcoef_ref,
     dq_ref, dk_ref, dv_ref, dq_scr) = refs
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)                      # (c, d)
    k = k_ref[0].astype(jnp.float32)                      # (bn, d)
    v = v_ref[0].astype(jnp.float32)                      # (bn, dv)
    g = g_ref[0].astype(jnp.float32)                      # (c, dv)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (c, bn)
    mask = _b_side_mask(
        s.shape, i, block_n=block_n, seg=seg, kv_offset=kv_offset,
        kv_valid=kv_valid,
    )
    if mask is not None:
        s = jnp.where(mask, s, _NEG_INF)

    p = jnp.exp(s - m_ref[0]) / jnp.maximum(l_ref[0], 1e-30)  # (c, bn)
    if mask is not None:
        p = jnp.where(mask, p, 0.0)

    dp = jax.lax.dot_general(
        g, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (c, bn)
    ds = p * (dp - dcoef_ref[0]) * scale                  # (c, bn)

    dv_ref[0] = jax.lax.dot_general(
        p, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(dv_ref.dtype)                                # (bn, dv)
    dk_ref[0] = jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(dk_ref.dtype)                                # (bn, d)
    dq_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (c, d)

    @pl.when(i == pl.num_programs(1) - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def landmark_summary_bwd(
    q_l: jnp.ndarray,    # (b, c, d)
    k: jnp.ndarray,      # (b, n, d)
    v: jnp.ndarray,      # (b, n, dv)
    bv: jnp.ndarray,     # (b, c, dv)  saved forward output
    m: jnp.ndarray,      # (b, c, 1)   saved row max
    l: jnp.ndarray,      # (b, c, 1)   saved row denominator
    g: jnp.ndarray,      # (b, c, dv)  cotangent of BV
    *,
    scale: float,
    block_n: int = 512,
    causal: bool = False,
    interpret: bool = False,
    kv_offset=None,
    kv_valid=None,
    seq_len_k: int = 0,
):
    """Backward of ``landmark_summary``: returns ``(dq_l, dk, dv)``.

    Under context parallelism, pass the shard's ``kv_offset``/``kv_valid``
    plus the *global* statistics (bv, m, l) — the per-shard reconstruction
    is then exact and ``dq_l`` is the local partial to psum.
    """
    b, c, d = q_l.shape
    n, dv = k.shape[1], v.shape[2]
    n_k = seq_len_k or n
    seg = -(-n_k // c) if causal else 0
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        k = jnp.pad(k, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    # D_i = sum_j P_ij (g_i . V_j) = g_i . BV_i — O(c dv), stays in jnp.
    dcoef = jnp.sum(
        g.astype(jnp.float32) * bv.astype(jnp.float32), axis=-1, keepdims=True
    )

    dyn = kv_offset is not None or kv_valid is not None
    kernel = functools.partial(
        _landmark_summary_bwd_kernel, scale=scale, n_valid=n,
        block_n=block_n, seg=seg, dyn=dyn,
    )
    stat_spec = pl.BlockSpec((1, c, 1), lambda bi, i: (bi, 0, 0))
    in_specs = [
        pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
        stat_spec,
        stat_spec,
        stat_spec,
    ]
    inputs = [q_l, k, v, g, m, l, dcoef]
    if dyn:
        in_specs.insert(0, pl.BlockSpec(memory_space=pltpu.SMEM))
        off = kv_offset if kv_offset is not None else 0
        # Defaults mirror the forward: all local keys valid, globally.
        inputs.insert(
            0,
            _bounds_array(off, kv_valid if kv_valid is not None else off + n),
        )
    dq, dk, dv_out = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, c, d), q_l.dtype),
            jax.ShapeDtypeStruct((b, n + n_pad, d), k.dtype),
            jax.ShapeDtypeStruct((b, n + n_pad, dv), v.dtype),
        ),
        scratch_shapes=[pltpu.VMEM((c, d), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    if n_pad:
        dk, dv_out = dk[:, :n], dv_out[:, :n]
    return dq, dk, dv_out


# --------------------------------------------------------------------------
# F-side backward: dQ, dK~, dM, dV, ddelta of
#   out = softmax(Q K~^T * scale) @ M + delta * V.
# --------------------------------------------------------------------------
def _query_side_bwd_kernel(
    *refs,
    scale: float,
    block_n: int,
    seg: int,
    pos_offset: int,
    dyn: bool,
):
    """Ref layout: [bounds (1,1) SMEM if dyn], q (1,bn,d), kl (1,c,d),
    m (1,c,dv), v (1,bn,dv), delta (1,1,1), g (1,bn,dv), dq (1,bn,d),
    dv (1,bn,dv), dkl (1,c,d), dm (1,c,dv), dd (1,1,1), dkl_scr (c,d),
    dm_scr (c,dv), dd_scr (1,1)."""
    if dyn:
        bounds_ref, *refs = refs
        pos_offset = bounds_ref[0, 0]
    (q_ref, kl_ref, m_ref, v_ref, delta_ref, g_ref,
     dq_ref, dv_ref, dkl_ref, dm_ref, dd_ref,
     dkl_scr, dm_scr, dd_scr) = refs
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dkl_scr[...] = jnp.zeros_like(dkl_scr)
        dm_scr[...] = jnp.zeros_like(dm_scr)
        dd_scr[...] = jnp.zeros_like(dd_scr)

    p = _query_side_probs(
        q_ref, kl_ref, scale=scale, block_n=block_n, seg=seg,
        pos_offset=pos_offset,
    )                                                     # (bn, c)
    q = q_ref[0].astype(jnp.float32)                      # (bn, d)
    kl = kl_ref[0].astype(jnp.float32)                    # (c, d)
    mm = m_ref[0].astype(jnp.float32)                     # (c, dv)
    v = v_ref[0].astype(jnp.float32)                      # (bn, dv)
    g = g_ref[0].astype(jnp.float32)                      # (bn, dv)

    dp = jax.lax.dot_general(
        g, mm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (bn, c)
    drow = jnp.sum(p * dp, axis=-1, keepdims=True)        # (bn, 1)
    ds = p * (dp - drow) * scale                          # (bn, c)

    dq_ref[0] = jax.lax.dot_general(
        ds, kl, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(dq_ref.dtype)                                # (bn, d)
    dv_ref[0] = (delta_ref[0, 0, 0] * g).astype(dv_ref.dtype)
    dkl_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (c, d)
    dm_scr[...] += jax.lax.dot_general(
        p, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (c, dv)
    dd_scr[...] += jnp.sum(g * v)

    @pl.when(i == pl.num_programs(1) - 1)
    def _finalize():
        dkl_ref[0] = dkl_scr[...].astype(dkl_ref.dtype)
        dm_ref[0] = dm_scr[...].astype(dm_ref.dtype)
        dd_ref[0] = dd_scr[...].astype(dd_ref.dtype)


def query_side_bwd(
    q: jnp.ndarray,      # (b, n, d)
    k_l: jnp.ndarray,    # (b, c, d)
    m_mat: jnp.ndarray,  # (b, c, dv)
    v: jnp.ndarray,      # (b, n, dv)
    delta: jnp.ndarray,  # (b, 1, 1) fp32
    g: jnp.ndarray,      # (b, n, dv)  cotangent of out
    *,
    scale: float,
    block_n: int = 512,
    causal: bool = False,
    seq_len_k: int = 0,
    interpret: bool = False,
    q_offset=None,
):
    """Backward of ``query_side``: returns ``(dq, dk_l, dm, dv, ddelta)``.

    Under context parallelism ``dk_l``/``dm``/``ddelta`` are the local
    partials to psum (dq/dv stay shard-local)."""
    b, n, d = q.shape
    c, dv = k_l.shape[1], v.shape[2]
    n_k = seq_len_k or n
    seg = -(-n_k // c) if causal else 0
    pos_offset = n_k - n if causal else 0
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        # Padded rows contribute nothing: their cotangent is zero, which
        # zeroes ds / dq / the scratch accumulators for those rows.
        q = jnp.pad(q, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
        g = jnp.pad(g, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    dyn = q_offset is not None
    kernel = functools.partial(
        _query_side_bwd_kernel, scale=scale, block_n=block_n, seg=seg,
        pos_offset=pos_offset, dyn=dyn,
    )
    in_specs = [
        pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, 1, 1), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
    ]
    inputs = [q, k_l, m_mat, v, delta.astype(jnp.float32), g]
    if dyn:
        in_specs.insert(0, pl.BlockSpec(memory_space=pltpu.SMEM))
        inputs.insert(0, _bounds_array(q_offset))
    dq, dv_out, dkl, dm, dd = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, i: (bi, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, n + n_pad, d), q.dtype),
            jax.ShapeDtypeStruct((b, n + n_pad, dv), v.dtype),
            jax.ShapeDtypeStruct((b, c, d), k_l.dtype),
            jax.ShapeDtypeStruct((b, c, dv), m_mat.dtype),
            jax.ShapeDtypeStruct((b, 1, 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((c, d), jnp.float32),
            pltpu.VMEM((c, dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    if n_pad:
        dq, dv_out = dq[:, :n], dv_out[:, :n]
    return dq, dkl, dm, dv_out, dd
