"""Model zoo assembly: specs + forward for every assigned architecture family.

Families
--------
dense   — llama/qwen-style decoder (GQA, rotary, SwiGLU, optional QKV bias)
moe     — dense trunk with MoE FFN (shared + routed experts) and GQA or MLA
ssm     — xLSTM: mLSTM blocks with periodic sLSTM blocks (attention-free)
hybrid  — hymba: parallel attention + mamba heads per layer, then MLP
audio   — whisper: bidirectional encoder (stub frame embeddings) + decoder
            with cross attention
vlm     — llava: image-patch stub projected into a dense decoder

Parameters are ParamSpec trees (models/params.py); the uniform trunk is
scanned over stacked layer weights, heterogeneous stacks (xlstm, whisper)
are unrolled. Forward signatures:

    model_specs(cfg)                      -> ParamSpec tree
    model_forward(params, cfg, batch)     -> (logits, aux)   [train/prefill]
    loss_fn(params, cfg, batch)           -> (loss, metrics)

The KV-cache decode path lives in repro/serve/decode.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint as Lc
from repro.models.attention import (
    cross_attention_forward,
    cross_attention_specs,
    gqa_forward,
    gqa_specs,
    mla_forward,
    mla_specs,
)
from repro.models.layers import (
    layer_norm,
    mlp_forward,
    mlp_specs,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.moe import moe_forward, moe_specs
from repro.models.params import ParamSpec, stack_layer_specs
from repro.models.ssm import (
    _causal_conv,
    mamba_forward,
    mamba_specs,
    mlstm_chunked,
    slstm_scan,
)

Params = Any


# ==========================================================================
# Layer specs / forwards per family
# ==========================================================================
def _norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def dense_layer_specs(cfg: ModelConfig) -> dict:
    attn = mla_specs(cfg) if cfg.mla else gqa_specs(cfg)
    specs = {"norm_attn": _norm_spec(cfg.d_model), "attn": attn,
             "norm_mlp": _norm_spec(cfg.d_model)}
    if cfg.moe:
        specs["moe"] = moe_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.act)
    return specs


def dense_layer_forward(p, cfg: ModelConfig, x, positions, impl, mode):
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps)
    if cfg.mla:
        attn_out = mla_forward(p["attn"], cfg, h, positions, impl=impl, mode=mode)
    else:
        attn_out, _ = gqa_forward(p["attn"], cfg, h, positions, impl=impl, mode=mode)
    x = x + attn_out
    h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        if cfg.moe_impl == "ep":
            from repro.models.moe import moe_forward_ep

            ff, aux = moe_forward_ep(p["moe"], cfg, h)
        else:
            ff, aux = moe_forward(p["moe"], cfg, h)
    else:
        ff = mlp_forward(p["mlp"], h, cfg.act)
    x = x + ff
    x = Lc(x, ("batch", "seq", "embed_act"))
    return x, aux


def hymba_layer_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "norm_mix": _norm_spec(d),
        "attn": gqa_specs(cfg),
        "mamba": mamba_specs(d, d, cfg.ssm_state, cfg.conv_width, max(d // 16, 8)),
        "gate_attn": ParamSpec((d,), ("embed",), init="ones"),
        "gate_ssm": ParamSpec((d,), ("embed",), init="ones"),
        "norm_mlp": _norm_spec(d),
        "mlp": mlp_specs(d, cfg.d_ff, cfg.act),
    }


def hymba_layer_forward(p, cfg: ModelConfig, x, positions, impl, mode):
    """Hymba: attention heads and mamba heads in parallel on the same input,
    fused by learned per-channel gates, followed by a dense MLP."""
    h = rms_norm(x, p["norm_mix"], cfg.norm_eps)
    attn_out, _ = gqa_forward(p["attn"], cfg, h, positions, impl=impl, mode=mode)
    ssm_out, _ = mamba_forward(
        p["mamba"], h, cfg.ssm_state, chunk=cfg.ssm_chunk,
        unroll=cfg.unroll_scans,
    )
    mixed = (
        p["gate_attn"].astype(x.dtype) * attn_out
        + p["gate_ssm"].astype(x.dtype) * ssm_out
    )
    x = x + mixed
    h = rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    x = x + mlp_forward(p["mlp"], h, cfg.act)
    x = Lc(x, ("batch", "seq", "embed_act"))
    return x, jnp.zeros((), jnp.float32)


# -- xLSTM blocks ----------------------------------------------------------
def mlstm_block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = 2 * d  # projection factor 2
    h = cfg.num_heads
    return {
        "norm": _norm_spec(d),
        "w_up": ParamSpec((d, 2 * di), ("embed", "ff")),
        "conv_w": ParamSpec((cfg.conv_width, di), (None, "ff"), scale=0.3),
        "conv_b": ParamSpec((di,), ("ff",), init="zeros"),
        "w_q": ParamSpec((di, di), ("ff", "ff_out")),
        "w_k": ParamSpec((di, di), ("ff", "ff_out")),
        "w_v": ParamSpec((di, di), ("ff", "ff_out")),
        "w_if": ParamSpec((di, 2 * h), ("ff", None), scale=0.05),
        "b_if": ParamSpec((2 * h,), (None,), init="zeros"),
        "ln_inner": ParamSpec((di,), ("ff",), init="ones"),
        "w_down": ParamSpec((di, d), ("ff", "embed")),
    }


def mlstm_block_forward(p, cfg: ModelConfig, x):
    b, s, d = x.shape
    h = cfg.num_heads
    dt = x.dtype
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"].astype(dt)
    di = up.shape[-1] // 2
    xm, z = up[..., :di], up[..., di:]
    xc = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"]))
    to_heads = lambda a: a.reshape(b, s, h, di // h).swapaxes(1, 2)
    q = to_heads(xc @ p["w_q"].astype(dt))
    k = to_heads(xc @ p["w_k"].astype(dt))
    v = to_heads(xm @ p["w_v"].astype(dt))
    gates = xc @ p["w_if"].astype(dt) + p["b_if"].astype(dt)  # (B,S,2H)
    ilog = gates[..., :h].swapaxes(1, 2)                      # (B,H,S)
    flog = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32)).swapaxes(1, 2)
    core, _ = mlstm_chunked(
        q, k, v, ilog, flog, chunk=cfg.ssm_chunk, unroll=cfg.unroll_scans
    )
    core = core.swapaxes(1, 2).reshape(b, s, di)
    core = rms_norm(core, p["ln_inner"], cfg.norm_eps)
    out = (core * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return x + out


def slstm_block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    return {
        "norm": _norm_spec(d),
        "w_g": ParamSpec((d, h, 4, dh), ("embed", "heads", None, "head_dim")),
        "b_g": ParamSpec((h, 4, dh), ("heads", None, "head_dim"), init="zeros"),
        "r_w": ParamSpec((h, 4, dh, dh), ("heads", None, "head_dim", None), scale=0.05),
        "ln_inner": ParamSpec((d,), ("embed",), init="ones"),
        "w_out": ParamSpec((d, d), ("embed", "ff")),
        "w_down": ParamSpec((d, d), ("ff", "embed")),
    }


def slstm_block_forward(p, cfg: ModelConfig, x):
    b, s, d = x.shape
    dt = x.dtype
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    xg = jnp.einsum("bsd,dhge->bshge", xn, p["w_g"].astype(dt)) + p["b_g"].astype(dt)
    hs, _ = slstm_scan(xg, p["r_w"])
    hs = hs.reshape(b, s, d)
    hs = rms_norm(hs, p["ln_inner"], cfg.norm_eps)
    out = jax.nn.gelu(hs @ p["w_out"].astype(dt)) @ p["w_down"].astype(dt)
    return x + out


# -- whisper layers (LayerNorm + GELU, pre-LN) ------------------------------
def _ln_specs(d: int) -> dict:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def whisper_enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln_attn": _ln_specs(cfg.d_model),
        "attn": gqa_specs(cfg),
        "ln_mlp": _ln_specs(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, "gelu"),
    }


def whisper_enc_layer_forward(p, cfg: ModelConfig, x, positions, impl):
    h = layer_norm(x, p["ln_attn"]["scale"], p["ln_attn"]["bias"], cfg.norm_eps)
    attn, _ = gqa_forward(p["attn"], cfg, h, positions, impl=impl, mode="bidir")
    x = x + attn
    h = layer_norm(x, p["ln_mlp"]["scale"], p["ln_mlp"]["bias"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h, "gelu")


def whisper_dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln_self": _ln_specs(cfg.d_model),
        "self_attn": gqa_specs(cfg),
        "ln_cross": _ln_specs(cfg.d_model),
        "cross_attn": cross_attention_specs(cfg),
        "ln_mlp": _ln_specs(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, "gelu"),
    }


def whisper_dec_layer_forward(p, cfg, x, enc_out, positions, impl, cross_impl):
    h = layer_norm(x, p["ln_self"]["scale"], p["ln_self"]["bias"], cfg.norm_eps)
    attn, _ = gqa_forward(p["self_attn"], cfg, h, positions, impl=impl, mode="causal")
    x = x + attn
    h = layer_norm(x, p["ln_cross"]["scale"], p["ln_cross"]["bias"], cfg.norm_eps)
    x = x + cross_attention_forward(p["cross_attn"], cfg, h, enc_out, impl=cross_impl)
    h = layer_norm(x, p["ln_mlp"]["scale"], p["ln_mlp"]["bias"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h, "gelu")


# ==========================================================================
# Whole-model specs
# ==========================================================================
def _layer_specs_for(cfg: ModelConfig) -> dict:
    if cfg.family in ("dense", "moe", "vlm"):
        return dense_layer_specs(cfg)
    if cfg.family == "hybrid":
        return hymba_layer_specs(cfg)
    raise ValueError(cfg.family)


def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_padded
    specs: dict = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), scale=0.02),
        "final_norm": _norm_spec(d),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))

    if cfg.family == "ssm":
        layers = []
        for i in range(cfg.num_layers):
            if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0:
                layers.append({"kind_slstm": slstm_block_specs(cfg)})
            else:
                layers.append({"kind_mlstm": mlstm_block_specs(cfg)})
        specs["layers"] = layers
    elif cfg.family == "audio":
        specs["enc_proj"] = ParamSpec((d, d), ("embed", "ff"))
        specs["enc_layers"] = [
            whisper_enc_layer_specs(cfg) for _ in range(cfg.encoder_layers)
        ]
        specs["enc_ln"] = _ln_specs(d)
        specs["dec_pos"] = ParamSpec((4096, d), (None, "embed"), scale=0.02)
        specs["layers"] = [
            whisper_dec_layer_specs(cfg) for _ in range(cfg.num_layers)
        ]
        specs["dec_ln"] = _ln_specs(d)
    else:
        layer = _layer_specs_for(cfg)
        if cfg.scan_layers:
            specs["layers"] = stack_layer_specs(layer, cfg.num_layers)
        else:
            specs["layers"] = [layer for _ in range(cfg.num_layers)]
        if cfg.family == "vlm":
            # Stub anyres frontend: pre-extracted patch features (1024) ->
            # two-layer MM projector into the LM embedding space.
            specs["mm_proj"] = {
                "w1": ParamSpec((1024, d), (None, "embed")),
                "w2": ParamSpec((d, d), ("embed", "ff")),
            }
    return specs


# ==========================================================================
# Forward
# ==========================================================================
def _embed_tokens(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    emb = params["embed"]
    return jnp.take(emb, tokens, axis=0).astype(cfg.compute_dtype)


def _unembed(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = x @ w
    return Lc(logits, ("batch", "seq", "vocab_act"))


def _run_trunk(params, cfg: ModelConfig, x, positions, impl, mode):
    """Scan (or unrolled loop) over the decoder trunk. Returns (x, aux)."""
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        for lp in params["layers"]:
            if "kind_slstm" in lp:
                x = slstm_block_forward(lp["kind_slstm"], cfg, x)
            else:
                x = mlstm_block_forward(lp["kind_mlstm"], cfg, x)
        return x, aux0

    fwd = {
        "dense": dense_layer_forward,
        "moe": dense_layer_forward,
        "vlm": dense_layer_forward,
        "hybrid": hymba_layer_forward,
    }[cfg.family]
    layer_fn = functools.partial(fwd, cfg=cfg, positions=positions, impl=impl, mode=mode)
    # "auto" resolves to the per-arch default pinned from the remat study
    # (configs/base.py REMAT_DEFAULTS, results/remat_study.json).
    from repro.configs.base import resolve_remat

    remat = resolve_remat(cfg.remat)
    if remat == "full":
        layer_fn = jax.checkpoint(layer_fn)
    elif remat == "dots":
        layer_fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    elif remat == "ss_stats":
        # Fused-attention training profile: across the layer boundary keep
        # only the (c, dv) landmark summary BV and the (c, 1) online-softmax
        # stats the custom-VJP kernels named in kernels/ops.py — everything
        # O(n)-sized is recomputed in backward.
        layer_fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "ss_bv", "ss_stats"
            ),
        )

    if cfg.scan_layers and not isinstance(params["layers"], list):
        def body(carry, lp):
            y, aux = carry
            y, a = layer_fn(lp, x=y)
            return (y, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
        return x, aux
    aux = aux0
    for lp in params["layers"]:
        x, a = layer_fn(lp, x=x)
        aux = aux + a
    return x, aux


def working_params(params, cfg: ModelConfig):
    """Cast fp32 master params to the compute dtype ONCE at step entry.

    Under FSDP/ZeRO the per-layer weight all-gathers then move bf16 instead
    of fp32 (2x less collective traffic); backward converts grads back to
    fp32 at the same boundary (standard mixed precision). No-op when the
    dtypes already match (reduced/CPU test configs). Integer leaves and
    non-float leaves pass through untouched.
    """
    dt = jnp.dtype(cfg.compute_dtype)
    if not cfg.cast_params_once or dt == jnp.dtype(cfg.param_dtype):
        return params
    return jax.tree.map(
        lambda p: p.astype(dt) if p.dtype == jnp.float32 else p, params
    )


def model_forward(params, cfg: ModelConfig, batch: dict, mode: str = "train"):
    """Full-sequence forward. Returns (logits (B,S,V), aux)."""
    dt = jnp.dtype(cfg.compute_dtype)
    params = working_params(params, cfg)

    if cfg.family == "audio":
        return _whisper_forward(params, cfg, batch)

    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(dt)  # (B, P, 1024)
        mp = params["mm_proj"]
        pe = jax.nn.gelu(patches @ mp["w1"].astype(dt)) @ mp["w2"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)
    x = x.astype(dt)
    x = Lc(x, ("batch", "seq", "embed_act"))
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    impl = cfg.attention_impl if mode == "train" else cfg.attention_impl
    x, aux = _run_trunk(params, cfg, x, positions, impl, "causal")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x), aux


def _whisper_forward(params, cfg: ModelConfig, batch: dict):
    dt = jnp.dtype(cfg.compute_dtype)
    frames = batch["frames"].astype(dt)  # (B, S_enc, d) stub embeddings
    b, s_enc, _ = frames.shape
    enc = frames @ params["enc_proj"].astype(dt)
    enc = enc + sinusoidal_positions(s_enc, cfg.d_model).astype(dt)
    pos_enc = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))
    for lp in params["enc_layers"]:
        enc = whisper_enc_layer_forward(
            lp, cfg, enc, pos_enc, cfg.encoder_attention_impl
        )
    enc = layer_norm(enc, params["enc_ln"]["scale"], params["enc_ln"]["bias"], cfg.norm_eps)

    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = _embed_tokens(params, cfg, tokens)
    pos_emb = params["dec_pos"]
    if s <= pos_emb.shape[0]:
        x = x + pos_emb[:s].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    for lp in params["layers"]:
        x = whisper_dec_layer_forward(
            lp, cfg, x, enc, positions, cfg.attention_impl,
            cfg.encoder_attention_impl,
        )
    x = layer_norm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"], cfg.norm_eps)
    return _unembed(params, cfg, x), jnp.zeros((), jnp.float32)


# ==========================================================================
# Loss
# ==========================================================================
def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Next-token cross entropy (+ MoE aux). Returns (loss, metrics)."""
    from repro.train.losses import next_token_loss

    logits, aux = model_forward(params, cfg, batch)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        # Only text positions carry labels; patch prefix is unsupervised.
        n_patches = logits.shape[1] - tokens.shape[1]
        logits = logits[:, n_patches:]
    ce_loss, metrics = next_token_loss(logits, tokens)
    loss = ce_loss + cfg.router_aux_coef * aux
    metrics["aux"] = aux
    return loss, metrics
