"""Pallas TPU kernels for the paper's O(n) attention hot spots.

``ss_attention.py`` holds the forward pl.pallas_call kernels (BlockSpec VMEM
tiling, segment-causal masks, online-softmax stats, dynamic key-validity
bounds), ``ss_attention_bwd.py`` the flash-style backward kernels,
``ops.py`` the jitted custom-VJP wrappers, ``sharded.py`` the shard_map
context-parallel driver (per-shard kernels + landmark-sized collectives),
``paged_decode.py`` the gather-free serving decode kernel (scalar-prefetch
block-table index maps over the shared KV block pools),
``dispatch.py`` the impl/block-size registry with measured autotune, and
``ref.py`` the pure-jnp oracles. Validated in interpret mode on CPU; TPU
v5e is the compile target.
"""

from repro.kernels.dispatch import (
    Plan,
    PlanKey,
    autotune,
    autotune_decode,
    dispatch_ss_attention,
    get_plan,
    load_cache,
    make_key,
    register_plan,
    save_cache,
)
from repro.kernels.paged_decode import paged_row_stats, paged_row_stats_lanes
from repro.kernels.ops import (
    flash_merge,
    flash_rescale,
    landmark_summary_op,
    nystrom_attention_fused,
    query_side_op,
    ss_attention_fused,
    ss_core_factors,
)
from repro.kernels.sharded import ss_attention_fused_sharded
from repro.kernels.ss_attention import landmark_summary, query_side
from repro.kernels.ss_attention_bwd import landmark_summary_bwd, query_side_bwd

__all__ = [
    "Plan",
    "PlanKey",
    "autotune",
    "autotune_decode",
    "dispatch_ss_attention",
    "flash_merge",
    "flash_rescale",
    "get_plan",
    "landmark_summary",
    "landmark_summary_bwd",
    "landmark_summary_op",
    "load_cache",
    "make_key",
    "nystrom_attention_fused",
    "paged_row_stats",
    "paged_row_stats_lanes",
    "query_side",
    "query_side_bwd",
    "query_side_op",
    "register_plan",
    "save_cache",
    "ss_attention_fused",
    "ss_attention_fused_sharded",
    "ss_core_factors",
]
