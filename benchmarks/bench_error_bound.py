"""Paper §7: the error bound of eq. (12),

    E <= 1 + ||A+||_inf (1 + delta ||A+||_inf)(1 - ||A+ - Z*||_inf)

with Z* the iterative pseudoinverse of eq. (11). We measure the actual
infinity-norm error E of the linear-time approximation against the exact
attention matrix and report E alongside the bound, sweeping the iteration
count T (which controls ||A+ - Z*||).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention import SSConfig, _softmax, spectral_shift_attention
from repro.core.landmarks import segment_means
from repro.core.pinv import iterative_pinv
from repro.core.spectral_shift import ss_core

N, C, D = 256, 32, 24


def _inf_norm(m):
    return float(jnp.max(jnp.sum(jnp.abs(m), axis=-1)))


def _bound_sweep(csv_rows, tag, a, exact, f, b_mat, n):
    """Eq.-(12) bound vs actual error across pinv iteration counts."""
    a_pinv = jnp.linalg.pinv(a)
    for t in (2, 4, 6, 10, 20):
        z = iterative_pinv(a, num_iters=t)
        core = ss_core(a, method="iterative", pinv_iters=t)
        delta = float(core.delta[..., 0, 0])
        approx = f @ core.u @ b_mat + delta * jnp.eye(n)
        e_actual = _inf_norm(exact - approx)
        na = _inf_norm(a_pinv)
        nz = _inf_norm(a_pinv - z)
        bound = 1 + na * (1 + delta * na) * (1 - min(nz, 1.0))
        csv_rows.append(f"error_bound_{tag},T={t},E_actual,{e_actual:.4f}")
        csv_rows.append(f"error_bound_{tag},T={t},bound_eq12,{bound:.4f}")
        csv_rows.append(f"error_bound_{tag},T={t},holds,{int(e_actual <= bound)}")
        csv_rows.append(f"error_bound_{tag},T={t},pinv_residual_inf,{nz:.4f}")


def run(csv_rows: list[str]) -> None:
    # Regime 1 (well-conditioned core): cluster-structured tokens give a
    # well-conditioned A_s, so the eq.-(11) iteration actually converges and
    # the eq.-(12) bound is non-vacuous.
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(C, D))
    centers = centers / np.linalg.norm(centers, axis=-1, keepdims=True) * 4.0
    # Segment-aligned clusters (segment j's tokens all near center j) so the
    # landmark core is sharply diagonal -> well-conditioned.
    toks = centers[np.arange(N) // (N // C)] + rng.normal(size=(N, D)) * 0.02
    qw = jnp.asarray(toks[None], jnp.float32)
    scale_w = 1 / np.sqrt(D)
    exact_w = _softmax(jnp.einsum("bnd,bmd->bnm", qw, qw) * scale_w)[0]
    q_lw = segment_means(qw, C)
    f_w = _softmax(jnp.einsum("bnd,bcd->bnc", qw, q_lw) * scale_w)[0]
    a_w = _softmax(jnp.einsum("bcd,bed->bce", q_lw, q_lw) * scale_w)[0]
    b_w = _softmax(jnp.einsum("bcd,bnd->bcn", q_lw, qw) * scale_w)[0]
    _bound_sweep(csv_rows, "clustered", a_w, exact_w, f_w, b_w, N)

    # Regime 2 (paper's raw setting): self-similar gaussian tokens — the
    # core is ill-conditioned, the iteration under-converges and the bound
    # degenerates to ~1 (still holds, but vacuously). Reported faithfully.
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, N, D)) * 0.6
    k = q  # self-similar tokens: the attention-relevant regime
    scale = 1 / np.sqrt(D)

    exact = _softmax(jnp.einsum("bnd,bmd->bnm", q, k) * scale)[0]
    q_l = segment_means(q, C)
    k_l = segment_means(k, C)
    f = _softmax(jnp.einsum("bnd,bcd->bnc", q, k_l) * scale)[0]
    a = _softmax(jnp.einsum("bcd,bed->bce", q_l, k_l) * scale)[0]
    b = _softmax(jnp.einsum("bcd,bnd->bcn", q_l, k) * scale)[0]

    a_pinv = jnp.linalg.pinv(a)
    for t in (2, 4, 6, 10):
        z = iterative_pinv(a, num_iters=t)
        core = ss_core(a, method="iterative", pinv_iters=t)
        delta = float(core.delta[..., 0, 0])
        approx = f @ core.u @ b + delta * jnp.eye(N)
        e_actual = _inf_norm(exact - approx)
        na = _inf_norm(a_pinv)
        nz = _inf_norm(a_pinv - z)
        bound = 1 + na * (1 + delta * na) * (1 - min(nz, 1.0))
        csv_rows.append(
            f"error_bound,T={t},E_actual,{e_actual:.4f}"
        )
        csv_rows.append(
            f"error_bound,T={t},bound_eq12,{bound:.4f}"
        )
        csv_rows.append(
            f"error_bound,T={t},holds,{int(e_actual <= bound)}"
        )
        csv_rows.append(
            f"error_bound,T={t},pinv_residual_inf,{nz:.4f}"
        )


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("\n".join(rows))
