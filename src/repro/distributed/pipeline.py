"""GPipe-style pipeline parallelism on a mesh axis, via shard_map + ppermute.

At 1000+-node scale the cross-pod ICI/DCN links are the scarce resource;
mapping pipeline stages onto the ``pod`` axis replaces the per-step gradient
all-reduce over the slow links with point-to-point activation transfers
(microbatch ping-pong), which is the standard multi-pod recipe. The schedule
here is the classic GPipe fill-drain expressed as a ``lax.scan`` over
``num_micro + num_stages - 1`` ticks:

    tick t, stage s computes microbatch (t - s); activations rotate to the
    next stage with one ``ppermute`` per tick.

Weights are stacked per-stage on the leading axis and sharded over the pipe
axis, so each device only holds (and only runs) its own stage's layers —
inside ``shard_map`` the stage picks its slice implicitly.

This module is mesh-shape agnostic: tests run it on a (4,)-device "pipe"
mesh (forced host devices); the production launcher maps it onto ``pod``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.4.35
    from jax.shard_map import shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map


def stack_stages(layer_params_list: list, num_stages: int):
    """[L layer pytrees] -> pytree with leading (num_stages, L/num_stages)."""
    L = len(layer_params_list)
    if L % num_stages:
        raise ValueError(f"{L} layers not divisible into {num_stages} stages")
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params_list)
    return jax.tree.map(
        lambda x: x.reshape(num_stages, L // num_stages, *x.shape[1:]), stacked
    )


def make_pipeline_forward(
    layer_fn: Callable,
    mesh: Mesh,
    axis: str = "pipe",
):
    """Build ``f(stage_params, microbatches) -> outputs``.

    ``layer_fn(layer_params, x) -> x`` is one layer; each stage scans it over
    its local layer stack. ``stage_params`` leaves are (S, L/S, ...), sharded
    over ``axis``; ``microbatches`` is (M, mb, ...) replicated. Output is
    (M, mb, ...) replicated (psum-broadcast from the last stage).
    """
    num_stages = mesh.shape[axis]

    def stage_fn(local_layers, x):
        def body(y, lp):
            return layer_fn(lp, y), None

        y, _ = jax.lax.scan(body, x, local_layers)
        return y

    def shard_body(stage_params, microbatches):
        # Inside shard_map: stage_params leaves are (1, L/S, ...) — this
        # stage's slice; microbatches (M, mb, ...) full (replicated).
        local_layers = jax.tree.map(lambda p: p[0], stage_params)
        s = jax.lax.axis_index(axis)
        num_micro = microbatches.shape[0]
        ticks = num_micro + num_stages - 1
        perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        zero = jnp.zeros_like(microbatches[0])

        def tick(carry, t):
            buf = carry  # activation handed to this stage this tick
            mb_idx = jnp.clip(t, 0, num_micro - 1)
            x_in = jnp.where(
                s == 0,
                jax.lax.dynamic_index_in_dim(
                    microbatches, mb_idx, 0, keepdims=False
                ),
                buf,
            )
            y = stage_fn(local_layers, x_in)
            nxt = jax.lax.ppermute(y, axis, perm)
            return nxt, y

        _, ys = jax.lax.scan(tick, zero, jnp.arange(ticks))
        # Last stage's outputs at ticks [S-1, S-1+M) are microbatches [0, M).
        outs = jax.lax.dynamic_slice_in_dim(ys, num_stages - 1, num_micro, 0)
        # Broadcast the last stage's result to every stage (cheap at test
        # scale; production computes the loss on the last stage instead).
        outs = jnp.where(s == num_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    def pipeline_forward(stage_params, microbatches):
        in_specs = (
            jax.tree.map(lambda _: P(axis), stage_params),
            P(),
        )
        fn = shard_map(
            shard_body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=P(),
            check_rep=False,
        )
        return fn(stage_params, microbatches)

    return pipeline_forward


def reference_forward(layer_fn: Callable, layer_params_list: list, x: jnp.ndarray):
    """Sequential oracle for the pipeline: run all layers on the full batch."""
    for lp in layer_params_list:
        x = layer_fn(lp, x)
    return x
