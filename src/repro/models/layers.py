"""Common neural layers (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rotary_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple:
    """positions (..., n) -> (sin, cos) of shape (..., n, head_dim/2)."""
    freqs = theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rotary(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x (..., n, d) with (sin, cos) (..., n, d/2); rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Fixed sinusoidal embeddings (whisper encoder)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_specs(d_model: int, d_ff: int, act: str) -> dict:
    if act == "swiglu":
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "ff")),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "ff")),
            "w_down": ParamSpec((d_ff, d_model), ("ff", "embed")),
        }
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "b_up": ParamSpec((d_ff,), ("ff",), init="zeros"),
        "w_down": ParamSpec((d_ff, d_model), ("ff", "embed")),
        "b_down": ParamSpec((d_model,), ("embed",), init="zeros"),
    }


def mlp_forward(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)
