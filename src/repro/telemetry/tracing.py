"""Tick-level tracing: lightweight host-side spans with JSONL export.

``with tracer.span("decode_tick", lane=i):`` records one event with
monotonic host timing (``time.perf_counter``) into a bounded in-memory
buffer — nesting depth is tracked so a JSONL dump reconstructs the tick
structure offline. Each span also feeds the ``span_seconds`` histogram
family in the attached metrics registry, so p50/p99 per span name ride in
the same snapshot as every other metric.

Two passthroughs surface spans in a *real* XLA profile when one is being
captured (``jax.profiler.trace``): ``annotate=True`` wraps every span in
``jax.profiler.TraceAnnotation``, and ``step_span`` uses
``StepTraceAnnotation`` so profilers group work by training step. Both
default off — annotation objects are cheap but not free, and serving ticks
are hot.

``NullTracer`` is the disabled twin: ``span()`` returns one shared no-op
context manager, records nothing, and ``dump_jsonl`` writes nothing.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional

from repro.telemetry.metrics import LATENCY_BUCKETS, MetricsRegistry


class _Span:
    """Context manager recording one event into the tracer's buffer."""

    __slots__ = ("tracer", "name", "labels", "annotation", "_t0")

    def __init__(self, tracer, name, labels, annotation):
        self.tracer = tracer
        self.name = name
        self.labels = labels
        self.annotation = annotation
        self._t0 = 0.0

    def __enter__(self):
        tl = self.tracer._tls
        tl.depth = getattr(tl, "depth", 0) + 1
        if self.annotation is not None:
            self.annotation.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        if self.annotation is not None:
            self.annotation.__exit__(*exc)
        tl = self.tracer._tls
        depth = tl.depth
        tl.depth = depth - 1
        self.tracer._record(self.name, self._t0, dur, depth - 1, self.labels)
        return False


class Tracer:
    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        *,
        annotate: bool = False,
        max_events: int = 200_000,
    ):
        self.annotate = annotate
        self.max_events = max_events
        self.events: list[dict] = []
        self.dropped = 0
        self._tls = threading.local()
        self._origin = time.perf_counter()
        self._span_hist = (
            registry.histogram(
                "span_seconds", help="host wall time per span name",
                labels=("span",), buckets=LATENCY_BUCKETS,
            )
            if registry is not None else None
        )

    @property
    def enabled(self) -> bool:
        return True

    def span(self, name: str, **labels) -> _Span:
        annotation = None
        if self.annotate:
            import jax

            annotation = jax.profiler.TraceAnnotation(name)
        return _Span(self, name, labels or None, annotation)

    def step_span(self, name: str, step: int):
        """Training-step span: same event record, but the XLA-profile
        passthrough uses ``StepTraceAnnotation`` so profilers bucket device
        work per step."""
        annotation = None
        if self.annotate:
            import jax

            annotation = jax.profiler.StepTraceAnnotation(name, step_num=step)
        return _Span(self, name, {"step": step}, annotation)

    def _record(self, name, t0, dur, depth, labels):
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        ev = {
            "name": name,
            "t": round(t0 - self._origin, 9),  # monotonic, tracer-relative
            "dur_s": round(dur, 9),
            "depth": depth,
        }
        if labels:
            ev["labels"] = labels
        self.events.append(ev)
        if self._span_hist is not None:
            self._span_hist.labels(span=name).observe(dur)

    def summary(self) -> dict:
        return {"events": len(self.events), "dropped": self.dropped}

    def dump_jsonl(self, fh) -> int:
        """Write one ``{"kind": "span", ...}`` line per event; returns the
        number of lines written."""
        n = 0
        for ev in self.events:
            fh.write(json.dumps({"kind": "span", **ev}) + "\n")
            n += 1
        return n


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    enabled = False
    events: list = []
    dropped = 0

    def span(self, name: str, **labels):
        return _NULL_SPAN

    def step_span(self, name: str, step: int):
        return _NULL_SPAN

    def summary(self) -> dict:
        return {"events": 0, "dropped": 0}

    def dump_jsonl(self, fh) -> int:
        return 0
