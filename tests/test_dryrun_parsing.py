"""Unit tests for the dry-run's HLO collective parser (pure string work —
safe to run alongside anything). The roofline numbers hang off this parser,
so it gets its own coverage."""
from __future__ import annotations

import os

# conftest initializes the jax backend (1 device) before this import, so the
# XLA_FLAGS side effect in repro.launch.dryrun cannot re-device this process.
_saved_flags = os.environ.get("XLA_FLAGS")
from repro.launch.dryrun import _shape_bytes, parse_collectives  # noqa: E402

if _saved_flags is None:
    os.environ.pop("XLA_FLAGS", None)  # don't leak 512 devices to children
else:
    os.environ["XLA_FLAGS"] = _saved_flags


class TestShapeBytes:
    def test_simple(self):
        assert _shape_bytes("f32[128,256]") == 128 * 256 * 4

    def test_bf16(self):
        assert _shape_bytes("bf16[2,4096,3584]") == 2 * 4096 * 3584 * 2

    def test_tuple_shapes(self):
        s = "(f32[8,8], bf16[16])"
        assert _shape_bytes(s) == 8 * 8 * 4 + 16 * 2

    def test_scalar(self):
        assert _shape_bytes("f32[]") == 4

    def test_pred(self):
        assert _shape_bytes("pred[64]") == 64


class TestParseCollectives:
    HLO = """
  %all-gather.1 = f32[256,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce.2 = bf16[128,128]{1,0} all-reduce(%p1), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%add
  %reduce-scatter.3 = f32[64]{0} reduce-scatter(%p2), channel_id=3, replica_groups=[1,256]<=[256], dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%p3), channel_id=4, source_target_pairs={{0,1}}
  %notacollective = f32[4]{0} add(%a, %b)
"""

    def test_counts_and_bytes(self):
        stats = parse_collectives(self.HLO)
        assert stats["all-gather"]["count"] == 1
        assert stats["all-gather"]["result_bytes"] == 256 * 1024 * 4
        assert stats["all-reduce"]["count"] == 1
        assert stats["reduce-scatter"]["count"] == 1
        assert stats["collective-permute"]["count"] == 1
        assert "add" not in stats

    def test_ring_factors(self):
        stats = parse_collectives(self.HLO)
        g = 16
        ag = stats["all-gather"]
        assert abs(ag["moved_bytes"] - ag["result_bytes"] * (g - 1) / g) < 1
        ar = stats["all-reduce"]
        assert abs(ar["moved_bytes"] - ar["result_bytes"] * 2 * (g - 1) / g) < 1
        rs = stats["reduce-scatter"]
        assert rs["moved_bytes"] == rs["result_bytes"] * (256 - 1)
        cp = stats["collective-permute"]
        assert cp["moved_bytes"] == cp["result_bytes"]

    def test_start_variants_counted(self):
        hlo = ("%ag = f32[64]{0} all-gather-start(%x), channel_id=9, "
               "replica_groups=[2,4]<=[8]")
        stats = parse_collectives(hlo)
        assert stats["all-gather"]["count"] == 1

    def test_empty(self):
        assert parse_collectives("") == {}
