"""LLaVA-NeXT-34B [hf:llava-hf]: anyres-tiling VLM; the vision tower is a
STUB (input_specs provides pre-extracted 1024-d patch features), projected
by a 2-layer MM adapter into a dense GQA decoder backbone."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, rope_theta=5e6,
    num_patches=2880,  # anyres: 4 tiles x 576 + base 576
    attention_impl="chunked",
)
