"""Tests for the paper's core math: ss_core (§4), pinv iteration (eq. 11),
matrix approximation models (§3/§4), Lemma 1 and Theorem 1."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.matrix_approx import (
    approximate_spsd,
    flat_tail_spsd,
    sample_columns,
)
from repro.core.pinv import iterative_pinv, svd_pinv
from repro.core.spectral_shift import ss_core


def _spsd(n=48, seed=0, cond=10.0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    lam = np.geomspace(cond, 1.0, n)
    return jnp.asarray((q * lam) @ q.T, jnp.float32)


def _softmax_core(c=32, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (c, 16)) * 0.5
    s = x @ x.T / 4.0
    p = jnp.exp(s - s.max(-1, keepdims=True))
    return p / p.sum(-1, keepdims=True)


class TestIterativePinv:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_converges_to_pinv(self, seed):
        a = _spsd(seed=seed)
        z = iterative_pinv(a, num_iters=14)
        ref = jnp.linalg.pinv(a)
        np.testing.assert_allclose(z, ref, atol=1e-3, rtol=1e-3)

    def test_penrose_conditions(self):
        a = _softmax_core()
        z = iterative_pinv(a, num_iters=14)
        np.testing.assert_allclose(a @ z @ a, a, atol=1e-3)
        np.testing.assert_allclose(z @ a @ z, z, atol=1e-3)

    def test_monotone_improvement(self):
        a = _spsd(cond=100.0)
        ref = jnp.linalg.pinv(a)
        errs = [
            float(jnp.linalg.norm(iterative_pinv(a, num_iters=t) - ref))
            for t in (2, 6, 12)
        ]
        assert errs[0] > errs[1] > errs[2], errs

    def test_batched(self):
        a = jnp.stack([_spsd(seed=s) for s in range(3)])
        z = iterative_pinv(a, num_iters=14)
        for i in range(3):
            np.testing.assert_allclose(
                z[i], jnp.linalg.pinv(a[i]), atol=1e-3, rtol=1e-3
            )


class TestSvdPinv:
    def test_full_rank(self):
        a = _spsd()
        pinv, keep, s = svd_pinv(a)
        assert bool(jnp.all(keep))
        np.testing.assert_allclose(pinv, jnp.linalg.pinv(a), atol=1e-4)

    def test_rank_deficient(self):
        # Rank-8 matrix: truncation must identify rank and invert stably.
        rng = np.random.default_rng(0)
        b = rng.normal(size=(32, 8)).astype(np.float32)
        a = jnp.asarray(b @ b.T)
        pinv, keep, s = svd_pinv(a, rank_tol=1e-4)
        assert int(keep.sum()) == 8
        np.testing.assert_allclose(a @ pinv @ a, a, atol=1e-3)


class TestSSCore:
    def test_svd_vs_iterative_well_conditioned(self):
        a = _softmax_core()
        c_svd = ss_core(a, method="svd", rank_tol=1e-6)
        c_it = ss_core(a, method="iterative", pinv_iters=16)
        np.testing.assert_allclose(c_svd.z, c_it.z, atol=1e-2, rtol=1e-2)

    def test_no_shift_degenerates_to_pinv(self):
        a = _softmax_core()
        core = ss_core(a, method="svd", use_shift=False)
        assert float(core.delta[..., 0, 0]) == 0.0
        np.testing.assert_allclose(core.u, core.z, atol=1e-6)

    def test_delta_nonnegative(self):
        for seed in range(4):
            a = _softmax_core(seed=seed)
            core = ss_core(a, method="iterative", pinv_iters=6)
            assert float(core.delta[..., 0, 0]) >= 0.0

    def test_delta_recovers_flat_tail(self):
        # Lemma-1 spectrum on the core itself: top-k head + flat tail theta.
        # Truncated-SVD delta must equal theta (mean of the discarded tail).
        n, k, theta = 32, 4, 0.25
        a = flat_tail_spsd(n, k, theta, seed=1)
        core = ss_core(a, method="svd", target_rank=k)
        assert abs(float(core.delta[..., 0, 0]) - theta) < 1e-4

    def test_u_closed_form(self):
        # U_ss = Z (I - delta Z) by construction.
        a = _softmax_core(seed=2)
        core = ss_core(a, method="svd")
        eye = jnp.eye(a.shape[-1])
        np.testing.assert_allclose(
            core.u, core.z @ (eye - core.delta * core.z), atol=1e-5
        )


class TestMatrixApprox:
    def test_lemma1_exact_reconstruction(self):
        """Lemma 1: flat-tail SPSD + c = O(k) columns => SS error == 0."""
        n, k, theta = 128, 8, 0.5
        K = flat_tail_spsd(n, k, theta, seed=0)
        cols = sample_columns(n, 16)
        approx = approximate_spsd(K, cols, "modified_ss_shifted", target_rank=k)
        rel = float(jnp.linalg.norm(K - approx) / jnp.linalg.norm(K))
        assert rel < 1e-4, rel

    def test_theorem1_ss_beats_prototype(self):
        """Theorem 1 under Lemma-1 conditions: SS strictly more accurate."""
        n, k, theta = 128, 8, 0.5
        K = flat_tail_spsd(n, k, theta, seed=0)
        cols = sample_columns(n, 16)
        err = lambda m: float(jnp.linalg.norm(
            K - approximate_spsd(K, cols, m, target_rank=k)
        ))
        assert err("modified_ss_shifted") < 1e-3 * err("prototype")

    def test_ss_beats_prototype_generic_flat_tails(self):
        """SS >= prototype across a sweep of tail heights (Frobenius)."""
        wins = 0
        for theta in (0.1, 0.3, 0.6, 1.0):
            K = flat_tail_spsd(96, 8, theta, seed=3)
            cols = sample_columns(96, 16)
            e_ss = float(jnp.linalg.norm(
                K - approximate_spsd(K, cols, "modified_ss_shifted", target_rank=8)
            ))
            e_ny = float(jnp.linalg.norm(
                K - approximate_spsd(K, cols, "prototype")
            ))
            wins += e_ss <= e_ny
        assert wins == 4

    def test_shift_identity_restores_rank(self):
        """Figure-2 claim: the SS approximation is NOT low-rank."""
        n, k, theta = 96, 8, 0.5
        K = flat_tail_spsd(n, k, theta, seed=0)
        cols = sample_columns(n, 16)
        proto = approximate_spsd(K, cols, "prototype")
        ss = approximate_spsd(K, cols, "modified_ss_shifted", target_rank=k)
        rank = lambda m: int(jnp.sum(jnp.linalg.svd(m, compute_uv=False) > 1e-4))
        assert rank(proto) <= 16          # prototype rank <= c
        assert rank(ss) >= n - 2          # shift-identity makes it full rank

    def test_delta_zero_reduces_to_prototype(self):
        K = _spsd(64)
        cols = sample_columns(64, 16)
        # With use_shift disabled inside ss_core the modified_ss model should
        # coincide with the prototype (same pinv path).
        proto = approximate_spsd(K, cols, "prototype", rank_tol=1e-6)
        from repro.core.pinv import svd_pinv

        c_mat = K[:, cols]
        a_mat = c_mat[cols, :]
        pinv, _, _ = svd_pinv(a_mat, rank_tol=1e-6)
        np.testing.assert_allclose(proto, c_mat @ pinv @ c_mat.T, atol=1e-4)
