"""Paged serving subsystem: block allocator invariants, paged-vs-dense
decode equivalence, batched-prefill-vs-token-replay equivalence, and the
preemption round-trip."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.decode import decode_step
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_cache import cache_specs
from repro.serve.paged import ZERO_BLOCK, BlockAllocator, PagedKVCache
from repro.serve.prefill import batched_prefill


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, seed=0, lo=4, hi=24, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        Request(
            u,
            rng.integers(3, cfg.vocab_size, int(rng.integers(lo, hi))).tolist(),
            max_new_tokens=max_new,
        )
        for u in range(n)
    ]


def _run(cfg, params, reqs, serve, stagger=0):
    eng = ServeEngine(cfg, params, serve=serve)
    for r in reqs[: len(reqs) - stagger]:
        eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
    if stagger:
        for _ in range(4):
            eng.tick()
        for r in reqs[len(reqs) - stagger:]:
            eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
    out = eng.run()
    return out, eng


BASE = ServeConfig(max_lanes=2, max_seq=64, block_size=8)
DENSE = dataclasses.replace(BASE, paged=False, batched_prefill=False)


# ==========================================================================
# BlockAllocator
# ==========================================================================
class TestBlockAllocator:
    def test_alloc_free_reuse(self):
        a = BlockAllocator(9, 8)  # 8 usable (block 0 reserved)
        got = a.alloc(1, 3)
        assert got is not None and len(got) == 3
        assert ZERO_BLOCK not in got
        assert a.num_free == 5
        assert a.alloc(2, 6) is None  # over budget: no state change
        assert a.num_free == 5 and 2 not in a.tables
        freed = a.free(1)
        assert sorted(freed) == sorted(got)
        assert a.num_free == 8
        # freed blocks come back (LIFO) and are never double-issued
        again = a.alloc(3, 8)
        assert sorted(again) == list(range(1, 9))
        assert a.alloc(4, 1) is None

    def test_tables_are_per_request(self):
        a = BlockAllocator(9, 4)
        a.alloc(7, 2)
        a.alloc(8, 2)
        assert set(a.tables[7]).isdisjoint(a.tables[8])
        a.alloc(7, 1)
        assert len(a.tables[7]) == 3  # growth appends

    def test_stats_and_utilization(self):
        a = BlockAllocator(9, 4)
        a.alloc(1, 4)
        st = a.stats()
        assert st["blocks_used"] == 4 and st["blocks_free"] == 4
        assert st["utilization"] == pytest.approx(0.5)

    def test_defragment_compacts_and_remaps(self):
        a = BlockAllocator(17, 8)
        a.alloc(1, 3)
        a.alloc(2, 4)
        a.alloc(3, 2)
        a.free(2)  # hole in the middle
        mapping = a.defragment()
        live = sorted(b for t in a.tables.values() for b in t)
        assert live == list(range(1, 6))  # compact prefix, block 0 untouched
        assert ZERO_BLOCK not in mapping and ZERO_BLOCK not in mapping.values()
        assert a.num_free == 16 - 5


# ==========================================================================
# Paged storage
# ==========================================================================
def test_paged_gather_matches_dense_roundtrip(qwen):
    """write_prefill -> gather_views reconstructs exactly the dense cache
    batched_prefill produced (modulo zero-padding past the prompt)."""
    cfg, params = qwen
    serve = BASE
    kv = PagedKVCache(cfg, serve)
    alloc = BlockAllocator(serve.resolved_num_blocks, serve.block_size)
    rng = np.random.default_rng(0)
    n = 19
    tokens = np.zeros((1, 32), np.int32)
    tokens[0, :n] = rng.integers(3, cfg.vocab_size, n)
    _, pcache = batched_prefill(
        params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
        seq_max=serve.max_seq,
    )
    alloc.alloc(0, alloc.blocks_for_tokens(n))
    tables = np.full((serve.max_lanes, serve.blocks_per_lane), ZERO_BLOCK,
                     np.int32)
    row = alloc.tables[0]
    tables[0, : len(row)] = row
    kv.write_prefill(0, pcache, tables[0], n_tokens=n)
    view = kv.gather_views(tables)

    k_dense = np.asarray(pcache["layers"][0]["k"] if isinstance(
        pcache["layers"], list) else pcache["layers"]["k"][0])
    k_view = np.asarray(view["layers"][0]["k"][0] if isinstance(
        view["layers"], list) else view["layers"]["k"][0][0])
    np.testing.assert_allclose(k_view[..., :32, :], k_dense, atol=0)
    assert np.all(k_view[..., 32:, :] == 0)  # unallocated -> zero block
    assert int(view["pos"][0]) == n


# ==========================================================================
# Engine equivalence
# ==========================================================================
def test_paged_vs_dense_token_identical(qwen):
    """Mixed batch, staggered arrivals: the paged/batched-prefill engine
    produces token-identical greedy outputs to the seed-style dense engine."""
    cfg, params = qwen
    reqs = _requests(cfg, 6, seed=1)
    ref, _ = _run(cfg, params, reqs, DENSE, stagger=3)
    out, eng = _run(cfg, params, reqs, BASE, stagger=3)
    assert ref == out
    st = eng.stats()
    assert st["finished"] == 6
    assert st["mode"] == "paged+batched-prefill"


def test_batched_prefill_matches_token_replay(qwen):
    """Cache state + next-token logits after batched prefill equal those
    after feeding the prompt token-by-token through decode_step."""
    cfg, params = qwen
    s_max = 64
    rng = np.random.default_rng(3)
    n = 21
    prompt = rng.integers(3, cfg.vocab_size, n)

    cache = init_params(cache_specs(cfg, 1, s_max), jax.random.PRNGKey(1))
    step = jax.jit(lambda c, t: decode_step(params, cfg, c, t))
    for i in range(n):
        replay_logits, cache = step(
            cache, jnp.asarray(prompt[None, i: i + 1], jnp.int32)
        )

    n_pad = 32
    tokens = np.zeros((1, n_pad), np.int32)
    tokens[0, :n] = prompt
    logits, pcache = batched_prefill(
        params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
        seq_max=s_max,
    )
    np.testing.assert_allclose(
        np.asarray(logits[0, n - 1], np.float32),
        np.asarray(replay_logits[0, 0], np.float32), atol=2e-4, rtol=2e-4,
    )
    assert int(pcache["pos"]) == n == int(cache["pos"])
    ref_l, new_l = cache["layers"], pcache["layers"]
    get = (lambda t, k: t[k]) if not isinstance(ref_l, list) else (
        lambda t, k: jnp.stack([la[k] for la in t])
    )
    # Layer 0 is a pure accumulation path (no upstream attention): cumsum
    # must match sequential _lmk_add to fp epsilon.
    for key in ("q_lmk", "k_lmk"):
        np.testing.assert_allclose(
            np.asarray(get(new_l, key))[0], np.asarray(get(ref_l, key))[0],
            atol=1e-4, rtol=1e-4,
        )
    np.testing.assert_array_equal(
        np.asarray(get(new_l, "k"))[0],
        np.asarray(get(ref_l, "k"))[0][..., :n_pad, :],
    )
    # Deeper layers inherit fp-reassociation noise amplified through the
    # layer-0 pseudoinverse (vmapped vs sequential attention); greedy
    # outputs stay identical (test_paged_vs_dense_token_identical).
    for key in ("q_lmk", "k_lmk"):
        np.testing.assert_allclose(
            np.asarray(get(new_l, key)), np.asarray(get(ref_l, key)),
            atol=5e-2, rtol=5e-2,
        )
    np.testing.assert_allclose(
        np.asarray(get(new_l, "k")),
        np.asarray(get(ref_l, "k"))[..., :n_pad, :], atol=5e-2, rtol=5e-2,
    )


def test_preemption_roundtrip_identical(qwen):
    """A pool too small for all lanes forces preemption; the preempted
    request restarts from scratch and still finishes with identical
    greedy output."""
    cfg, params = qwen
    reqs = _requests(cfg, 4, seed=2, lo=20, hi=21, max_new=30)
    serve = dataclasses.replace(BASE, max_lanes=3, num_blocks=12)
    ref, _ = _run(cfg, params, reqs, dataclasses.replace(
        DENSE, max_lanes=3))
    out, eng = _run(cfg, params, reqs, serve)
    st = eng.stats()
    assert st["preemptions"] > 0, "pool should have forced preemption"
    assert st["finished"] == 4
    assert ref == out
    assert st["kv"]["blocks_used"] == 0  # everything released at the end


def test_scheduler_metrics_and_ttft(qwen):
    """Batched prefill: first token lands one tick after admission, and the
    engine surfaces latency/utilization counters."""
    cfg, params = qwen
    reqs = _requests(cfg, 1, seed=4, lo=30, hi=31, max_new=4)
    _, eng = _run(cfg, params, reqs, BASE)
    st = eng.stats()
    assert st["ttft_ticks_p50"] == 1.0  # one tick: prefill + first sample
    assert st["new_tokens"] == 4
    _, eng_d = _run(cfg, params, reqs, DENSE)
    # token replay pays one tick per prompt token before the first sample
    assert eng_d.stats()["ttft_ticks_p50"] == float(len(reqs[0].prompt))


def test_ssm_family_falls_back_dense():
    """xLSTM has no sequence-shaped cache: the engine runs lane-dense with
    no allocator, and outputs match the seed configuration."""
    cfg = reduced(get_config("xlstm-350m"))
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    reqs = _requests(cfg, 3, seed=5)
    ref, _ = _run(cfg, params, reqs, DENSE)
    out, eng = _run(cfg, params, reqs, BASE)
    assert ref == out
    assert eng.stats()["mode"] == "dense+replay-prefill"
    assert "kv" not in eng.stats()


def test_defragment_mid_stream_preserves_outputs(qwen):
    """engine.defragment() between ticks permutes pool storage + tables
    consistently: in-flight requests finish with unchanged output."""
    cfg, params = qwen
    reqs = _requests(cfg, 4, seed=8, max_new=12)
    ref, _ = _run(cfg, params, reqs, DENSE)
    eng = ServeEngine(cfg, params, serve=BASE)
    for r in reqs:
        eng.submit(Request(r.uid, list(r.prompt), r.max_new_tokens))
    moved_total = 0
    for _ in range(60):
        if eng.sched.idle:
            break
        eng.tick()
        moved_total += eng.defragment()  # compact while requests in flight
    out = eng.run()
    assert ref == out
    # retirements between staggered requests leave holes, so compaction
    # must actually have moved something for this test to mean anything
    assert moved_total > 0


def test_ss_fused_prefill_runs(qwen):
    """The Pallas-kernel prefill path (approximate prompt attention) serves
    a batch end-to-end and leaves exact landmark state behind."""
    cfg, params = qwen
    reqs = _requests(cfg, 3, seed=6)
    serve = dataclasses.replace(BASE, prefill_impl="ss_fused")
    out, eng = _run(cfg, params, reqs, serve)
    assert eng.stats()["finished"] == 3
    assert all(len(v) > 0 for v in out.values())


# ==========================================================================
# Bucketed ss_fused prefill (key-validity masked kernels)
# ==========================================================================
def test_ss_fused_prefill_padding_invariant(qwen):
    """Bucket-padded ss_fused prefill == unpadded ss_fused prefill: the
    dynamic kv_valid bound keeps padded zero-keys out of the softmax, so
    logits at valid positions and the cache state are identical."""
    cfg, params = qwen
    s_max = 64
    rng = np.random.default_rng(11)
    n = 21  # > num_landmarks (16): the masked fused path
    prompt = rng.integers(3, cfg.vocab_size, n)

    def run(n_pad):
        tokens = np.zeros((1, n_pad), np.int32)
        tokens[0, :n] = prompt
        return batched_prefill(
            params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
            seq_max=s_max, prefill_impl="ss_fused",
        )

    logits_u, cache_u = run(n)       # unpadded reference
    logits_p, cache_p = run(32)      # bucket-padded
    np.testing.assert_allclose(
        np.asarray(logits_p[0, :n], np.float32),
        np.asarray(logits_u[0], np.float32), atol=1e-4, rtol=1e-4,
    )
    assert int(np.argmax(logits_p[0, n - 1])) == int(np.argmax(logits_u[0, n - 1]))
    get = (lambda t, k: jnp.stack([la[k] for la in t])) if isinstance(
        cache_u["layers"], list) else (lambda t, k: t[k])
    for key in ("q_lmk", "k_lmk"):
        np.testing.assert_allclose(
            np.asarray(get(cache_p["layers"], key), np.float32),
            np.asarray(get(cache_u["layers"], key), np.float32),
            atol=1e-4, rtol=1e-4,
        )
    np.testing.assert_allclose(
        np.asarray(get(cache_p["layers"], "k"))[..., :n, :],
        np.asarray(get(cache_u["layers"], "k"))[..., :n, :],
        atol=1e-4, rtol=1e-4,
    )


def test_ss_fused_bucket_size_token_identical(qwen):
    """Greedy engine outputs are invariant to the bucket size in ss_fused
    mode — padding is invisible end to end (prompts > num_landmarks so the
    masked kernels, not the degenerate exact path, are exercised)."""
    cfg, params = qwen
    reqs = _requests(cfg, 4, seed=9, lo=18, hi=30)
    outs = []
    for bucket in (8, 32):
        serve = dataclasses.replace(
            BASE, prefill_impl="ss_fused", prefill_bucket=bucket)
        out, eng = _run(cfg, params, reqs, serve)
        assert eng.stats()["finished"] == 4
        outs.append(out)
    assert outs[0] == outs[1]


def test_ss_fused_degenerate_prompt_unpadded(qwen):
    """Prompts of <= num_landmarks tokens take the exact-attention path and
    still serve correctly (the engine slices them to exact length)."""
    cfg, params = qwen
    reqs = _requests(cfg, 3, seed=10, lo=4, hi=16)  # all <= 16 landmarks
    serve = dataclasses.replace(BASE, prefill_impl="ss_fused")
    out, eng = _run(cfg, params, reqs, serve)
    assert eng.stats()["finished"] == 3
    assert all(len(v) > 0 for v in out.values())


def test_engine_warms_decode_plan(qwen):
    """ServeEngine resolves the decode-shape dispatch key at construction
    and surfaces the plan in stats()."""
    from repro.kernels import dispatch

    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    assert eng.decode_plan.impl in ("jnp", "fused", "interpret", "sharded")
    key = dispatch.make_key(
        BASE.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
        cfg.compute_dtype, True, family="decode",
    )
    assert key.family == "decode"
    # The heuristic decode plan routes to the jnp decode math.
    assert eng.decode_plan.impl == "jnp"
    assert eng.stats()["decode_plan"].startswith("jnp/")


def test_ss_fused_degenerate_padded_prompt_exact(qwen):
    """Regression: a bucket-padded window of <= num_landmarks tokens takes
    the exact path WITH the key-validity mask applied — padded zero-keys
    must not shift the logits or the next token."""
    cfg, params = qwen
    rng = np.random.default_rng(13)
    n = 5  # << num_landmarks (16)
    prompt = rng.integers(3, cfg.vocab_size, n)

    def run(n_pad):
        tokens = np.zeros((1, n_pad), np.int32)
        tokens[0, :n] = prompt
        return batched_prefill(
            params, cfg, jnp.asarray(tokens), jnp.asarray(n, jnp.int32),
            seq_max=64, prefill_impl="ss_fused",
        )

    logits_u, _ = run(n)
    logits_p, _ = run(8)
    np.testing.assert_allclose(
        np.asarray(logits_p[0, :n], np.float32),
        np.asarray(logits_u[0], np.float32), atol=1e-4, rtol=1e-4,
    )
    assert int(np.argmax(logits_p[0, n - 1])) == int(np.argmax(logits_u[0, n - 1]))


def test_engine_honors_autotune_cache_override(qwen, tmp_path):
    """Regression: ServeEngine's dispatch warm-up loads plans from
    ModelConfig.autotune_cache, like the Trainer does."""
    from repro.kernels import dispatch

    cfg, params = qwen
    cache = tmp_path / "tuned.json"
    key = dispatch.make_key(
        BASE.max_seq, cfg.num_landmarks, cfg.resolved_head_dim,
        cfg.compute_dtype, True, family="decode",
    )
    dispatch.clear_registry()
    dispatch.register_plan(
        key, dispatch.Plan(impl="jnp", block_n=64, source="autotuned"))
    dispatch.save_cache(str(cache))
    dispatch.clear_registry()
    try:
        eng = ServeEngine(
            dataclasses.replace(cfg, autotune_cache=str(cache)), params,
            serve=BASE,
        )
        assert eng.decode_plan.block_n == 64
        assert eng.decode_plan.source == "cache"
    finally:
        dispatch.clear_registry()  # drop the process-wide cache override
