"""Jitted wrapper: full spectral-shifting attention backed by Pallas kernels.

``ss_attention_fused(q, k, v, ...)`` computes the same function as
``repro.core.attention.spectral_shift_attention`` — including the
segment-causal variant — with the two O(n) GEMMs executed by the Pallas
kernels in ``ss_attention.py``:

    1. landmarks            (jnp: segment means, trivial)
    2. A_s, U_ss, delta     (jnp: c x c, O(c^3) — stays on jnp autodiff)
    3. BV                   (Pallas: landmark_summary, streamed over n)
    4. M = U_ss @ BV        (jnp: c x c @ c x dv)
    5. out = F @ M + d * V  (Pallas: query_side, streamed over n)

Steps 3 and 5 carry ``jax.custom_vjp`` rules backed by the flash-style
backward kernels in ``ss_attention_bwd.py``: the forward saves the online-
softmax statistics ``(m, l)`` (B-side) instead of any (c, n)/(n, c) factor,
and the backward reconstructs the softmax streams exactly from them. The
saved residuals are tagged with ``jax.ad_checkpoint.checkpoint_name``
(names ``"ss_bv"`` / ``"ss_stats"``) so the ``remat="ss_stats"`` policy in
models/model.py keeps only these tiny tensors across the layer boundary.

``jax.grad`` therefore flows end to end: through the custom-VJP kernels for
the O(n) streams and through ordinary jnp autodiff for the cubic-small
``ss_core`` (pinv + delta) and the landmark means.

Accepts (..., n, d) with arbitrary leading dims; leading dims are flattened
into the kernel batch dim.

``kv_valid`` (optional traced scalar) enables bucketed padding: only the
first ``kv_valid`` keys enter the landmark means and the B-side softmax, so
one XLA program serves every prompt length in a bucket (serve/prefill.py).
Maskless callers must pass exact-length windows — padded zero-keys would
otherwise leak into the softmax normalization. The context-parallel
(sequence-sharded) driver lives in ``kernels/sharded.py`` and reuses the
same kernels plus the core helper below.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from repro.core.attention import SSConfig, _softmax, full_attention
from repro.core.landmarks import masked_segment_means, segment_means
from repro.core.spectral_shift import ss_core
from repro.kernels.ss_attention import landmark_summary, query_side
from repro.kernels.ss_attention_bwd import landmark_summary_bwd, query_side_bwd


def _float0_like(x):
    """Cotangent for an integer-typed primal (or None passthrough)."""
    return None if x is None else np.zeros(jnp.shape(x), jax.dtypes.float0)


# --------------------------------------------------------------------------
# Online-softmax (flash) partial-state algebra, shared by every merge site:
# the context-parallel cross-shard combine (kernels/sharded.py) and the
# streaming decode state's per-token append (serve/decode_state.py).
#
# A partial state (m, l, acc) represents sum_j exp(s_j - m) for row max
# anchor m (l) and sum_j exp(s_j - m) * v_j (acc); the softmax output is
# acc / l. ``m`` need not be the true row max — any finite anchor gives the
# same normalized result — which is what makes the zeros-initialized empty
# state (m=0, l=0, acc=0) a valid identity element for ``flash_merge``.
# --------------------------------------------------------------------------
def flash_rescale(m, l, acc, m_new):
    """Re-anchor a partial state to ``m_new`` (>= m for stability).
    Returns the rescaled ``(l, acc)``; the new anchor is ``m_new``."""
    corr = jnp.exp(m - m_new)
    return l * corr, acc * corr


def flash_merge(m_a, l_a, acc_a, m_b, l_b, acc_b):
    """Merge two online-softmax partial states into one. Shapes broadcast;
    ``m``/``l`` carry a trailing singleton axis so the correction factors
    broadcast against ``acc`` (..., rows, dv)."""
    m = jnp.maximum(m_a, m_b)
    l_ar, acc_ar = flash_rescale(m_a, l_a, acc_a, m)
    l_br, acc_br = flash_rescale(m_b, l_b, acc_b, m)
    return m, l_ar + l_br, acc_ar + acc_br


# --------------------------------------------------------------------------
# Differentiable kernel ops. ``meta`` is a hashable tuple of static config;
# custom_vjp treats it as non-differentiable.
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def landmark_summary_op(meta, q_l, k, v, kv_valid=None):
    """Differentiable BV = softmax(Q~ K^T) @ V.  meta = (scale, block_n,
    block_c, causal, interpret). ``kv_valid`` (optional traced scalar) masks
    keys at positions >= kv_valid out of the softmax (bucketed prefill)."""
    scale, block_n, block_c, causal, interpret = meta
    return landmark_summary(
        q_l, k, v, scale=scale, block_n=block_n, block_c=block_c,
        causal=causal, interpret=interpret, kv_valid=kv_valid,
    )


def _landmark_summary_fwd(meta, q_l, k, v, kv_valid=None):
    scale, block_n, block_c, causal, interpret = meta
    bv, m, l = landmark_summary(
        q_l, k, v, scale=scale, block_n=block_n, block_c=block_c,
        causal=causal, interpret=interpret, return_stats=True,
        kv_valid=kv_valid,
    )
    res = (
        q_l, k, v,
        checkpoint_name(bv, "ss_bv"),
        checkpoint_name(m, "ss_stats"),
        checkpoint_name(l, "ss_stats"),
        kv_valid,
    )
    return bv, res


def _landmark_summary_bwd(meta, res, g):
    # block_c tiles the forward stream only; the backward kernel reconstructs
    # the softmax from the (m, l) stats with its own (full-c) block geometry.
    scale, block_n, _block_c, causal, interpret = meta
    q_l, k, v, bv, m, l, kv_valid = res
    dq, dk, dv = landmark_summary_bwd(
        q_l, k, v, bv, m, l, g, scale=scale, block_n=block_n, causal=causal,
        interpret=interpret, kv_valid=kv_valid,
    )
    return dq, dk, dv, _float0_like(kv_valid)


landmark_summary_op.defvjp(_landmark_summary_fwd, _landmark_summary_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def query_side_op(meta, q, k_l, m_mat, v, delta):
    """Differentiable out = softmax(Q K~^T) @ M + delta * V.  meta = (scale,
    block_n, causal, seq_len_k, interpret); ``delta`` must be fp32."""
    scale, block_n, causal, seq_len_k, interpret = meta
    return query_side(
        q, k_l, m_mat, v, delta, scale=scale, block_n=block_n, causal=causal,
        seq_len_k=seq_len_k, interpret=interpret,
    )


def _query_side_fwd(meta, q, k_l, m_mat, v, delta):
    out = query_side_op(meta, q, k_l, m_mat, v, delta)
    return out, (q, k_l, m_mat, v, delta)


def _query_side_bwd(meta, res, g):
    scale, block_n, causal, seq_len_k, interpret = meta
    q, k_l, m_mat, v, delta = res
    return query_side_bwd(
        q, k_l, m_mat, v, delta, g, scale=scale, block_n=block_n,
        causal=causal, seq_len_k=seq_len_k, interpret=interpret,
    )


query_side_op.defvjp(_query_side_fwd, _query_side_bwd)


# --------------------------------------------------------------------------
# The c x c spectral-shift core (jnp autodiff, replicated under sharding).
# --------------------------------------------------------------------------
def ss_core_factors(q_l, k_l, cfg: SSConfig, scale: float, n_k):
    """(U, delta) of the c x c core, exactly as the jnp reference computes
    them: fp32 softmax of the landmark score matrix, Newton–Schulz pinv +
    shift, the ``delta_scale="corrected"`` rescale, the ``eq10_literal``
    variant, and the causal lower-triangular projection.

    O(c^3)-small and batch-replicated, so the shard_map context-parallel
    driver (kernels/sharded.py) runs it unchanged per device on the
    psum-combined landmarks. ``n_k`` is the TRUE key length (may be traced
    under bucketed padding) — only the "corrected" rescale reads it.
    Returns fp32 ``u`` (..., c, c) and fp32 ``delta`` (..., 1, 1)."""
    c_count = q_l.shape[-2]
    a_mask = (
        jnp.arange(c_count)[:, None] >= jnp.arange(c_count)[None, :]
        if cfg.causal
        else None
    )
    a = _softmax(
        jnp.einsum(
            "...cd,...ed->...ce",
            q_l.astype(jnp.float32),
            k_l.astype(jnp.float32),
        )
        * scale,
        a_mask,
    )
    core = ss_core(
        a,
        method=cfg.method,
        pinv_iters=cfg.pinv_iters,
        rank_tol=cfg.rank_tol,
        use_shift=cfg.use_shift,
    )
    if cfg.delta_scale == "corrected" and cfg.use_shift:
        # Beyond-paper shift rescale — mirror spectral_shift_attention.
        core = core._replace(
            delta=core.delta * (c_count / n_k),
            u=jnp.matmul(
                core.z,
                jnp.eye(c_count, dtype=core.z.dtype)
                - (core.delta * (c_count / n_k)) * core.z,
            ),
        )
    if cfg.variant == "eq10_literal":
        u = jnp.matmul(
            core.z, jnp.eye(c_count, dtype=a.dtype) - core.delta * a
        )
    else:
        u = core.u
    if cfg.causal:
        # Exact pinv of the lower-triangular core is lower-triangular;
        # project the finite Newton–Schulz estimate back (no future leak).
        tril = jnp.tril(jnp.ones((c_count, c_count), bool))
        u = jnp.where(tril, u, 0.0)
    return u, core.delta


# --------------------------------------------------------------------------
# Full fused attention.
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "scale", "block_n", "block_c", "interpret"),
)
def ss_attention_fused(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig = SSConfig(),
    *,
    scale: Optional[float] = None,
    block_n: int = 512,
    block_c: int = 0,
    interpret: bool = False,
    kv_valid=None,
) -> jnp.ndarray:
    """Pallas-backed spectral-shifting attention. Shapes (..., n, d).

    Differentiable (custom-VJP kernels) and segment-causal capable —
    ``cfg.causal=True`` applies the same masks as the jnp reference path:
    the B-/F-side masks stream inside the kernels, the (c, c) core mask and
    the lower-triangular projection of U stay in jnp.

    ``kv_valid`` (optional traced scalar): treat only the first ``kv_valid``
    positions as real — landmark means and the B-side softmax mask out the
    padded tail, so a bucket-padded prompt computes exactly what the
    unpadded call would (outputs at positions >= kv_valid are garbage the
    caller discards). Bidirectional self-attention only.

    ``block_c`` (0 = all landmarks resident) tiles the B-side kernel's
    landmark rows across an extra grid axis — an autotune degree of freedom
    for large c * dv VMEM footprints (kernels/dispatch.py sweeps it).
    """
    *lead, n, d = q.shape
    n_k = k.shape[-2]
    dv = v.shape[-1]
    c = cfg.num_landmarks
    if kv_valid is not None:
        if cfg.causal:
            raise ValueError(
                "kv_valid masking supports the bidirectional (prefill) "
                "variant only; causal bucketing needs dynamic segment masks"
            )
        if n != n_k:
            raise ValueError("kv_valid masking requires self-attention (n == n_k)")
        if n <= c:
            # Assert-guard for the exact-attention degenerate path: it has
            # no key-validity mask, so padded windows would leak — callers
            # (serve/engine.py) must slice tiny prompts to exact length.
            raise ValueError(
                f"kv_valid masking needs padded n ({n}) > num_landmarks "
                f"({c}); run degenerate prompts unpadded instead"
            )
    if n <= c and n_k <= c:
        # Degenerate small-n regime: exact attention, as the jnp path does.
        return full_attention(q, k, v, causal=cfg.causal, scale=scale)
    scale = scale if scale is not None else 1.0 / (d**0.5)
    b = 1
    for s_ in lead:
        b *= s_
    qf = q.reshape(b, n, d)
    kf = k.reshape(b, n_k, d)
    vf = v.reshape(b, n_k, dv)

    if kv_valid is not None:
        kv_valid = jnp.asarray(kv_valid, jnp.int32)
        # Dynamic-length landmark means: identical to segment_means on the
        # sliced prompt, but shape-static across the bucket.
        q_l = masked_segment_means(qf, c, kv_valid)
        k_l = masked_segment_means(kf, c, kv_valid)
    else:
        q_l = segment_means(qf, c, via_matmul=cfg.landmark_via_matmul)  # (b, c, d)
        k_l = segment_means(kf, c, via_matmul=cfg.landmark_via_matmul)
    if q_l.shape[-2] != k_l.shape[-2]:
        # Mirror the jnp path's guard: n_q <= c < n_k degenerates Q~ to
        # per-token landmarks and the (c, c) core goes rectangular.
        raise ValueError(
            "spectral-shift attention needs matching landmark counts for Q~ "
            f"and K~, got {q_l.shape[-2]} vs {k_l.shape[-2]}. For decode "
            "(n_q=1) use the jnp path with cached q_landmarks/k_landmarks."
        )

    # c x c core in jnp (fp32 softmax), causally masked like _ss_factors.
    # Under bucketed padding the key length the delta_scale="corrected"
    # rescale sees must be the TRUE prompt length, not the padded shape.
    u, delta_core = ss_core_factors(
        q_l, k_l, cfg, scale, n_k if kv_valid is None else kv_valid
    )

    bv = landmark_summary_op(
        (scale, block_n, block_c, cfg.causal, interpret), q_l, kf, vf,
        kv_valid,
    )  # (b, c, dv)
    m_mat = jnp.matmul(u.astype(jnp.float32), bv.astype(jnp.float32)).astype(
        v.dtype
    )
    if cfg.include_shift_identity and n <= n_k:
        # + delta_ss I_n -> + delta_ss * V on the query-aligned rows of V
        # (decode convention: queries are the last n positions of the
        # n_k-long context; self-attention is the n == n_k case).
        delta = delta_core.astype(jnp.float32)
        v_q = vf if n == n_k else vf[:, n_k - n :]
    else:
        delta = jnp.zeros((b, 1, 1), jnp.float32)
        v_q = vf if n == n_k else jnp.zeros((b, n, dv), vf.dtype)
    out = query_side_op(
        (scale, block_n, cfg.causal, n_k, interpret),
        qf, k_l, m_mat, v_q, delta,
    )
    return out.reshape(*lead, n, dv)


@functools.partial(
    jax.jit, static_argnames=("cfg", "scale", "block_n", "interpret")
)
def nystrom_attention_fused(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: SSConfig = SSConfig(use_shift=False, include_shift_identity=False),
    *,
    scale: Optional[float] = None,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas-backed Nystromformer baseline (delta = 0)."""
    import dataclasses

    cfg = dataclasses.replace(cfg, use_shift=False, include_shift_identity=False)
    return ss_attention_fused(
        q, k, v, cfg, scale=scale, block_n=block_n, interpret=interpret
    )
