"""Pallas TPU kernels for spectral-shifting attention (DESIGN.md §3).

Two kernels cover the only O(n) GEMMs in the method; everything else is
O(c^2)-small and stays in jnp:

* ``landmark_summary``  (B-side): ``BV = softmax(Q~ K^T) @ V``. The c landmark
  queries are VMEM-resident; K/V stream HBM->VMEM in ``block_n`` chunks with
  the online-softmax (flash) recurrence, so no (c, n) intermediate ever
  exists. Grid = (batch, n_blocks), n innermost so the fp32 accumulators in
  VMEM scratch persist across the stream.

* ``query_side`` (F-side): ``out = softmax(Q K~^T) @ M + delta * V`` with
  ``M = U_ss (BV)`` (c x dv, VMEM-resident). Softmax axis is c (fully
  resident) so each Q/V block needs exactly one HBM read and one write —
  the (n, c) matrix F is never materialized.

Block shapes default to MXU/VPU-aligned sizes (lane dim = head_dim, ideally
a multiple of 128; sublane blocks multiples of 8). Kernels are validated on
CPU in interpret mode against ``ref.py``; TPU is the compile target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# B-side: landmark summary with online softmax over the streamed n axis.
# --------------------------------------------------------------------------
def _landmark_summary_kernel(
    q_ref,  # (1, c, d)    VMEM
    k_ref,  # (1, bn, d)   VMEM (streamed)
    v_ref,  # (1, bn, dv)  VMEM (streamed)
    o_ref,  # (1, c, dv)   VMEM
    m_scr,  # (c, 1)       fp32 scratch: running max
    l_scr,  # (c, 1)       fp32 scratch: running denominator
    acc_scr,  # (c, dv)    fp32 scratch: running numerator
    *,
    scale: float,
    n_valid: int,
    block_n: int,
):
    i = pl.program_id(1)
    n_blocks = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (c, d)
    k = k_ref[0].astype(jnp.float32)                      # (bn, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (c, bn)

    # Mask keys past the true sequence end (zero-padded tail block).
    if n_valid % block_n:
        kv_pos = i * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_pos < n_valid, s, _NEG_INF)

    m_prev = m_scr[...]                                   # (c, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (c, bn)
    corr = jnp.exp(m_prev - m_new)                        # (c, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (c, dv)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new

    @pl.when(i == n_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def landmark_summary(
    q_l: jnp.ndarray,  # (b, c, d)
    k: jnp.ndarray,    # (b, n, d)
    v: jnp.ndarray,    # (b, n, dv)
    *,
    scale: float,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """BV = softmax(Q~ K^T * scale) @ V via a flash-style streamed kernel."""
    b, c, d = q_l.shape
    n, dv = k.shape[1], v.shape[2]
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        k = jnp.pad(k, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    kernel = functools.partial(
        _landmark_summary_kernel, scale=scale, n_valid=n, block_n=block_n
    )
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q_l, k, v)


# --------------------------------------------------------------------------
# F-side: fused softmax(Q K~^T) @ M + delta * V over streamed Q/V blocks.
# --------------------------------------------------------------------------
def _query_side_kernel(
    q_ref,      # (1, bn, d)   VMEM (streamed)
    kl_ref,     # (1, c, d)    VMEM
    m_ref,      # (1, c, dv)   VMEM
    v_ref,      # (1, bn, dv)  VMEM (streamed)
    delta_ref,  # (1, 1, 1)    SMEM-ish scalar block
    o_ref,      # (1, bn, dv)  VMEM
    *,
    scale: float,
):
    q = q_ref[0].astype(jnp.float32)                      # (bn, d)
    kl = kl_ref[0].astype(jnp.float32)                    # (c, d)
    s = jax.lax.dot_general(
        q, kl, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (bn, c)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, m_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (bn, dv)
    out = out + delta_ref[0, 0, 0] * v_ref[0].astype(jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


def query_side(
    q: jnp.ndarray,      # (b, n, d)
    k_l: jnp.ndarray,    # (b, c, d)
    m_mat: jnp.ndarray,  # (b, c, dv)
    v: jnp.ndarray,      # (b, n, dv)
    delta: jnp.ndarray,  # (b, 1, 1)
    *,
    scale: float,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """out = softmax(Q K~^T * scale) @ M + delta * V, one HBM pass over Q/V."""
    b, n, d = q.shape
    c, dv = k_l.shape[1], v.shape[2]
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        q = jnp.pad(q, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    kernel = functools.partial(_query_side_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
            pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, i: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n + n_pad, dv), q.dtype),
        interpret=interpret,
    )(q, k_l, m_mat, v, delta.astype(jnp.float32))
    return out[:, :n] if n_pad else out
