"""Metrics registry: counters, gauges and fixed-bucket histograms.

One registry backs every ``stats()`` view in the serving/training stack so
aggregate bookkeeping lives in exactly one place. Three metric kinds:

* ``Counter`` — monotone float, ``inc(n)``;
* ``Gauge`` — point-in-time value, either ``set(v)`` or a zero-hot-path
  callback (``fn=...``) evaluated only when the gauge is *read*;
* ``Histogram`` — fixed bucket bounds, so p50/p90/p99 are derivable from
  the per-bucket counts without storing samples. Percentiles are reported
  as the **upper bound of the bucket holding the target rank** (the
  conservative Prometheus-style estimate); when every observation in range
  shares one value the reported percentile is exact, which keeps
  tick-valued histograms (unit buckets) exact for the scheduler's
  TTFT/latency views.

Labeled *families* let one metric name cover a whole ``impl|mode|horizon``
grid: ``registry.counter("x", labels=("impl",)).labels(impl="paged").inc()``
— children are created on first use and share the family's buckets/help.

``NullRegistry`` mirrors the full API with shared no-op objects: metric
calls on it are attribute lookups that drop their arguments, it never
retains a reference to anything, and ``snapshot()`` is ``{}`` — the
zero-overhead backing for disabled telemetry (``ServeConfig.telemetry``)
and for the import-time default in ``kernels/dispatch.py``.
"""
from __future__ import annotations

import bisect
import threading
from typing import Callable, Optional, Sequence


def exp_buckets(lo: float, hi: float, per_decade: int = 3) -> tuple[float, ...]:
    """Exponential bucket bounds from ``lo`` to >= ``hi`` with
    ``per_decade`` bounds per decade (3 -> 1, 2.15, 4.64 pattern)."""
    if lo <= 0 or hi <= lo:
        raise ValueError("need 0 < lo < hi")
    out = []
    b = lo
    factor = 10.0 ** (1.0 / per_decade)
    while b < hi * (1 + 1e-9):
        out.append(b)
        b *= factor
    return tuple(out)


# Wall-clock latencies (seconds): 20 us .. ~100 s.
LATENCY_BUCKETS = exp_buckets(2e-5, 100.0, per_decade=4)
# Engine-tick counts: exact up to 64 ticks (unit buckets), then pow2.
TICK_BUCKETS = tuple(float(i) for i in range(1, 65)) + tuple(
    float(2 ** i) for i in range(7, 15)
)
# Dimensionless ratios in [0, 1]-ish (drift residuals, occupancy).
RATIO_BUCKETS = exp_buckets(1e-6, 10.0, per_decade=3)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def sample(self) -> dict:
        return {"value": self.value}


class Gauge:
    __slots__ = ("_value", "fn")

    def __init__(self, fn: Optional[Callable[[], float]] = None):
        self._value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        if self.fn is not None:
            return float(self.fn())
        return self._value

    def sample(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bound histogram; final overflow bucket is implicit (+inf)."""

    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS):
        bounds = tuple(float(b) for b in bounds)
        if list(bounds) != sorted(set(bounds)) or not bounds:
            raise ValueError("histogram bounds must be sorted and distinct")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # [..., overflow]
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        # bucket i covers (bounds[i-1], bounds[i]]
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v

    def percentile(self, p: float) -> Optional[float]:
        """Upper bound of the bucket holding rank ceil(p% of count); the
        overflow bucket reports the largest finite bound. None when empty."""
        if self.count == 0:
            return None
        target = (p / 100.0) * self.count
        cum = 0
        for i, n in enumerate(self.counts):
            cum += n
            if n > 0 and cum >= target:
                return self.bounds[min(i, len(self.bounds) - 1)]
        return self.bounds[-1]

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def sample(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class Family:
    """Labeled metric family: one name, one child metric per label-set."""

    def __init__(self, make: Callable[[], object], label_names: tuple[str, ...]):
        self._make = make
        self.label_names = label_names
        self.children: dict[tuple, object] = {}

    def labels(self, **kv):
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"expected labels {self.label_names}, got {tuple(kv)}"
            )
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = self._make()
            return child
        return child


class MetricsRegistry:
    """Name -> metric (or labeled family). Registration is idempotent:
    re-registering a name returns the existing object (kind mismatch
    raises), so modules can declare their metrics independently."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, tuple[str, object, str]] = {}  # kind, obj, help

    def _register(self, name, kind, make, labels, help):
        with self._lock:
            hit = self._metrics.get(name)
            if hit is not None:
                if hit[0] != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {hit[0]}"
                    )
                return hit[1]
            obj = Family(make, tuple(labels)) if labels else make()
            self._metrics[name] = (kind, obj, help)
            return obj

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()):
        return self._register(name, "counter", Counter, labels, help)

    def gauge(self, name: str, help: str = "", labels: Sequence[str] = (),
              fn: Optional[Callable[[], float]] = None):
        return self._register(name, "gauge", lambda: Gauge(fn), labels, help)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS):
        return self._register(
            name, "histogram", lambda: Histogram(buckets), labels, help
        )

    def get(self, name: str):
        hit = self._metrics.get(name)
        return hit[1] if hit else None

    # -- export ---------------------------------------------------------------
    def iter_samples(self):
        """Yield ``(name, kind, labels_dict, sample_dict)`` for every child
        metric (families expand to one row per label-set)."""
        with self._lock:
            items = list(self._metrics.items())
        for name, (kind, obj, _help) in items:
            if isinstance(obj, Family):
                for key, child in sorted(obj.children.items()):
                    yield name, kind, dict(zip(obj.label_names, key)), \
                        child.sample()
            else:
                yield name, kind, {}, obj.sample()

    def snapshot(self) -> dict:
        """Nested dict view: ``{name: sample}`` for plain metrics,
        ``{name: {"label=v,...": sample}}`` for families."""
        out: dict = {}
        for name, _kind, labels, sample in self.iter_samples():
            if labels:
                key = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                out.setdefault(name, {})[key] = sample
            else:
                out[name] = sample
        return out


# --------------------------------------------------------------------------
# The zero-overhead null implementation.
# --------------------------------------------------------------------------
class _NullMetric:
    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, p: float):
        return None

    def labels(self, **kv):
        return self

    value = 0.0
    count = 0
    sum = 0.0

    def sample(self) -> dict:
        return {}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """API-compatible no-op registry: every call returns the one shared
    null metric, nothing is retained, ``snapshot()`` is empty."""

    def counter(self, name, help="", labels=()):
        return _NULL_METRIC

    def gauge(self, name, help="", labels=(), fn=None):
        return _NULL_METRIC

    def histogram(self, name, help="", labels=(), buckets=LATENCY_BUCKETS):
        return _NULL_METRIC

    def get(self, name):
        return None

    def iter_samples(self):
        return iter(())

    def snapshot(self) -> dict:
        return {}
