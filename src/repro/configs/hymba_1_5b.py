"""Hymba-1.5B [arXiv:2411.13676]: parallel attention + mamba heads."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, ssm_state=16, conv_width=4,
    attention_impl="chunked",
)
