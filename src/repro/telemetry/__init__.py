"""Unified telemetry: metrics registry + tick tracing + drift monitors.

``Telemetry`` is the one object the engine/trainer/benchmarks hold. It
bundles a :class:`~repro.telemetry.metrics.MetricsRegistry` and a
:class:`~repro.telemetry.tracing.Tracer` and exposes the two export paths
the rest of the stack (and CI) consume:

* ``snapshot()`` — nested dict of every metric sample plus span-buffer
  counters; cheap, safe to call mid-run.
* ``dump_jsonl(path)`` — one self-describing JSONL file: a ``meta`` line,
  one ``metric`` line per (name, label-set), one ``span`` line per traced
  event. This is the artifact CI uploads and the offline-analysis input.

``Telemetry(enabled=False)`` (or :func:`null_telemetry`) swaps in the
no-op registry/tracer pair: every instrumentation site still *calls*
telemetry, but each call is a shared-object no-op, nothing is retained,
and dumps write nothing — the zero-overhead contract behind the
``ServeConfig.telemetry`` knob.

The PR 7 observability layer adds three more members to the bundle:

* ``flight`` — a :class:`~repro.telemetry.flight.FlightRecorder` holding
  one bounded lifeline per request; its lifelines are appended to the
  JSONL dump as ``{"kind": "flight", ...}`` lines and drive the request
  tracks in the Perfetto export (:mod:`repro.telemetry.export`);
* ``meta_defaults`` — the provenance stamp (git SHA, jax version,
  config hash) merged into every ``dump_jsonl`` meta line and exported
  trace; populate it via :func:`stamp_provenance`;
* the flight recorder shares the tracer's ``perf_counter`` origin so
  lifelines and host spans share one timeline.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.telemetry.accounting import (  # noqa: F401
    NullNumericsProbe,
    NumericsProbe,
    XLAAccounting,
    compiled_cost,
    install_compile_listener,
    tagged_program,
)
from repro.telemetry.export import (  # noqa: F401
    chrome_trace,
    validate_trace,
    write_chrome_trace,
)
from repro.telemetry.flight import FlightRecorder, NullFlightRecorder  # noqa: F401
from repro.telemetry.metrics import (  # noqa: F401  (re-exports)
    LATENCY_BUCKETS,
    RATIO_BUCKETS,
    TICK_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    exp_buckets,
)
from repro.telemetry.monitors import (  # noqa: F401
    DriftMonitor,
    SpectrumMonitor,
    bv_from_stats,
    bv_row_residual,
    spectrum_mass,
)
from repro.telemetry.provenance import config_hash, git_sha, provenance  # noqa: F401
from repro.telemetry.tracing import NullTracer, Tracer  # noqa: F401


class Telemetry:
    """Bundle of one metrics registry + one tracer with JSONL export."""

    def __init__(
        self,
        enabled: bool = True,
        *,
        registry: Optional[MetricsRegistry] = None,
        annotate: bool = False,
        max_events: int = 200_000,
    ):
        self.enabled = enabled
        self.meta_defaults: dict = {}
        if enabled:
            self.metrics = registry if registry is not None else MetricsRegistry()
            self.tracer = Tracer(
                self.metrics, annotate=annotate, max_events=max_events
            )
            self.flight = FlightRecorder(
                registry=self.metrics, origin=self.tracer._origin
            )
        else:
            self.metrics = NullRegistry()
            self.tracer = NullTracer()
            self.flight = NullFlightRecorder()

    def stamp_provenance(self, *cfgs) -> None:
        """Record the provenance stamp (git SHA, jax version, and the
        joint hash of ``cfgs``) into ``meta_defaults`` so every later
        ``dump_jsonl``/trace export carries it."""
        if self.enabled:
            self.meta_defaults.update(provenance(*cfgs))

    def span(self, name: str, **labels):
        return self.tracer.span(name, **labels)

    def step_span(self, name: str, step: int):
        return self.tracer.step_span(name, step)

    def snapshot(self) -> dict:
        return {"metrics": self.metrics.snapshot(), "spans": self.tracer.summary()}

    def dump_jsonl(self, path, meta: Optional[dict] = None) -> int:
        """Write the full telemetry state as JSONL; returns lines written.
        Disabled telemetry writes nothing (and creates no file)."""
        if not self.enabled:
            return 0
        n = 0
        with open(path, "w") as fh:
            head = {"kind": "meta", "schema": "repro-telemetry-v1"}
            head.update(self.meta_defaults)
            if meta:
                head.update(meta)
            fh.write(json.dumps(head) + "\n")
            n += 1
            for name, kind, labels, sample in self.metrics.iter_samples():
                row = {"kind": "metric", "name": name, "type": kind}
                if labels:
                    row["labels"] = labels
                row.update(sample)
                fh.write(json.dumps(row) + "\n")
                n += 1
            n += self.tracer.dump_jsonl(fh)
            n += self.flight.dump_jsonl(fh)
        return n


def null_telemetry() -> Telemetry:
    return Telemetry(enabled=False)
