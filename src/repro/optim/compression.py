"""Gradient compression for DP all-reduce at 1000+-node scale.

int8 per-tensor quantized all-reduce with error feedback (EF-SGD style):
each step transmits int8 (4x less than fp32) plus one fp32 scale; the
quantization residual is carried host-side and added back next step, so the
method is unbiased in the long run and known to preserve convergence.

``compressed_psum`` is the shard_map collective (quantize -> psum -> dequant)
for explicit-collective training loops; ``compress_tree``/``decompress`` are
the pure pieces, unit-tested in isolation.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jnp.ndarray      # int8 payload
    scale: jnp.ndarray  # () fp32


def compress(x: jnp.ndarray, residual: jnp.ndarray | None = None):
    """x (+ carried residual) -> (Compressed, new_residual)."""
    x32 = x.astype(jnp.float32)
    if residual is not None:
        x32 = x32 + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    new_residual = x32 - q.astype(jnp.float32) * scale
    return Compressed(q=q, scale=scale), new_residual


def decompress(c: Compressed) -> jnp.ndarray:
    return c.q.astype(jnp.float32) * c.scale


def compressed_psum(x: jnp.ndarray, axis_name: str, residual=None):
    """Quantized all-reduce over ``axis_name`` (use inside shard_map).

    int8 payloads are summed in int32 (no overflow for <= 2^23 participants),
    scales are mean-combined — a standard, cheap approximation of per-shard
    dequant-then-sum that keeps the wire format at 1 byte/element.
    """
    c, new_res = compress(x, residual)
    qsum = jax.lax.psum(c.q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(c.scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = qsum.astype(jnp.float32) * (ssum / n)
    return out, new_res


def make_compressed_grad_allreduce(mesh, axis_name: str = "data"):
    """Returns f(grads_tree, residual_tree) -> (reduced_tree, new_residuals),
    running the quantized all-reduce via shard_map over ``axis_name``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def _reduce(grads, residuals):
        def inner(g_tree, r_tree):
            outs = jax.tree.map(
                lambda g, r: compressed_psum(g, axis_name, r), g_tree, r_tree
            )
            reduced = jax.tree.map(lambda t: t[0] / 1.0, outs,
                                   is_leaf=lambda x: isinstance(x, tuple))
            new_res = jax.tree.map(lambda t: t[1], outs,
                                   is_leaf=lambda x: isinstance(x, tuple))
            return reduced, new_res

        spec = jax.tree.map(lambda _: P(), grads)
        return shard_map(
            inner, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check_rep=False,
        )(grads, residuals)

    return _reduce
