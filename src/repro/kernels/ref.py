"""Pure-jnp oracles for the spectral-shifting Pallas kernels.

Each function mirrors one kernel's contract exactly (same shapes, same fp32
accumulation, same output dtype) so tests can ``assert_allclose`` against
them across shape/dtype sweeps.
"""
from __future__ import annotations

import jax.numpy as jnp


def ref_landmark_summary(
    q_l: jnp.ndarray,  # (b, c, d)   landmark queries Q~
    k: jnp.ndarray,    # (b, n, d)
    v: jnp.ndarray,    # (b, n, dv)
    scale: float,
) -> jnp.ndarray:
    """B-side oracle: softmax(Q~ K^T * scale) @ V -> (b, c, dv)."""
    s = jnp.einsum(
        "bcd,bnd->bcn", q_l.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bcn,bnd->bcd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def ref_query_side(
    q: jnp.ndarray,      # (b, n, d)
    k_l: jnp.ndarray,    # (b, c, d)   landmark keys K~
    m_mat: jnp.ndarray,  # (b, c, dv)  M = U_ss @ (B @ V)
    v: jnp.ndarray,      # (b, n, dv)
    delta: jnp.ndarray,  # (b, 1, 1)
    scale: float,
) -> jnp.ndarray:
    """F-side oracle: softmax(Q K~^T * scale) @ M + delta * V -> (b, n, dv)."""
    s = jnp.einsum(
        "bnd,bcd->bnc", q.astype(jnp.float32), k_l.astype(jnp.float32)
    ) * scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bnc,bcd->bnd", p, m_mat.astype(jnp.float32))
    out = out + delta.astype(jnp.float32) * v.astype(jnp.float32)
    return out.astype(q.dtype)
