"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 50 --batch 8 --seq 256

On this CPU container ``--reduced`` shrinks the arch to smoke scale and runs
on a local mesh; on a real cluster the same entry point builds the
production mesh (``--mesh prod`` / ``--mesh prod-multipod``) and every step
function, sharding rule and checkpoint path is identical — the dry-run
(launch/dryrun.py) proves those configurations compile for every assigned
(arch × shape) cell.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging

import jax

from repro.configs.base import SHAPE_PRESETS, ShapeConfig, TrainConfig, reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed.fault_tolerance import FailureInjector
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCH_IDS + ["paper-bert"])
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPE_PRESETS))
    ap.add_argument("--reduced", action="store_true",
                    help="shrink to smoke scale (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=0, help="override global batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq len")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attention", default=None,
                    help="override training attention impl")
    ap.add_argument("--mesh", default="local", choices=["local", "prod", "prod-multipod"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a simulated host failure at this step")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.attention:
        cfg = dataclasses.replace(cfg, attention_impl=args.attention)

    preset = SHAPE_PRESETS[args.shape]
    shape = ShapeConfig(
        name=preset.name,
        seq_len=args.seq or preset.seq_len,
        global_batch=args.batch or preset.global_batch,
        kind="train",
    )
    tcfg = TrainConfig(
        learning_rate=args.lr,
        total_steps=max(args.steps, 10),
        warmup_steps=max(args.steps // 10, 1),
        microbatches=args.microbatches,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
    )
    if args.mesh == "local":
        mesh = make_local_mesh(args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")

    injector = (
        FailureInjector({args.fail_at: ["host0"]}) if args.fail_at else None
    )
    trainer = Trainer(cfg, tcfg, shape, mesh, injector=injector)
    history = trainer.run(args.steps)
    trainer.save(blocking=True)

    first, last = history[0], history[-1]
    print(
        f"[train] {args.arch} steps={len(history)} "
        f"loss {first['loss']:.4f} -> {last['loss']:.4f} "
        f"(mean step {sum(h['step_time_s'] for h in history)/len(history):.3f}s)"
    )
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=2)
    return history


if __name__ == "__main__":
    main()
