"""Property-based tests (hypothesis) on the system's invariants."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

from repro.core.landmarks import segment_means, segment_of
from repro.core.pinv import iterative_pinv
from repro.core.spectral_shift import ss_core

pytestmark = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis not installed")

if HAVE_HYP:
    _settings = settings(max_examples=25, deadline=None)
else:  # decorators below still need *some* callable at collection time
    def _settings(fn):
        return fn

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    given = lambda *a, **k: (lambda fn: fn)  # noqa: E731
    st = _St()


def _np_x(n, d, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)), jnp.float32)


class TestSegmentMeans:
    @_settings
    @given(
        n=st.integers(4, 200),
        m=st.integers(1, 32),
        d=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 100),
    )
    def test_global_mean_preserved(self, n, m, d, seed):
        """Count-weighted mean of landmarks == mean of all tokens."""
        x = _np_x(n, d, seed)
        lm = segment_means(x, m)
        if n <= m:  # degenerate: identity
            np.testing.assert_allclose(lm, x, atol=1e-6)
            return
        seg = -(-n // m)
        counts = np.clip(n - np.arange(m) * seg, 1, seg).astype(np.float32)
        # Zero-token segments contribute nothing (mean is 0/num irrelevant):
        valid = (n - np.arange(m) * seg) > 0
        w_mean = (np.asarray(lm[valid]) * counts[valid, None]).sum(0) / n
        np.testing.assert_allclose(w_mean, np.asarray(x).mean(0), atol=1e-4)

    @_settings
    @given(
        n=st.integers(8, 128),
        m=st.integers(2, 16),
        seed=st.integers(0, 50),
    )
    def test_linearity(self, n, m, seed):
        """segment_means(a*x + y) == a*segment_means(x) + segment_means(y)."""
        x = _np_x(n, 8, seed)
        y = _np_x(n, 8, seed + 1)
        lhs = segment_means(2.5 * x + y, m)
        rhs = 2.5 * segment_means(x, m) + segment_means(y, m)
        np.testing.assert_allclose(lhs, rhs, atol=1e-4)

    @_settings
    @given(n=st.integers(4, 256), m=st.integers(1, 64))
    def test_segment_of_bounds(self, n, m):
        pos = jnp.arange(n)
        segs = segment_of(pos, n, m)
        assert int(segs.min()) >= 0
        assert int(segs.max()) < m
        # Non-decreasing in position.
        assert bool(jnp.all(jnp.diff(segs) >= 0))


class TestPinvProperties:
    @_settings
    @given(c=st.integers(4, 24), seed=st.integers(0, 100))
    def test_penrose_on_spd(self, c, seed):
        rng = np.random.default_rng(seed)
        b = rng.normal(size=(c, c)).astype(np.float32)
        a = jnp.asarray(b @ b.T + 0.5 * np.eye(c))
        z = iterative_pinv(a, num_iters=18)
        resid = float(jnp.max(jnp.abs(a @ z @ a - a))) / float(jnp.max(jnp.abs(a)))
        assert resid < 1e-2, resid

    @_settings
    @given(c=st.integers(4, 24), seed=st.integers(0, 100))
    def test_symmetric_input_symmetric_output(self, c, seed):
        rng = np.random.default_rng(seed)
        b = rng.normal(size=(c, c)).astype(np.float32)
        a = jnp.asarray(b @ b.T + 0.1 * np.eye(c))
        z = iterative_pinv(a, num_iters=10)
        asym = float(jnp.max(jnp.abs(z - z.T))) / float(jnp.max(jnp.abs(z)))
        assert asym < 1e-3, asym


class TestSSCoreProperties:
    @_settings
    @given(c=st.integers(4, 32), seed=st.integers(0, 100),
           scale=st.floats(0.1, 2.0))
    def test_delta_nonneg_and_finite(self, c, seed, scale):
        """For any softmax core: delta >= 0 and all outputs finite."""
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(c, 8)).astype(np.float32) * scale
        s = jnp.asarray(x @ x.T) / np.sqrt(8)
        p = jnp.exp(s - s.max(-1, keepdims=True))
        a = p / p.sum(-1, keepdims=True)
        core = ss_core(a, method="iterative", pinv_iters=6)
        assert float(core.delta[..., 0, 0]) >= 0.0
        for leaf in (core.u, core.z, core.delta):
            assert bool(jnp.all(jnp.isfinite(leaf)))

    @_settings
    @given(c=st.integers(4, 24), seed=st.integers(0, 50))
    def test_shift_off_means_u_equals_z(self, c, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(c, 8)).astype(np.float32)
        s = jnp.asarray(x @ x.T)
        p = jnp.exp(s - s.max(-1, keepdims=True))
        a = p / p.sum(-1, keepdims=True)
        core = ss_core(a, method="iterative", use_shift=False)
        np.testing.assert_allclose(core.u, core.z, atol=1e-6)


class TestAttentionProperties:
    @_settings
    @given(
        n=st.sampled_from([64, 128, 200]),
        c=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 50),
    )
    def test_ss_attention_finite_any_shape(self, n, c, seed):
        from repro.core.attention import SSConfig, spectral_shift_attention

        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(1, n, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, n, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, n, 16)), jnp.float32)
        out = spectral_shift_attention(q, k, v, SSConfig(num_landmarks=c))
        assert out.shape == q.shape
        assert bool(jnp.all(jnp.isfinite(out)))

    @_settings
    @given(seed=st.integers(0, 50))
    def test_full_attention_convexity(self, seed):
        """Exact softmax attention output lies in the convex hull of V
        (per-coordinate bounds)."""
        from repro.core.attention import full_attention

        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(1, 32, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 32, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 32, 8)), jnp.float32)
        out = full_attention(q, k, v)
        assert bool(jnp.all(out <= v.max(axis=-2, keepdims=True) + 1e-5))
        assert bool(jnp.all(out >= v.min(axis=-2, keepdims=True) - 1e-5))


class TestLossProperties:
    @_settings
    @given(seed=st.integers(0, 50), b=st.integers(1, 4), s=st.integers(4, 32))
    def test_ce_nonnegative_and_uniform_bound(self, seed, b, s):
        from repro.train.losses import next_token_loss

        rng = np.random.default_rng(seed)
        V = 64
        logits = jnp.asarray(rng.normal(size=(b, s, V)), jnp.float32)
        tokens = jnp.asarray(rng.integers(1, V, (b, s)), jnp.int32)
        loss, m = next_token_loss(logits, tokens)
        assert float(loss) >= 0.0
        # Random logits: CE close to log V, certainly below 2 log V.
        assert float(loss) < 2 * np.log(V)

    @_settings
    @given(seed=st.integers(0, 20))
    def test_perfect_prediction_zero_loss(self, seed):
        from repro.train.losses import next_token_loss

        rng = np.random.default_rng(seed)
        V, b, s = 32, 2, 16
        tokens = jnp.asarray(rng.integers(1, V, (b, s)), jnp.int32)
        logits = jax.nn.one_hot(
            jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))), V
        ) * 1e4
        loss, _ = next_token_loss(logits, tokens)
        assert float(loss) < 1e-3


class TestAllocatorInvariants:
    """Random interleavings of allocator / prefix-cache operations must
    preserve the pool-partition invariant: every usable block id is either
    on the free list (exactly once) or refcounted (count >= 1), and the
    prefix cache's ``evictable_blocks`` never promises more than a full
    reclaim sweep can actually free."""

    @staticmethod
    def _check_partition(alloc):
        free = alloc._free
        assert len(free) == len(set(free)), "free-list duplicates"
        refed = set(alloc.refcounts)
        assert refed.isdisjoint(free)
        assert refed | set(free) == set(range(1, alloc.num_blocks))
        assert all(rc >= 1 for rc in alloc.refcounts.values())

    @_settings
    @given(seed=st.integers(0, 200), num_blocks=st.integers(4, 40))
    def test_alloc_free_cow_interleaving(self, seed, num_blocks):
        from repro.serve.paged import BlockAllocator

        rng = np.random.default_rng(seed)
        a = BlockAllocator(num_blocks, 4)
        live: list[int] = []
        for step in range(60):
            op = rng.integers(4)
            if op == 0:  # alloc a few blocks for a (maybe new) uid
                uid = int(rng.integers(8))
                got = a.alloc(uid, int(rng.integers(1, 4)))
                if got is not None and uid not in live:
                    live.append(uid)
            elif op == 1 and live:  # free a live uid
                uid = live.pop(int(rng.integers(len(live))))
                a.free(uid)
            elif op == 2 and live:  # share + cow a random slot
                uid = live[int(rng.integers(len(live)))]
                table = a.tables.get(uid, [])
                if table:
                    slot = int(rng.integers(len(table)))
                    a.take_ref(table[slot])  # simulate a cache retention
                    got = a.cow(uid, slot)
                    if got is None:
                        a.release_ref(table[slot])  # undo: pool was short
            elif op == 3:
                a.scramble_free(int(rng.integers(1 << 30)) + 1)
            self._check_partition(a)
        for uid in live:
            a.free(uid)
        # cache-retained blocks (taken in op 2) may survive; release them
        for b in list(a.refcounts):
            while b in a.refcounts:
                a.release_ref(b)
        self._check_partition(a)
        assert a.num_used == 0

    @_settings
    @given(seed=st.integers(0, 200))
    def test_defragment_preserves_contents_mapping(self, seed):
        from repro.serve.paged import ZERO_BLOCK, BlockAllocator

        rng = np.random.default_rng(seed)
        a = BlockAllocator(33, 4)
        for uid in range(6):
            a.alloc(uid, int(rng.integers(1, 5)))
        before = {u: list(t) for u, t in a.tables.items()}
        for uid in rng.permutation(6)[:3]:
            a.free(int(uid))
        held = {u: list(t) for u, t in a.tables.items()}
        mapping = a.defragment()
        assert ZERO_BLOCK not in mapping and ZERO_BLOCK not in mapping.values()
        # tables are remapped consistently and stay disjoint
        seen: set = set()
        for u, t in a.tables.items():
            assert t == [mapping.get(b, b) for b in held[u]]
            assert seen.isdisjoint(t)
            seen.update(t)
        self._check_partition(a)
        del before

    @_settings
    @given(seed=st.integers(0, 200), nb=st.integers(8, 32))
    def test_prefix_cache_never_overpromises(self, seed, nb):
        """evictable_blocks() is can_alloc's promise: a full reclaim-only
        eviction sweep must free AT LEAST that many blocks, under any
        interleaving of inserts, live-table retentions and evictions."""
        from repro.serve.paged import BlockAllocator, PrefixCache

        rng = np.random.default_rng(seed)
        bs = 4
        a = BlockAllocator(nb, bs)
        cache = PrefixCache(a)
        next_uid = 1000
        live: list[int] = []
        for step in range(30):
            op = rng.integers(3)
            if op == 0:  # insert a random prompt as a cache entry
                uid = next_uid
                next_uid += 1
                n_tok = int(rng.integers(bs, 3 * bs + 1))
                got = a.alloc(uid, a.blocks_for_tokens(n_tok))
                if got is None:
                    continue
                prompt = rng.integers(3, 1 << 20, n_tok).tolist()
                cache.insert(prompt, a.tables[uid])
                a.free(uid)  # entry's own refs keep the blocks resident
            elif op == 1 and cache._entries:  # live table attaches a prefix
                e = cache._entries[int(rng.integers(len(cache._entries)))]
                uid = next_uid
                next_uid += 1
                a.attach_shared(uid, e.blocks)
                live.append(uid)
            elif op == 2 and live:
                a.free(live.pop(int(rng.integers(len(live)))))
            self._check_partition(a)
            # static bound: the promise can never exceed the blocks whose
            # every reference is cache-held
            cache_only = sum(
                1 for b, rc in a.refcounts.items()
                if rc == cache._cache_refs.get(b, 0)
            )
            assert cache.evictable_blocks() <= cache_only
        # destructive check of the promise itself: a full reclaim-only
        # sweep frees at least evictable_blocks()
        promised = cache.evictable_blocks()
        free0 = a.num_free
        while cache.evict_one(reclaim_only=True):
            pass
        assert a.num_free - free0 >= promised, (
            f"promised {promised}, freed {a.num_free - free0}")
        self._check_partition(a)
        for uid in live:
            a.free(uid)
        self._check_partition(a)


class TestCheckpointProperty:
    @_settings
    @given(seed=st.integers(0, 30))
    def test_roundtrip_arbitrary_tree(self, seed):
        import tempfile

        from repro.checkpoint.checkpointer import Checkpointer

        rng = np.random.default_rng(seed)
        tree = {
            "a": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
            "nested": {
                "b": jnp.asarray(rng.integers(0, 10, (4,)), jnp.int32),
                "c": [jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
                      for _ in range(2)],
            },
        }
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            ck.save(1, tree, blocking=True)
            out = ck.restore(1, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
