"""Roofline analysis (§Roofline of EXPERIMENTS.md).

Reads the dry-run JSONs (results/dryrun/*__single*.json) and derives, per
(arch x shape) cell, the three roofline terms on TPU v5e:

    t_compute    = HLO_FLOPs_per_device    / 197e12   [s]  (bf16 peak/chip)
    t_memory     = HLO_bytes_per_device    / 819e9    [s]  (HBM bw/chip)
    t_collective = moved_bytes_per_device  / 50e9     [s]  (ICI link bw)

All three numerators are per-device quantities: ``cost_analysis`` runs on
the post-SPMD partitioned module, and the dry-run's L=2/L=4 probe
extrapolates the scan-hidden layer body to the full depth (XLA counts while
bodies once). ``moved_bytes`` models ring collectives:
ag/a2a: out*(g-1)/g, ar: 2*out*(g-1)/g, rs: out*(g-1), cp: out.

Also reports MODEL_FLOPS (6*N_active*D train / 2*N_active*D inference) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy
waste), the dominant term, and a rule-based note on what would move it.

Usage:
    python -m benchmarks.roofline [--dir results/dryrun] [--tag TAG]
        [--format md|csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import SHAPE_PRESETS
from repro.configs.registry import ARCH_IDS, get_config

PEAK_FLOPS = 197e12   # TPU v5e bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
ICI_BW = 50e9         # bytes/s / link

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def active_params(arch: str) -> float:
    """N_active: total params minus un-routed expert weights."""
    cfg = get_config(arch)
    from repro.models.model import model_specs
    from repro.models.params import count_params

    total = count_params(model_specs(cfg))
    if cfg.moe:
        inactive = (
            cfg.num_layers * 3 * cfg.d_model * cfg.moe_d_ff
            * (cfg.num_experts - cfg.top_k)
        )
        return total - inactive
    return total


def model_flops(arch: str, shape_name: str) -> float:
    shape = SHAPE_PRESETS[shape_name]
    n_act = active_params(arch)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens
    # decode: one new token per sequence.
    return 2.0 * n_act * shape.global_batch


def load_cell(path: str) -> dict | None:
    with open(path) as f:
        d = json.load(f)
    if d.get("status") != "ok":
        return None
    probe = d.get("probe") or {}
    use_probe = "flops_extrapolated" in probe
    flops = probe["flops_extrapolated"] if use_probe else d["flops_total"]
    bytes_ = probe["bytes_extrapolated"] if use_probe else d["hlo_bytes_accessed"]
    moved = (
        probe["collective_moved_extrapolated"]
        if use_probe
        else sum(v["moved_bytes"] for v in d.get("collectives", {}).values())
    )
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "devices": d["devices"],
        "attention": d.get("attention", "?"),
        "flops": flops,
        "bytes": bytes_,
        "moved": moved,
        "probe": use_probe,
        "state_bytes": d.get("state_bytes_per_device", 0),
    }


_NOTES = {
    "compute": "compute-bound: cut HLO FLOPs (less remat, fewer landmark "
               "FLOPs, larger c-blocks feeding the MXU)",
    "memory": "memory-bound: cut bytes (chunked/flash attention so scores "
              "never hit HBM, bf16 activations, fusion)",
    "collective": "collective-bound: reshard (FSDP->TP ratio), overlap "
                  "collectives with compute, or compress gradients",
}


def analyze(cell: dict) -> dict:
    t_c = cell["flops"] / PEAK_FLOPS
    t_m = cell["bytes"] / HBM_BW
    t_x = cell["moved"] / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell["shape"])
    mf_dev = mf / cell["devices"]
    bound = max(terms.values())
    return {
        **cell,
        "t_compute": t_c,
        "t_memory": t_m,
        "t_collective": t_x,
        "dominant": dominant,
        "model_flops_dev": mf_dev,
        "useful_ratio": mf_dev / cell["flops"] if cell["flops"] else 0.0,
        # Achievable MFU if the dominant term is the step time.
        "roofline_mfu": (mf_dev / PEAK_FLOPS) / bound if bound else 0.0,
        "note": _NOTES[dominant],
    }


def collect(dirpath: str, mesh: str = "single", tag: str = "") -> list[dict]:
    rows = []
    suffix = f"__{tag}" if tag else ""
    for arch in ARCH_IDS:
        for shape in SHAPES:
            path = os.path.join(dirpath, f"{arch}__{shape}__{mesh}{suffix}.json")
            if not os.path.exists(path):
                continue
            cell = load_cell(path)
            if cell:
                rows.append(analyze(cell))
    return rows


def fmt_md(rows: list[dict]) -> str:
    out = [
        "| arch | shape | attn | t_comp (s) | t_mem (s) | t_coll (s) | "
        "dominant | useful | roofline-MFU |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['attention']} "
            f"| {r['t_compute']:.3f} | {r['t_memory']:.3f} "
            f"| {r['t_collective']:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_mfu'] * 100:.1f}% |"
        )
    return "\n".join(out)


def fmt_csv(rows: list[dict]) -> str:
    out = ["arch,shape,attention,t_compute,t_memory,t_collective,dominant,"
           "useful_ratio,roofline_mfu"]
    for r in rows:
        out.append(
            f"{r['arch']},{r['shape']},{r['attention']},{r['t_compute']:.4f},"
            f"{r['t_memory']:.4f},{r['t_collective']:.4f},{r['dominant']},"
            f"{r['useful_ratio']:.3f},{r['roofline_mfu']:.3f}"
        )
    return "\n".join(out)


def run(csv_rows: list[str]) -> None:
    """benchmarks.run entry: emit the roofline table as CSV rows."""
    dirpath = ("results/dryrun_v2" if os.path.isdir("results/dryrun_v2")
               else "results/dryrun")
    rows = collect(dirpath)
    for r in rows:
        csv_rows.append(
            f"roofline,{r['arch']}:{r['shape']},{r['dominant']},"
            f"{r['roofline_mfu']:.3f}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    args = ap.parse_args()
    rows = collect(args.dir, args.mesh, args.tag)
    print(fmt_md(rows) if args.format == "md" else fmt_csv(rows))


if __name__ == "__main__":
    main()
