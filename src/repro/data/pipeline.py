"""Deterministic, shardable token data pipeline.

Two sources:
* ``SyntheticLM`` — seeded synthetic token stream (Zipfian-ish) for smoke
  tests, dry-runs, and reproducible benchmarks. Stateless: batch ``i`` is a
  pure function of (seed, i), so restarts/elastic re-sharding resume exactly
  by step counter (no iterator state to checkpoint beyond the step).
* ``TextFileLM`` — byte-level tokenization of a local text file with a
  deterministic window sampler, for the real training example.

``make_global_batch`` builds jax.Arrays with an explicit sharding so each
data-parallel host only materializes its shard (multi-host friendly via
``jax.make_array_from_callback``).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # Zipf-ish marginal so CE has learnable structure + a copy task so
        # a few hundred steps show a clearly decreasing loss.
        ranks = rng.zipf(1.3, size=(self.global_batch, self.seq_len))
        tokens = np.clip(ranks, 1, self.vocab_size - 1).astype(np.int32)
        # Inject periodic structure: token[t] == token[t-8] for half the seq.
        tokens[:, 8::2] = tokens[:, : tokens.shape[1] - 8 : 2][:, : tokens[:, 8::2].shape[1]]
        return {"tokens": tokens}


@dataclasses.dataclass
class TextFileLM:
    path: str
    seq_len: int
    global_batch: int
    seed: int = 0
    vocab_size: int = 256  # byte-level

    def __post_init__(self):
        with open(self.path, "rb") as f:
            self._data = np.frombuffer(f.read(), dtype=np.uint8)
        if len(self._data) < self.seq_len + 1:
            raise ValueError("text file smaller than one sequence")

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        starts = rng.integers(
            0, len(self._data) - self.seq_len - 1, size=self.global_batch
        )
        toks = np.stack(
            [self._data[s : s + self.seq_len].astype(np.int32) for s in starts]
        )
        return {"tokens": toks}


def make_global_batch(host_batch: dict, sharding_tree) -> dict:
    """Place a host-local numpy batch onto devices with explicit shardings.

    With a single process this is a device_put; under multi-host each process
    contributes only its addressable shard via make_array_from_callback.
    """
    def place(arr, sh):
        arr = np.asarray(arr)
        if jax.process_count() == 1:
            return jax.device_put(arr, sh)
        return jax.make_array_from_callback(
            arr.shape, sh, lambda idx: arr[idx]
        )

    return jax.tree.map(place, host_batch, sharding_tree)
