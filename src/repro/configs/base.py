"""Model/run configuration dataclasses shared by every architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0            # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "swiglu"          # swiglu | gelu

    # attention approximation (the paper's technique)
    attention_impl: str = "full"             # training-time self-attention
    decode_attention_impl: str = "spectral_shift"  # KV-cache decode path
    encoder_attention_impl: str = "spectral_shift"  # bidirectional sites
    decode_streaming: str = "exact"    # spectral-shift decode state policy:
                                       # recompute = rebuild B/BV over the
                                       #   whole cache horizon every token
                                       #   (O(c*S*d)/token, the legacy path)
                                       # exact = stream (m, l, BV) stats in
                                       #   the cache; frozen landmark rows
                                       #   flash-append the new key, only the
                                       #   active segment's row is recomputed
                                       #   (O(S*d + c*d)/token, token-
                                       #   identical to recompute on greedy)
                                       # frozen = active row streams too and
                                       #   is rebased lazily at segment
                                       #   boundaries (amortized O(c*d)/token,
                                       #   bounded drift within a segment)
    num_landmarks: int = 64
    ss_method: str = "iterative"
    pinv_iters: int = 6
    include_shift_identity: bool = True
    landmark_via_matmul: bool = False  # GEMM segment-means: required for
                                       # sharded-seq (context-parallel) runs
    cast_params_once: bool = True      # bf16 working copy cast at step entry
                                       # (collectives move bf16, not fp32)
    kernels_interpret: bool = True     # Pallas interpret mode (CPU); the TPU
                                       # launcher flips this to False
    attention_backend: str = "auto"    # kernel route for *_fused impls:
                                       # auto (dispatch registry) | fused |
                                       # jnp | interpret (forced)
    autotune: bool = False             # measured autotune for unseen shape
                                       # keys (kernels/dispatch.py); winners
                                       # persist to the on-disk cache
    autotune_cache: str = ""           # cache path override ("" = default
                                       # REPRO_AUTOTUNE_CACHE / ~/.cache)
    seq_shard_fused: bool = True       # context-parallel cells keep the fused
                                       # Pallas path via the shard_map driver
                                       # (kernels/sharded.py); False restores
                                       # the legacy jnp-GSPMD downgrade in
                                       # apply_seq_sharding_config

    # MoE
    moe: bool = False
    moe_impl: str = "gspmd"      # gspmd (implicit) | ep (shard_map all-to-all)
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # MLA (DeepSeek-V2 style)
    mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    conv_width: int = 4
    slstm_every: int = 0         # xLSTM: every k-th block is sLSTM (0 = none)
    ssm_chunk: int = 256         # chunk length for chunk-parallel SSM scans

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False
    encoder_seq_ratio: float = 1.0  # encoder length relative to shape seq_len

    # modality frontend stub
    frontend: str = "none"       # none | audio_frames | image_patches
    num_patches: int = 0         # vlm: image-patch count per example

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"          # none | full | dots | ss_stats (save only
                                 # the fused-attention (m, l)/BV residuals) |
                                 # auto (per-backend default, REMAT_DEFAULTS)
    unroll_scans: bool = False   # probe mode: unroll chunk scans so XLA
                                 # cost_analysis sees every body (math-identical)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so TP-16 shards evenly."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_decoder_only(self) -> bool:
        return self.encoder_layers == 0


# Per-arch remat defaults for ``remat="auto"``, pinned from the measured
# study in results/remat_study.json (benchmarks/remat_study.py; reduced
# dense decoder scaled from the 4k/32k train cells). Measured: ``dots``
# carries the largest fwd->bwd footprint at every cell (+26-38% XLA temp vs
# full at 4k/32k on both routes); ``ss_stats`` matches ``full``'s footprint
# while additionally keeping only the tagged (m, l)/BV attention residuals
# on the kernel route (bench_train_step: ~2.1x smaller vjp residuals at
# 4k), which is the profile that matters on real accelerators — so
# TPU/GPU pin ``ss_stats``. On CPU the dispatch heuristic routes attention
# to jnp (no tagged residuals; ss_stats degenerates to recompute-all) and
# ``full`` is fastest-or-equal at every measured cell, so CPU pins
# ``full``.
REMAT_DEFAULTS: dict[str, str] = {
    "tpu": "ss_stats",
    "gpu": "ss_stats",
    "cpu": "full",
}


def resolve_remat(remat: str, backend: Optional[str] = None) -> str:
    """Map ``remat="auto"`` to the pinned per-arch default (identity for
    every explicit policy)."""
    if remat != "auto":
        return remat
    if backend is None:
        import jax

        backend = jax.default_backend()
    return REMAT_DEFAULTS.get(backend, "full")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPE_PRESETS: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-engine knobs: paged KV cache + two-phase scheduler.

    ``paged=False, batched_prefill=False`` reproduces the seed engine exactly
    (dense per-lane caches, one prompt token per tick); the defaults give the
    vLLM-style engine (shared block pool, one-forward-pass prefill).
    """

    max_lanes: int = 4
    max_seq: int = 512
    block_size: int = 16          # tokens per KV block; must divide max_seq
    num_blocks: int = 0           # 0 => max_lanes * max_seq / block_size
    paged: bool = True            # block-paged pool vs dense per-lane caches
    batched_prefill: bool = True  # whole-prompt forward vs token replay
    prefill_bucket: int = 32      # prompts padded up to a bucket multiple
                                  # (bounds the number of prefill compiles);
                                  # rounded up to a block_size multiple
    prefill_impl: str = "replay"  # replay  = per-token decode math, exact
                                  # ss_fused = Pallas landmark_summary /
                                  #   query_side kernels, approximate prompt
                                  #   attention (landmark state still exact)
    decode_impl: str = "gather"   # decode-tick route over paged storage:
                                  # gather = assemble a transient dense
                                  #   per-lane K/V view each tick (legacy,
                                  #   O(S*d) HBM traffic; the only route for
                                  #   decode_streaming="recompute")
                                  # paged  = gather-free: the block-table
                                  #   Pallas kernel streams K/V straight
                                  #   from the pools and the new token
                                  #   commits via a single-block scatter
                                  #   (kernels/paged_decode.py; falls back
                                  #   to gather when unsupported)
    chunked_prefill: bool = False  # continuous batching: split prefill into
                                   # fixed-size chunks that ride inside the
                                   # decode tick (decode lanes advance every
                                   # tick, long prompts never stall them).
                                   # False reproduces the two-phase engine
                                   # exactly. Needs batched_prefill; falls
                                   # back to whole-prompt for families
                                   # without batched prefill (hybrid/ssm).
    prefill_chunk_tokens: int = 64  # chunk size (one static XLA program);
                                    # rounded up to a block_size multiple so
                                    # chunks commit whole blocks
    prefill_token_budget: int = 0   # max prompt tokens chunk-prefilled per
                                    # tick across all lanes; 0 = one chunk.
                                    # At least one chunk always runs when a
                                    # prefill is pending (no livelock).
    prefix_cache: bool = False    # content-hash prefix caching over the
                                  # block pool: prompts are hashed block by
                                  # block (chained hashes) and a matching
                                  # cached prefix maps its physical blocks
                                  # into the new request's table with
                                  # refcounts + copy-on-write. Implies the
                                  # continuous-batching (chunked) tick for
                                  # partial-hit resume; needs paged=True
                                  # (silently off for dense caches). False
                                  # reproduces the non-caching engine
                                  # byte for byte.
    prefix_cache_blocks: int = 0  # cap on pool blocks the prefix cache may
                                  # retain for finished requests (LRU-evicted
                                  # beyond it); 0 = bounded only by pool
                                  # pressure (allocation shortfalls evict)
    prefix_attach: str = "reseg"  # streaming-stat seeding on a cache hit:
                                  # reseg    = reuse the entry's stats stored
                                  #   at the canonical segmentation, running
                                  #   the O(c*d) re-segmentation program only
                                  #   if the lane's horizon segmentation
                                  #   differs (it never does within one
                                  #   engine, so a full hit is pure host
                                  #   work)
                                  # recompute = always re-derive the stats
                                  #   from the shared K/V blocks via the
                                  #   prefill handoff program (correctness
                                  #   fallback; token-identity-tested)
    eos_id: int = 2
    seed: int = 0
    telemetry: bool = False       # unified metrics/tracing/drift monitors
                                  # (src/repro/telemetry): off = no-op
                                  # registry + tracer on the hot path, no
                                  # extra device programs; the scheduler's
                                  # latency percentiles work either way
    numerics_probe_every: int = 0  # every N ticks, count NaN/Inf in decode
                                   # logits and the landmark (m, l) stats
                                   # (numerics_nonfinite_total{site=}); 0 =
                                   # off. Each probe forces a host sync, so
                                   # this is a cadence, not a boolean.
                                   # Requires telemetry=True to count.
    max_queue: int = 0            # admission-queue bound: a submit() that
                                  # would grow the waiting queue past this
                                  # is REJECTED (engine.submit returns
                                  # False, serve_rejected_total counts it,
                                  # the flight "reject" event carries a
                                  # retry_after_ticks hint). 0 = unbounded
                                  # (the pre-backpressure behavior).
    watchdog_ticks: int = 0       # no-progress watchdog: after N
                                  # consecutive ticks with work pending but
                                  # zero progress (no token, no chunk, no
                                  # prefill, no admission) the engine walks
                                  # the escalation ladder — reclaim parked
                                  # blocks, preempt the youngest lane, and
                                  # only as the last rung raise a
                                  # structured EngineStalled. 0 = off. A
                                  # healthy run never trips it, so any
                                  # value is output-identical to 0.
    numerics_guard: bool = False  # online non-finite defense for the
                                  # streaming decode state: after every
                                  # decode dispatch, check each active
                                  # lane's logits row and landmark
                                  # (m, l, acc) stats on the host;
                                  # corrupted stats under finite logits
                                  # quarantine the lane and rebuild its
                                  # stats exactly from cached K/V (the
                                  # prefix-attach reseed program);
                                  # corrupted logits replay-preempt the
                                  # lane (full recompute). Forces a host
                                  # sync per tick — a correctness posture,
                                  # not a fast path. Works without
                                  # telemetry (counters live on the
                                  # scheduler's always-real registry).
    numerics_demote_after: int = 2  # guard trips per request before a
                                    # frozen-mode lane is demoted to
                                    # decode_streaming="exact" for the rest
                                    # of its life (numerics_demotions_total
                                    # counts it); exact mode recomputes the
                                    # active row per tick, so a stats
                                    # corruptor can't keep re-poisoning the
                                    # drift window.

    @property
    def blocks_per_lane(self) -> int:
        return self.max_seq // self.block_size

    @property
    def resolved_num_blocks(self) -> int:
        # +1: block 0 is reserved as the permanently-zero block that backs
        # unallocated block-table slots.
        n = self.num_blocks or self.max_lanes * self.blocks_per_lane
        # One lane must always be able to hold a full sequence, or a lone
        # request could deadlock preempting itself forever.
        return max(n, self.blocks_per_lane) + 1

    def __post_init__(self):
        # Only the block-paged layout needs the divisibility; the dense
        # seed-compat mode accepts any max_seq, as the seed engine did.
        if self.paged and self.max_seq % self.block_size:
            raise ValueError(
                f"block_size {self.block_size} must divide max_seq "
                f"{self.max_seq} (or set paged=False)"
            )
        if self.prefill_impl not in ("replay", "ss_fused"):
            raise ValueError(f"unknown prefill_impl {self.prefill_impl!r}")
        if self.decode_impl not in ("gather", "paged"):
            raise ValueError(f"unknown decode_impl {self.decode_impl!r}")
        if self.numerics_probe_every < 0:
            raise ValueError(
                f"numerics_probe_every must be >= 0, "
                f"got {self.numerics_probe_every}"
            )
        if self.chunked_prefill and not self.batched_prefill:
            raise ValueError(
                "chunked_prefill=True requires batched_prefill=True (chunks "
                "are bucketed batched-prefill programs)"
            )
        if self.prefill_chunk_tokens <= 0:
            raise ValueError(
                f"prefill_chunk_tokens must be > 0, "
                f"got {self.prefill_chunk_tokens}"
            )
        if self.prefill_token_budget < 0:
            raise ValueError(
                f"prefill_token_budget must be >= 0, "
                f"got {self.prefill_token_budget}"
            )
        if self.prefix_attach not in ("reseg", "recompute"):
            raise ValueError(f"unknown prefix_attach {self.prefix_attach!r}")
        if self.prefix_cache_blocks < 0:
            raise ValueError(
                f"prefix_cache_blocks must be >= 0, "
                f"got {self.prefix_cache_blocks}"
            )
        if self.prefix_cache and not self.batched_prefill:
            raise ValueError(
                "prefix_cache=True requires batched_prefill=True (partial "
                "hits resume through chunked batched prefill)"
            )
        if self.max_queue < 0:
            raise ValueError(
                f"max_queue must be >= 0, got {self.max_queue}"
            )
        if self.watchdog_ticks < 0:
            raise ValueError(
                f"watchdog_ticks must be >= 0, got {self.watchdog_ticks}"
            )
        if self.numerics_demote_after < 1:
            raise ValueError(
                f"numerics_demote_after must be >= 1, "
                f"got {self.numerics_demote_after}"
            )


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / trainer knobs (used by the real training driver)."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1        # grad-accumulation steps
    opt_state_dtype: str = "float32"
    grad_compression: Optional[str] = None  # None | "int8"
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    seed: int = 0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving its family shape
    (GQA ratios, MoE top-k, MLA ranks scale down proportionally)."""
    kv_ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    heads = 4
    small: dict = dict(
        num_layers=2,
        d_model=128,
        num_heads=heads,
        num_kv_heads=max(1, heads // kv_ratio),
        d_ff=256,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else 0,
        num_landmarks=16,
        scan_layers=cfg.scan_layers,
        remat="none",
        compute_dtype="float32",
    )
    if cfg.moe:
        small.update(num_experts=8, num_shared_experts=min(cfg.num_shared_experts, 1),
                     top_k=min(cfg.top_k, 2), moe_d_ff=64)
    if cfg.mla:
        small.update(kv_lora_rank=32, rope_head_dim=16)
    if cfg.ssm_state:
        small.update(ssm_state=8)
    if cfg.encoder_layers:
        small.update(encoder_layers=2)
    if cfg.num_patches:
        small.update(num_patches=16)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
