"""Mixture-of-Experts FFN (DeepSeek-V2 / Kimi-K2 style: shared + routed,
top-k, capacity-bounded token dropping).

Dispatch uses scatter/gather (k scatters of the token block) rather than the
GShard (G,S,E,C) one-hot einsum — the einsum form costs T*E*C*D MACs (an
~80x FLOP overhead at our configs) while scatter is O(T*k*D) data movement.
Under pjit, tokens are batch-sharded ("data") and expert weights are
expert-sharded ("data") + ff-sharded ("model"), so the buf einsum reshard is
the classic EP all-to-all, inserted by GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import mlp_forward, mlp_specs
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    specs = {
        "router": ParamSpec((d, e), ("embed", None), scale=d**-0.5),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed_unsharded", "moe_ff")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed_unsharded", "moe_ff")),
        "w_down": ParamSpec((e, f, d), ("experts", "moe_ff", "embed_unsharded")),
    }
    if cfg.num_shared_experts:
        specs["shared"] = mlp_specs(d, cfg.moe_d_ff * cfg.num_shared_experts, "swiglu")
    return specs


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = int(seq_len * cfg.top_k * cfg.capacity_factor / cfg.num_experts) + 1
    return max(cfg.top_k, min(c, seq_len))


def moe_forward(
    p: dict, cfg: ModelConfig, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (out (B,S,D), aux load-balance loss (scalar))."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = capacity(cfg, s)
    dt = x.dtype

    gates = jax.nn.softmax(
        (x @ p["router"].astype(dt)).astype(jnp.float32), axis=-1
    )  # (B,S,E)
    top_w, top_i = jax.lax.top_k(gates, k)  # (B,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * <f_e, p_e>.
    me = jnp.mean(gates, axis=(0, 1))  # (E,)
    one_hot_all = jax.nn.one_hot(top_i, e, dtype=jnp.float32)  # (B,S,k,E)
    fe = jnp.mean(one_hot_all.sum(2), axis=(0, 1)) / k
    aux = e * jnp.sum(fe * me)

    # Slot assignment: position of each (token, choice) within its expert,
    # in token order, capacity-bounded.
    choice_hot = one_hot_all.reshape(b, s * k, e).astype(jnp.int32)
    pos = jnp.cumsum(choice_hot, axis=1) - 1  # (B,S*k,E)
    slot = jnp.sum(pos * choice_hot, axis=-1).reshape(b, s, k)  # (B,S,k)
    keep = (slot < cap).astype(dt)
    slot = jnp.clip(slot, 0, cap - 1)

    # Dispatch: k scatter-adds of the token block into (B,E,cap,D).
    buf = jnp.zeros((b, e, cap, d), dt)
    b_idx = jnp.arange(b)[:, None]
    for j in range(k):
        buf = buf.at[b_idx, top_i[..., j], slot[..., j]].add(
            x * keep[..., j : j + 1], mode="drop"
        )

    # Expert FFN (SwiGLU), batched over (B, E): the (B<->E) reshard here is
    # the EP all-to-all under pjit.
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt))
    ) * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    buf_out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))

    # Combine: gather each choice's slot back and mix with gate weights.
    out = jnp.zeros_like(x)
    for j in range(k):
        gathered = buf_out[b_idx, top_i[..., j], slot[..., j]]  # (B,S,D)
        out = out + gathered * (top_w[..., j, None].astype(dt) * keep[..., j : j + 1])

    if cfg.num_shared_experts:
        out = out + mlp_forward(p["shared"], x, "swiglu")
    return out, aux.astype(jnp.float32)


# ==========================================================================
# Explicit expert-parallel MoE (shard_map all-to-all dispatch)
# ==========================================================================
def moe_forward_ep(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """Expert-parallel MoE: tokens move to experts via all-to-all.

    Under pure GSPMD sharding the capacity-buffer scatter makes the
    partitioner combine FULL-BATCH buffer contributions with per-scatter
    all-reduces — measured 15.1 GB x 8 scatters x layer on kimi-k2
    (EXPERIMENTS.md §Perf cell B). This implementation makes the intended
    communication pattern explicit with shard_map:

      * experts are sharded over the ``data`` axis (E_loc per shard) and
        replicated over ``model``/``pod``;
      * each shard packs its tokens into per-destination capacity buckets
        and exchanges them with ONE all-to-all over ``data`` — the payload
        is split over ``model`` first, so each model shard moves and
        computes 1/TP of the capacity slots (token-sliced expert FFN: the
        small d_ff stays unsharded, no per-layer TP psum on the buffer);
      * expert outputs return by the inverse all-to-all and a single cheap
        (B_loc, S, D) psum over ``model`` rebuilds the combined output.

    Per-layer traffic per device ~ 2 x (T·k·D / E-shards / TP) a2a
    + one (B_loc,S,D) psum, vs ~8 full-buffer all-reduces under GSPMD.
    Falls back to ``moe_forward`` outside a mesh context (CPU tests).
    """
    from repro.distributed.sharding import _mesh, spec_for

    try:  # jax >= 0.4.35
        from jax.shard_map import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return moe_forward(p, cfg, x)
    # Experts shard over every non-TP mesh axis ("pod" included on the
    # multi-pod mesh — leaving them data-only replicates 1T of expert
    # weights + moments across pods, §Perf cell B it4).
    ep_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp = 1
    for a in ep_axes:
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    e, k = cfg.num_experts, cfg.top_k
    if e % dp:
        return moe_forward(p, cfg, x)  # experts must tile the EP axes
    e_loc = e // dp

    # Specs: batch over (pod,data); experts over the same axes; everything
    # else rides along replicated (model splits happen inside, by slicing).
    x_spec = spec_for(("batch", "seq", None))
    w_spec = P(ep_axes if len(ep_axes) > 1 else ep_axes[0], None, None)
    shared_spec = jax.tree.map(lambda _: P(), p.get("shared", {}))

    def inner(x_loc, router, w_gate, w_up, w_down, shared):
        b_loc, s, d = x_loc.shape
        t = b_loc * s
        dt = x_loc.dtype
        xt = x_loc.reshape(t, d)
        midx = jax.lax.axis_index("model") if tp > 1 else 0

        gates = jax.nn.softmax(
            (xt @ router.astype(dt)).astype(jnp.float32), axis=-1
        )  # (T, E)
        top_w, top_i = jax.lax.top_k(gates, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        # Load-balance aux (global means via psum over the token axes).
        me = jnp.mean(gates, axis=0)
        fe = jnp.mean(
            jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum(1), axis=0
        ) / k
        tok_axes = tuple(a for a in mesh.axis_names if a != "model")
        me = jax.lax.pmean(me, tok_axes)
        fe = jax.lax.pmean(fe, tok_axes)
        aux = e * jnp.sum(fe * me)

        # Capacity per (source shard, expert), padded to a multiple of TP so
        # the slot dimension splits evenly over the model axis.
        cap = int(t * k * cfg.capacity_factor / e) + 1
        cap = max(cap, k)
        cap = -(-cap // tp) * tp

        # Slot of each (token, choice) within its expert bucket. Choice-major
        # cumsum (k separate (T, E) passes) keeps tensors at (T, E) instead
        # of (T*k, E) and lets dispatch scatter straight from xt — the
        # (T*k, D) fp32 payload materialization was the dominant memory term
        # of the first EP cut (15 GB/layer on kimi, §Perf cell B it2).
        base = jnp.zeros((e,), jnp.int32)
        slots, keeps = [], []
        for j in range(k):
            oh = jax.nn.one_hot(top_i[:, j], e, dtype=jnp.int32)  # (T, E)
            pos = jnp.cumsum(oh, axis=0) - 1 + base[None, :]
            slots.append(jnp.sum(pos * oh, axis=-1))              # (T,)
            base = base + oh.sum(axis=0)
            keeps.append(slots[-1] < cap)

        # Pack tokens into (dp, E_loc, cap//tp, D) send buckets, model-sliced
        # on the cap axis: this shard only fills/sends its cap/TP band.
        # Dispatch payload moves in the compute dtype (bf16 on TPU).
        send = jnp.zeros((dp, e_loc, cap // tp, d), dt)
        dest_l, ein_l, slotb_l, use_l = [], [], [], []
        for j in range(k):
            ej = top_i[:, j]
            slot = jnp.clip(slots[j], 0, cap - 1)
            band = (slot // (cap // tp)) == midx if tp > 1 else \
                jnp.ones_like(keeps[j])
            use = keeps[j] & band
            dest_l.append(ej // e_loc)
            ein_l.append(ej % e_loc)
            slotb_l.append(slot % (cap // tp))
            use_l.append(use)
            send = send.at[dest_l[j], ein_l[j], slotb_l[j]].add(
                xt * use[:, None].astype(dt), mode="drop"
            )

        # Exchange over data: dim 0 (destination) splits, received buffers
        # stack along a new source dim -> (dp, e_loc, cap//tp, d) where dim 0
        # now indexes the SOURCE shard.
        recv = jax.lax.all_to_all(
            send, ep_axes, split_axis=0, concat_axis=0, tiled=True
        ) if dp > 1 else send

        # Local expert FFN on (e_loc, dp * cap//tp, d), full d_ff (no TP).
        buf = recv.transpose(1, 0, 2, 3).reshape(e_loc, dp * (cap // tp), d)
        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt))
        ) * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
        out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))

        # Inverse exchange: back to (dp, e_loc, cap//tp, d) by source shard.
        out = out.reshape(e_loc, dp, cap // tp, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(
            out, ep_axes, split_axis=0, concat_axis=0, tiled=True
        ) if dp > 1 else out
        # back[dest, e_in, slot_b] is this shard's token results.

        # Combine the k choices (masked to this model shard's band), then
        # psum over model to merge the TP-sliced bands. Per-choice gathers
        # keep the working set at (T, D).
        y = jnp.zeros((t, d), jnp.float32)
        for j in range(k):
            gathered = back[dest_l[j], ein_l[j], slotb_l[j]]  # (T, D)
            wj = top_w[:, j] * use_l[j].astype(jnp.float32)
            y = y + gathered.astype(jnp.float32) * wj[:, None]
        if tp > 1:
            y = jax.lax.psum(y, "model")
        y = y.astype(dt)

        if shared:
            y = y + mlp_forward(shared, xt, "swiglu")
        return y.reshape(b_loc, s, d), aux

    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(x_spec, P(), w_spec, w_spec, w_spec, shared_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    out, aux = fn(
        x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
        p.get("shared", {}),
    )
    return out, aux.astype(jnp.float32)
