"""Serving benchmark: time-to-first-token and throughput, dense token-replay
engine vs paged engine with batched prefill.

TTFT is reported both in engine ticks (the architectural win: one batched
forward pass vs one tick per prompt token) and wall-clock seconds. The
paged engine's tick TTFT is 1 by construction; the replay engine's equals
the prompt length.

Besides the CSV rows, results land in two machine-readable artifacts:

* ``BENCH_serve.json`` (repo top level, same ``schema``/``cells`` shape as
  ``BENCH_decode.json``) so the serving perf trajectory is trackable
  across PRs;
* a telemetry JSONL dump from the final throughput cell, run with
  ``ServeConfig.telemetry`` enabled (``REPRO_TELEMETRY_JSONL`` overrides
  the path) — TTFT/ITL histograms, per-tick spans, pool gauges, autotune
  counters. CI's bench-smoke job uploads it as an artifact.

The continuous-batching headline lives in the Poisson cell: a seeded
Poisson-arrival trace with mixed long/short prompts is replayed on the
chunked-prefill engine and on the two-phase baseline
(``chunked_prefill=False``), and TTFT/ITL p50/p99 are computed bench-side
from per-token wall stamps. The chunked replay also exports a Perfetto
trace (``REPRO_TRACE_JSON`` overrides the path) showing chunk lifelines
riding the decode ticks — CI uploads it too.

The prefix-cache cell contrasts cold vs warm TTFT on an identical
256-token prompt (``ServeConfig.prefix_cache=True``: the warm request
full-hits the content-hash index and takes its first token from cached
logits with zero prefill compute) and sweeps a seeded shared-prefix
request stream for the hit rate; warm outputs are asserted
greedy-identical to cold in-line.

The chaos cell replays the Poisson trace once more under a fixed
performance-fault plan (``repro.serve.chaos``: dropped samples, allocation
failures, scrambled free lists) and reports the degraded ITL tail plus the
goodput fraction surviving relative to the fault-free run — outputs are
asserted token-identical, so the delta is pure recovery overhead.

    PYTHONPATH=src python -m benchmarks.run --only serve
    REPRO_BENCH_SMOKE=1 ... (one prompt length, fewer reps, for CI)
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.workload import latency_metrics, poisson_trace, replay_trace

PROMPT_LENS = (32, 64, 128, 256)
MAX_SEQ = 320
MAX_NEW = 8
# Poisson-arrival mixed-length workload: identical in smoke and full runs
# (the regress gate compares the cell across the two). Short/long prompt mix
# puts whole-prompt prefills in front of live decoders — the regime chunked
# prefill exists for.
POISSON = dict(
    n_requests=12, mean_interarrival_ticks=2.0, prompt_lens=(16, 160),
    max_new_tokens=12,
)
POISSON_SEED = 7
TELEMETRY_PATH = os.path.join(
    os.path.dirname(__file__), "..", "results", "telemetry_serve.jsonl"
)
TRACE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "results", "trace_serve_poisson.json"
)

_cells: dict[str, dict] = {}


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def _record(cell: str, metric: str, value: float) -> None:
    _cells.setdefault(cell, {})[metric] = round(float(value), 4)


def _setup():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _serve_cfg(paged: bool, lanes: int) -> ServeConfig:
    return ServeConfig(
        max_lanes=lanes, max_seq=MAX_SEQ, block_size=16,
        paged=paged, batched_prefill=paged,
    )


def _ttft(cfg, params, serve, prompt_len: int, reps: int = 3) -> tuple[int, float]:
    """(ticks, seconds) from submission to the first generated token of one
    request. The same engine first serves an identical throwaway request so
    every XLA program (prefill bucket + decode tick buckets) is compiled
    before timing; best of ``reps`` to shrug off machine noise."""
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params, serve=serve)
    warm = rng.integers(3, cfg.vocab_size, prompt_len).tolist()
    eng.submit(Request(999, warm, max_new_tokens=MAX_NEW))
    eng.run()
    best = (0, float("inf"))
    for rep in range(1, reps + 1):
        uid = 1000 + rep
        eng.submit(Request(
            uid, rng.integers(3, cfg.vocab_size, prompt_len).tolist(),
            max_new_tokens=MAX_NEW,
        ))
        ticks = 0
        t0 = time.perf_counter()
        while eng.sched.timing[uid].first_token < 0:
            eng.tick()
            ticks += 1
            if ticks > 10 * prompt_len:
                break
        sec = time.perf_counter() - t0
        eng.run()  # drain
        if sec < best[1]:
            best = (ticks, sec)
    return best


def _throughput(cfg, params, serve, n_req: int = 8) -> tuple[float, ServeEngine]:
    """tok/s over a mixed batch; the identical batch runs once un-timed on
    the same engine so compiles aren't billed."""
    eng = ServeEngine(cfg, params, serve=serve)

    def submit_all(offset):
        rng = np.random.default_rng(1)
        for u in range(n_req):
            plen = int(rng.integers(8, 48))
            eng.submit(Request(
                offset + u, rng.integers(3, cfg.vocab_size, plen).tolist(),
                max_new_tokens=16,
            ))

    submit_all(0)
    eng.run()  # warm every program shape
    submit_all(1000)
    t0 = time.perf_counter()
    before = sum(len(v) for v in eng.finished.values())
    eng.run()
    dt = time.perf_counter() - t0
    after = sum(len(v) for v in eng.finished.values())
    return (after - before) / dt, eng


def _telemetry_cell(cfg, params, lanes: int, path: str) -> None:
    """One frozen-streaming throughput run with full telemetry enabled —
    exercises TTFT/ITL histograms, per-tick spans, drift/spectrum monitors
    and pool gauges, then dumps the JSONL artifact."""
    fcfg = dataclasses.replace(cfg, decode_streaming="frozen")
    serve = dataclasses.replace(_serve_cfg(True, lanes), telemetry=True)
    # identical workload in smoke and full runs: the regress gate compares
    # this cell across the two, and a smaller batch is drain-tail-dominated
    # (half the tok/s), not a faster version of the same measurement
    tps, eng = _throughput(fcfg, params, serve, n_req=8)
    _record(f"paged|frozen|lanes{lanes}", "tok_per_s_telemetry", tps)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    n = eng.telemetry.dump_jsonl(path, meta={
        "bench": "serve", "host": jax.default_backend(), "lanes": lanes,
    })
    print(f"[bench_serve] telemetry dump: {n} lines -> {path}")


def _poisson_cell(cfg, params, csv_rows: list[str], trace_path: str) -> None:
    """ITL/TTFT percentiles under a seeded Poisson arrival trace with mixed
    long/short prompts: continuous batching (chunked prefill) vs the
    two-phase baseline (``chunked_prefill=False``) on the SAME trace.

    Each engine first replays the identical trace under shifted uids so
    every XLA program (chunk step, prefill buckets, decode ticks) is
    compiled before the timed replay. Latency comes from bench-side
    ``Request.on_token`` wall stamps, so warmup never contaminates the
    percentiles. The chunked engine runs with telemetry on and exports a
    Perfetto trace of the timed replay (chunk lifelines riding the decode
    ticks) for the CI artifact."""
    lanes = 4
    configs = {
        "two_phase": dataclasses.replace(_serve_cfg(True, lanes)),
        "chunked": dataclasses.replace(
            _serve_cfg(True, lanes), chunked_prefill=True,
            prefill_chunk_tokens=32, prefill_token_budget=32,
            telemetry=True,
        ),
    }
    results: dict[str, dict] = {}
    for name, serve in configs.items():
        eng = ServeEngine(cfg, params, serve=serve)
        replay_trace(eng, poisson_trace(
            seed=POISSON_SEED, uid_offset=10_000,
            vocab_size=cfg.vocab_size, **POISSON))  # warm: compile everything
        stamps = replay_trace(eng, poisson_trace(
            seed=POISSON_SEED, vocab_size=cfg.vocab_size, **POISSON))
        m = latency_metrics(stamps)
        results[name] = m
        cell = f"paged|{name}|poisson"
        for k in ("ttft_p50_s", "ttft_p99_s", "itl_p50_s", "itl_p99_s"):
            _record(cell, k, m[k])
            csv_rows.append(f"serve,poisson_{name},{k},{m[k]:.4f}")
        if name == "chunked":
            from repro.telemetry.export import write_chrome_trace

            os.makedirs(os.path.dirname(trace_path), exist_ok=True)
            n = write_chrome_trace(trace_path, eng.telemetry, meta={
                "bench": "serve_poisson", "host": jax.default_backend(),
            })
            print(f"[bench_serve] poisson trace: {n} events -> {trace_path}")
    speedup = results["two_phase"]["itl_p99_s"] / max(
        results["chunked"]["itl_p99_s"], 1e-9)
    _record("paged|chunked|poisson", "itl_p99_speedup", speedup)
    csv_rows.append(f"serve,poisson,itl_p99_speedup,{speedup:.2f}")
    print(f"[bench_serve] poisson itl p99: two_phase="
          f"{results['two_phase']['itl_p99_s']:.4f}s chunked="
          f"{results['chunked']['itl_p99_s']:.4f}s ({speedup:.2f}x)")


def _prefix_cell(cfg, params, csv_rows: list[str]) -> None:
    """Shared-prefix caching: cold vs warm TTFT on an identical 256-token
    prompt (full hit: the warm request's first token comes straight from
    the cached logits, zero prefill compute) plus a hit-rate sweep over a
    seeded request stream drawn from a small set of shared prefixes.

    Warm outputs are asserted greedy-identical to cold in-line — a fast
    warm TTFT that changed the tokens would be a broken cache, not a win.
    Runs in smoke too: the cold/warm contrast is the point, not the reps."""
    plen = 256
    reps = 1 if _smoke() else 3
    serve = dataclasses.replace(
        _serve_cfg(True, 2), prefix_cache=True, prefill_chunk_tokens=64)
    rng = np.random.default_rng(5)
    eng = ServeEngine(cfg, params, serve=serve)
    # throwaway cold+warm pair compiles every program (chunk steps, decode
    # ticks, attach) before anything is timed
    warmup = rng.integers(3, cfg.vocab_size, plen).tolist()
    eng.submit(Request(900, warmup, max_new_tokens=MAX_NEW))
    eng.run()
    eng.submit(Request(901, list(warmup), max_new_tokens=MAX_NEW))
    eng.run()

    def ttft(uid, prompt) -> float:
        eng.submit(Request(uid, list(prompt), max_new_tokens=MAX_NEW))
        ticks, t0 = 0, time.perf_counter()
        while eng.sched.timing[uid].first_token < 0:
            eng.tick()
            ticks += 1
            if ticks > 10 * plen:
                break
        sec = time.perf_counter() - t0
        eng.run()  # drain
        return sec

    best_cold = best_warm = float("inf")
    for rep in range(reps):
        prompt = rng.integers(3, cfg.vocab_size, plen).tolist()
        best_cold = min(best_cold, ttft(2000 + rep, prompt))   # miss: prefill
        best_warm = min(best_warm, ttft(3000 + rep, prompt))   # full hit
        assert eng.finished[2000 + rep] == eng.finished[3000 + rep], \
            "warm output diverged from cold — prefix cache is broken"
    speedup = best_cold / max(best_warm, 1e-9)
    cell = f"paged|prefix|prompt{plen}"
    _record(cell, "ttft_cold_s", best_cold)
    _record(cell, "ttft_warm_s", best_warm)
    _record(cell, "ttft_warm_speedup", speedup)
    csv_rows.append(f"serve,prefix{plen},ttft_cold_s,{best_cold:.4f}")
    csv_rows.append(f"serve,prefix{plen},ttft_warm_s,{best_warm:.4f}")
    csv_rows.append(f"serve,prefix{plen},ttft_warm_speedup,{speedup:.1f}")
    print(f"[bench_serve] prefix cache: cold={best_cold:.4f}s "
          f"warm={best_warm:.4f}s ({speedup:.1f}x)")

    # hit-rate sweep: 3 shared 128-token prefixes, distinct 32-token tails,
    # served sequentially — the first request per prefix misses and caches,
    # the rest partial-hit. Deterministic stream -> deterministic rate.
    eng2 = ServeEngine(cfg, params, serve=serve)
    per_prefix = 2 if _smoke() else 3
    uid = 0
    srng = np.random.default_rng(6)
    for prefix in [srng.integers(3, cfg.vocab_size, 128).tolist()
                   for _ in range(3)]:
        for _ in range(per_prefix):
            tail = srng.integers(3, cfg.vocab_size, 32).tolist()
            eng2.submit(Request(uid, prefix + tail, max_new_tokens=MAX_NEW))
            eng2.run()
            uid += 1
    pst = eng2.stats()["prefix"]
    rate = pst["hits"] / max(pst["hits"] + pst["misses"], 1)
    _record("paged|prefix|sweep", "prefix_hit_rate", rate)
    _record("paged|prefix|sweep", "ttft_warm_s_p50",
            eng2.stats()["ttft_warm_s_p50"])
    csv_rows.append(f"serve,prefix_sweep,prefix_hit_rate,{rate:.3f}")
    print(f"[bench_serve] prefix sweep: hit rate {rate:.2f} "
          f"({pst['hits']}/{pst['hits'] + pst['misses']})")


def _chaos_cell(cfg, params, csv_rows: list[str]) -> None:
    """Graceful degradation under injected faults: the SAME seeded Poisson
    trace replayed fault-free and under a fixed performance-fault plan
    (dropped device samples, allocation failures, scrambled free lists) on
    the chunked-prefill engine with the watchdog armed.

    These fault sites cost ticks, never tokens — the timed requests'
    outputs are asserted greedy-identical to the fault-free run in-line —
    so the cell measures pure serving resilience: how much goodput
    survives (``goodput_frac``) and how far the ITL tail stretches while
    the engine retries allocations and re-samples dropped tokens."""
    from repro.serve.chaos import FaultPlan, FaultRule

    serve = dataclasses.replace(
        _serve_cfg(True, 2), chunked_prefill=True,
        prefill_chunk_tokens=32, prefill_token_budget=32,
        watchdog_ticks=64,
    )
    plan = FaultPlan(seed=POISSON_SEED, rules=(
        FaultRule("drop_sample", rate=0.05),
        FaultRule("alloc_fail", rate=0.05),
        FaultRule("fragment", rate=0.25),
    ))
    out: dict[str, dict] = {}
    engines: dict[str, ServeEngine] = {}
    for name, chaos in (("clean", None), ("chaos", plan)):
        eng = ServeEngine(cfg, params, serve=serve, chaos=chaos)
        replay_trace(eng, poisson_trace(
            seed=POISSON_SEED, uid_offset=10_000,
            vocab_size=cfg.vocab_size, **POISSON))  # warm: compile everything
        t0 = time.perf_counter()
        stamps = replay_trace(eng, poisson_trace(
            seed=POISSON_SEED, vocab_size=cfg.vocab_size, **POISSON))
        dt = time.perf_counter() - t0
        toks = sum(len(v) for u, v in eng.finished.items()
                   if u < 10_000 and eng.outcomes.get(u) == "finished")
        out[name] = {"goodput": toks / dt, **latency_metrics(stamps)}
        engines[name] = eng
    for u in range(POISSON["n_requests"]):
        assert engines["clean"].finished.get(u) == \
            engines["chaos"].finished.get(u), \
            f"chaos changed tokens for uid {u} — faults must cost ticks only"
    frac = out["chaos"]["goodput"] / max(out["clean"]["goodput"], 1e-9)
    injections = engines["chaos"].chaos.injections
    cell = "paged|chaos|degraded"
    _record(cell, "itl_p99_s", out["chaos"]["itl_p99_s"])
    _record(cell, "goodput_tok_per_s", out["chaos"]["goodput"])
    _record(cell, "goodput_frac", frac)
    _record(cell, "chaos_injections", injections)
    csv_rows.append(f"serve,chaos,itl_p99_s,{out['chaos']['itl_p99_s']:.4f}")
    csv_rows.append(
        f"serve,chaos,goodput_tok_per_s,{out['chaos']['goodput']:.1f}")
    csv_rows.append(f"serve,chaos,goodput_frac,{frac:.3f}")
    csv_rows.append(f"serve,chaos,chaos_injections,{injections}")
    print(f"[bench_serve] chaos: goodput {out['chaos']['goodput']:.1f} tok/s "
          f"({frac:.2f}x clean), itl p99 {out['chaos']['itl_p99_s']:.4f}s, "
          f"{injections} injections")


def write_json() -> None:
    from benchmarks.run import write_bench  # lazy: avoids an import cycle

    write_bench(
        "serve",
        schema="impl|mode|cell -> {ttft_ticks, ttft_s, tok_per_s, ...}",
        shape={"max_seq": MAX_SEQ, "max_new": MAX_NEW,
               "prompt_lens": list(PROMPT_LENS),
               "poisson": {**{k: list(v) if isinstance(v, tuple) else v
                              for k, v in POISSON.items()},
                           "seed": POISSON_SEED}},
        cells=_cells,
    )


def run(csv_rows: list[str]) -> None:
    _cells.clear()
    cfg, params = _setup()
    prompt_lens = (32,) if _smoke() else PROMPT_LENS
    reps = 1 if _smoke() else 3
    fused = dataclasses.replace(_serve_cfg(True, 1), prefill_impl="ss_fused")
    for plen in prompt_lens:
        ticks_d, sec_d = _ttft(cfg, params, _serve_cfg(False, 1), plen, reps)
        ticks_p, sec_p = _ttft(cfg, params, _serve_cfg(True, 1), plen, reps)
        _, sec_f = _ttft(cfg, params, fused, plen, reps)
        csv_rows.append(f"serve,prompt{plen},ttft_ticks_dense,{ticks_d}")
        csv_rows.append(f"serve,prompt{plen},ttft_ticks_paged,{ticks_p}")
        csv_rows.append(f"serve,prompt{plen},ttft_s_dense,{sec_d:.4f}")
        csv_rows.append(f"serve,prompt{plen},ttft_s_paged,{sec_p:.4f}")
        csv_rows.append(f"serve,prompt{plen},ttft_s_paged_ss_fused,{sec_f:.4f}")
        csv_rows.append(
            f"serve,prompt{plen},ttft_tick_speedup,{ticks_d / max(ticks_p, 1):.1f}"
        )
        csv_rows.append(
            f"serve,prompt{plen},ttft_wall_speedup,{sec_d / max(sec_p, 1e-9):.1f}"
        )
        csv_rows.append(
            f"serve,prompt{plen},ttft_wall_speedup_ss_fused,"
            f"{sec_d / max(sec_f, 1e-9):.1f}"
        )
        _record(f"dense|replay|prompt{plen}", "ttft_ticks", ticks_d)
        _record(f"dense|replay|prompt{plen}", "ttft_s", sec_d)
        _record(f"paged|batched|prompt{plen}", "ttft_ticks", ticks_p)
        _record(f"paged|batched|prompt{plen}", "ttft_s", sec_p)
        _record(f"paged|ss_fused|prompt{plen}", "ttft_s", sec_f)
    lane_counts = (2,) if _smoke() else (2, 4)
    for lanes in lane_counts:
        tps_d, _ = _throughput(cfg, params, _serve_cfg(False, lanes))
        tps_p, _ = _throughput(cfg, params, _serve_cfg(True, lanes))
        csv_rows.append(f"serve,lanes{lanes},tok_per_s_dense,{tps_d:.1f}")
        csv_rows.append(f"serve,lanes{lanes},tok_per_s_paged,{tps_p:.1f}")
        _record(f"dense|replay|lanes{lanes}", "tok_per_s", tps_d)
        _record(f"paged|batched|lanes{lanes}", "tok_per_s", tps_p)
    _telemetry_cell(
        cfg, params, lanes=2,
        path=os.environ.get("REPRO_TELEMETRY_JSONL", TELEMETRY_PATH),
    )
    _poisson_cell(
        cfg, params, csv_rows,
        trace_path=os.environ.get("REPRO_TRACE_JSON", TRACE_PATH),
    )
    _prefix_cell(cfg, params, csv_rows)
    _chaos_cell(cfg, params, csv_rows)
    write_json()


if __name__ == "__main__":
    rows: list[str] = []
    run(rows)
    print("name,case,metric,value")
    print("\n".join(rows))
