"""Iterative Moore-Penrose pseudoinverse (paper §7, eq. (11)).

The quartic Newton-Schulz-type iteration

    Z_{j+1} = 1/4 * Z_j (13 I - A Z_j (15 I - A Z_j (7 I - A Z_j)))

converges to ``A^+`` when the initial guess satisfies
``||A A^+ - A Z_0|| < 1``; the standard safe initializer is
``Z_0 = A^T / (||A||_1 ||A||_inf)`` (as in Nystromformer). Finite iteration
counts under-invert the small-eigenvalue tail, which the spectral-shifting
core exploits as a soft rank truncation (DESIGN.md §2.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def iterative_pinv(a: jnp.ndarray, num_iters: int = 6) -> jnp.ndarray:
    """Approximate pseudoinverse of ``a`` (..., c, c) via paper eq. (11)."""
    c = a.shape[-1]
    dtype = jnp.promote_types(a.dtype, jnp.float32)
    a32 = a.astype(dtype)
    eye = jnp.eye(c, dtype=dtype)
    abs_a = jnp.abs(a32)
    # ||A||_1 = max column abs-sum, ||A||_inf = max row abs-sum.
    norm_1 = jnp.max(jnp.sum(abs_a, axis=-2), axis=-1)[..., None, None]
    norm_inf = jnp.max(jnp.sum(abs_a, axis=-1), axis=-1)[..., None, None]
    z0 = jnp.swapaxes(a32, -1, -2) / jnp.maximum(norm_1 * norm_inf, 1e-30)

    def body(_, z):
        az = jnp.matmul(a32, z)
        inner = 7.0 * eye - az
        inner = 15.0 * eye - jnp.matmul(az, inner)
        inner = 13.0 * eye - jnp.matmul(az, inner)
        return 0.25 * jnp.matmul(z, inner)

    z = jax.lax.fori_loop(0, num_iters, body, z0)
    return z.astype(a.dtype)


def svd_pinv(
    a: jnp.ndarray, rank_tol: float = 1e-4
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact truncated pseudoinverse via SVD (CPU oracle path).

    Returns ``(pinv, kept_mask, singular_values)`` where ``kept_mask`` marks
    singular values above ``rank_tol * sigma_max`` (the effective rank used by
    the spectral-shift delta).
    """
    dtype = jnp.promote_types(a.dtype, jnp.float32)
    u, s, vt = jnp.linalg.svd(a.astype(dtype), full_matrices=False)
    cutoff = rank_tol * jnp.max(s, axis=-1, keepdims=True)
    keep = s > cutoff
    s_inv = jnp.where(keep, 1.0 / jnp.where(keep, s, 1.0), 0.0)
    pinv = jnp.einsum("...ji,...j,...kj->...ik", vt, s_inv, u)
    return pinv.astype(a.dtype), keep, s
