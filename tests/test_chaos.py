"""Serving chaos harness: deterministic fault injection, recovery policies
(deadlines, cancellation, numerics-guard escalation, no-progress watchdog)
and the multi-seed soak.

The soak's core invariants:

* every submitted uid ends in EXACTLY one terminal outcome
  (finished / cancelled / rejected / deadline_expired);
* zero leaked blocks — after drain the allocator's free list plus the
  refcounted set partitions the pool, and every surviving refcount is
  fully accounted for by prefix-cache entries;
* no livelock — the engine drains within the tick budget (and the
  watchdog is armed, so a structural wedge raises EngineStalled);
* the injected faults in the soak plans are all *performance* faults
  (lost allocations, stalls, dropped samples, cache misses), so greedy
  outputs must be TOKEN-IDENTICAL to the fault-free run on the same
  arrival trace.

On an invariant failure the failing run's Perfetto trace is written to
``results/`` so CI can upload it as an artifact and the seed replays
locally (the whole injection schedule derives from ``(plan.seed, tick)``).
"""
from __future__ import annotations

import dataclasses
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.chaos import ChaosInjector, EngineStalled, FaultPlan, FaultRule
from repro.serve.engine import Request, ServeEngine
from repro.serve.workload import poisson_trace, replay_trace
from repro.telemetry.export import write_chrome_trace
from repro.telemetry.flight import FlightRecorder
from repro.telemetry.metrics import MetricsRegistry

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


BASE = ServeConfig(max_lanes=2, max_seq=64, block_size=8)
GUARD = dataclasses.replace(BASE, numerics_guard=True, numerics_demote_after=2)

_PROMPT = list(range(7, 7 + 11))  # fixed prompt for the guard-ladder tests


def _assert_no_leaks(eng):
    """Pool accounting after drain: free list ⊎ refcounted ids == the whole
    usable pool, no double-frees, and every surviving reference is a
    prefix-cache retention (or none survive at all)."""
    alloc = eng.sched.allocator
    if alloc is None:
        return
    assert alloc.tables == {}, f"leaked tables: {alloc.tables}"
    free = alloc._free
    assert len(free) == len(set(free)), "free-list duplicates"
    refed = set(alloc.refcounts)
    assert refed.isdisjoint(free), "block both free and referenced"
    assert refed | set(free) == set(range(1, alloc.num_blocks))
    if eng.prefix is not None:
        cache_refs = eng.prefix._cache_refs
        for b in refed:
            assert alloc.refcounts[b] == cache_refs.get(b, 0), (
                f"block {b}: refcount {alloc.refcounts[b]} not accounted "
                f"for by cache refs {cache_refs.get(b, 0)}"
            )
    else:
        assert alloc.num_used == 0


def _assert_outcomes(eng, uids, expect="finished"):
    for uid in uids:
        assert eng.outcomes.get(uid) == expect, (
            uid, eng.outcomes.get(uid))
        assert (uid in eng.finished) == (expect == "finished")


# ==========================================================================
# ChaosInjector unit behaviour (no engine builds)
# ==========================================================================
class TestInjector:
    def test_rule_validation(self):
        with pytest.raises(ValueError, match="unknown chaos site"):
            FaultRule("explode")
        with pytest.raises(ValueError, match="rate"):
            FaultRule("alloc_fail", rate=1.5)

    def test_window_and_lane_filters(self):
        plan = FaultPlan(rules=(
            FaultRule("drop_sample", start_tick=5, end_tick=7, lane=1),
        ))
        inj = ChaosInjector(plan)
        fired = []
        for tick in range(1, 10):
            inj.begin_tick(tick)
            for lane in (0, 1):
                if inj.fire("drop_sample", lane=lane):
                    fired.append((tick, lane))
        assert fired == [(5, 1), (6, 1), (7, 1)]
        assert inj.injections == 3

    def test_rate_is_deterministic_per_seed(self):
        plan = FaultPlan(seed=11, rules=(FaultRule("alloc_fail", rate=0.4),))

        def schedule():
            inj = ChaosInjector(plan)
            out = []
            for tick in range(1, 40):
                inj.begin_tick(tick)
                # two opportunities per tick: distinct ordinals, so the
                # rate applies per call but the schedule still replays
                out.append((inj.fire("alloc_fail") is not None,
                            inj.fire("alloc_fail") is not None))
            return out

        a, b = schedule(), schedule()
        assert a == b
        flat = [x for pair in a for x in pair]
        assert any(flat) and not all(flat)  # rate < 1 actually gates
        # a different seed yields a different schedule
        other = ChaosInjector(dataclasses.replace(plan, seed=12))
        diff = []
        for tick in range(1, 40):
            other.begin_tick(tick)
            diff.append((other.fire("alloc_fail") is not None,
                         other.fire("alloc_fail") is not None))
        assert diff != a

    def test_counts_and_flight_events(self):
        reg = MetricsRegistry()
        fl = FlightRecorder()
        plan = FaultPlan(rules=(FaultRule("tick_delay"),))
        inj = ChaosInjector(plan, flight=fl, registry=reg)
        inj.begin_tick(3)
        rule = inj.fire("tick_delay")
        assert rule is not None and rule.site == "tick_delay"
        assert inj.fire("fragment") is None  # no rule for the site
        line = next(l for l in fl.lifelines() if l.uid == -1)
        ev = line.events[0]
        assert ev["kind"] == "chaos" and ev["site"] == "tick_delay"
        assert ev["tick"] == 3

    def test_engine_stalled_structure(self):
        err = EngineStalled(tick=9, stall_ticks=4, waiting=2,
                            active_lanes=0, parked=1,
                            pool={"blocks_free": 0})
        assert err.tick == 9 and err.waiting == 2
        assert "no progress for 4 ticks" in str(err)


# ==========================================================================
# Recovery policies: rejection / cancellation / deadlines / watchdog
# ==========================================================================
def _mk_reqs(cfg, n, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(u, rng.integers(
            3, cfg.vocab_size, int(rng.integers(5, 20))).tolist(),
            max_new_tokens=max_new)
        for u in range(n)
    ]


def test_bounded_queue_rejects_and_recovers(qwen):
    cfg, params = qwen
    eng = ServeEngine(cfg, params,
                      serve=dataclasses.replace(BASE, max_queue=2))
    reqs = _mk_reqs(cfg, 4)
    accepted = [eng.submit(r) for r in reqs]
    assert accepted == [True, True, False, False]
    _assert_outcomes(eng, [2, 3], "rejected")
    eng.run()
    _assert_outcomes(eng, [0, 1], "finished")
    assert eng.stats()["rejected"] == 2
    # backpressure is advisory, not terminal: a resubmit after the queue
    # drains is accepted and sheds the stale "rejected" outcome
    assert eng.submit(Request(2, list(reqs[2].prompt), max_new_tokens=4))
    eng.run()
    _assert_outcomes(eng, [0, 1, 2], "finished")
    _assert_no_leaks(eng)


def test_cancel_queued_and_active(qwen):
    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    for r in _mk_reqs(cfg, 3, max_new=8):
        eng.submit(r)
    # queued cancellation: uid 2 never reaches a lane
    assert eng.cancel(2)
    assert eng.outcomes[2] == "cancelled"
    eng.tick()
    eng.tick()
    # active cancellation: uid 0 is mid-decode on a lane
    assert any(l.req is not None and l.req.uid == 0 for l in eng.lanes)
    assert eng.cancel(0)
    assert all(l.req is None or l.req.uid != 0 for l in eng.lanes)
    # unknown and already-terminal uids refuse
    assert not eng.cancel(99)
    assert not eng.cancel(0)
    eng.run()
    assert eng.outcomes == {0: "cancelled", 2: "cancelled", 1: "finished"}
    st = eng.stats()
    assert st["cancelled"] == 2 and st["finished"] == 1
    _assert_no_leaks(eng)


def test_cancel_from_on_token_callback(qwen):
    """A client cancelling its own request from the token callback must not
    crash the emit path (the lane is gone when the callback returns)."""
    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    seen = []

    def bail(uid, tok):
        seen.append(tok)
        eng.cancel(uid)

    eng.submit(Request(0, _PROMPT, max_new_tokens=16, on_token=bail))
    eng.run()
    assert len(seen) == 1  # first token streamed, then the cancel landed
    assert eng.outcomes == {0: "cancelled"}
    assert 0 not in eng.finished
    _assert_no_leaks(eng)


def test_deadlines_expire_queued_and_seated(qwen):
    cfg, params = qwen
    eng = ServeEngine(cfg, params,
                      serve=dataclasses.replace(BASE, max_lanes=1))
    # uid 0 monopolizes the single lane; uid 1's deadline expires in the
    # queue (waiting-branch cleanup); uid 2's budget is generous enough to
    # outlast the backlog and finish normally.
    eng.submit(Request(0, _PROMPT, max_new_tokens=16))
    eng.submit(Request(1, list(_PROMPT), max_new_tokens=4, deadline_ticks=2))
    eng.submit(Request(2, list(_PROMPT), max_new_tokens=4, deadline_ticks=60))
    eng.run()
    assert eng.outcomes == {
        0: "finished", 1: "deadline_expired", 2: "finished"}
    st = eng.stats()
    assert st["deadline_expired"] == 1 and st["finished"] == 2
    _assert_no_leaks(eng)


def test_deadline_expires_mid_decode(qwen):
    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    eng.submit(Request(0, _PROMPT, max_new_tokens=32, deadline_ticks=4))
    eng.submit(Request(1, list(_PROMPT), max_new_tokens=4))
    eng.run()
    assert eng.outcomes == {0: "deadline_expired", 1: "finished"}
    assert 0 not in eng.finished
    assert eng.finished[1]  # the survivor is untouched
    _assert_no_leaks(eng)


def test_watchdog_raises_engine_stalled(qwen):
    """An open-ended admission stall with nothing on a lane is a structural
    wedge: the ladder has no parked blocks to reclaim and no lane to
    preempt, so the watchdog reports instead of spinning forever."""
    cfg, params = qwen
    plan = FaultPlan(rules=(FaultRule("admission_stall"),))
    eng = ServeEngine(
        cfg, params, chaos=plan,
        serve=dataclasses.replace(BASE, watchdog_ticks=3))
    for r in _mk_reqs(cfg, 2):
        eng.submit(r)
    with pytest.raises(EngineStalled) as ei:
        eng.run(max_ticks=50)
    assert ei.value.waiting == 2 and ei.value.active_lanes == 0
    assert eng.stats()["watchdog_fires"] == 1


def test_watchdog_off_by_default(qwen):
    """watchdog_ticks=0 (the default) never raises — the same wedge just
    burns the tick budget, exactly the pre-chaos-harness behaviour."""
    cfg, params = qwen
    plan = FaultPlan(rules=(FaultRule("admission_stall"),))
    eng = ServeEngine(cfg, params, chaos=plan, serve=BASE)
    for r in _mk_reqs(cfg, 2):
        eng.submit(r)
    eng.run(max_ticks=20)
    assert not eng.finished and eng.stats()["watchdog_fires"] == 0


# ==========================================================================
# Numerics-guard escalation ladder
# ==========================================================================
@pytest.fixture(scope="module")
def exact_clean(qwen):
    """Fault-free exact-mode baseline for the guard identity tests."""
    cfg, params = qwen
    eng = ServeEngine(cfg, params, serve=BASE)
    eng.submit(Request(0, _PROMPT, max_new_tokens=12))
    return eng.run()


def test_guard_quarantine_reseed_is_exact(qwen, exact_clean):
    """Stats-only corruption (K/V intact): the guard quarantines the lane
    and rebuilds every (m, l, acc) row from cached K/V. In exact mode the
    rebuilt rows ARE the uncorrupted state, so the run is token-identical
    to the fault-free baseline."""
    cfg, params = qwen
    plan = FaultPlan(seed=1, rules=(
        FaultRule("nan_stats", lane=0, start_tick=3, end_tick=3),))
    eng = ServeEngine(cfg, params, serve=GUARD, chaos=plan)
    eng.submit(Request(0, _PROMPT, max_new_tokens=12))
    out = eng.run()
    assert out == exact_clean
    st = eng.stats()
    assert st["quarantines"] == 1
    assert st["demotions"] == 0  # demotion is a frozen-mode rung
    assert st["chaos_injections"] == 1
    _assert_no_leaks(eng)


def test_guard_nan_logits_replay_preempts(qwen, exact_clean):
    """Corrupted logits mean the emitted token is unrecoverable in place
    (the per-tick landmark-sum updates make retry unsound), so the guard
    replay-preempts: recompute from scratch, token-identical output."""
    cfg, params = qwen
    plan = FaultPlan(seed=2, rules=(
        FaultRule("nan_logits", lane=0, start_tick=3, end_tick=3),))
    eng = ServeEngine(cfg, params, serve=GUARD, chaos=plan)
    eng.submit(Request(0, _PROMPT, max_new_tokens=12))
    out = eng.run()
    assert out == exact_clean
    st = eng.stats()
    assert st["quarantines"] == 0 and st["preemptions"] >= 1
    _assert_no_leaks(eng)


def test_guard_escalates_frozen_lane_to_exact(qwen):
    """Repeat-tripping frozen lane walks the full ladder: quarantine +
    reseed on each trip, then demotion to the exact-mode decode program
    at numerics_demote_after trips. The request still completes."""
    cfg, params = qwen
    fcfg = dataclasses.replace(cfg, decode_streaming="frozen")
    plan = FaultPlan(seed=3, rules=(
        FaultRule("nan_stats", lane=0, start_tick=3, end_tick=4),))
    eng = ServeEngine(fcfg, params, serve=GUARD, chaos=plan)
    eng.submit(Request(0, _PROMPT, max_new_tokens=12))
    out = eng.run()
    st = eng.stats()
    assert st["quarantines"] == 2
    assert st["demotions"] == 1
    assert eng.outcomes == {0: "finished"}
    assert out[0]  # the demoted lane still streams tokens to completion
    _assert_no_leaks(eng)


def test_guard_off_is_silent_corruption(qwen):
    """The repro the guard exists for: with numerics_guard=False the same
    injected NaN stats silently poison every subsequent decode step —
    the request 'finishes' with garbage tokens."""
    cfg, params = qwen
    fcfg = dataclasses.replace(cfg, decode_streaming="frozen")
    plan = FaultPlan(seed=3, rules=(
        FaultRule("nan_stats", lane=0, start_tick=3, end_tick=4),))

    def run(serve):
        eng = ServeEngine(fcfg, params, serve=serve, chaos=plan)
        eng.submit(Request(0, _PROMPT, max_new_tokens=12))
        return eng.run()

    poisoned = run(BASE)
    clean = run(dataclasses.replace(BASE, numerics_guard=True))
    # tokens sampled before the injection window agree; the tail diverges
    assert poisoned[0][:2] == clean[0][:2]
    assert poisoned[0] != clean[0]


# ==========================================================================
# Replayability of a whole chaos run
# ==========================================================================
def test_chaos_run_replays_bit_identical(qwen):
    cfg, params = qwen
    plan = FaultPlan(seed=7, rules=(FaultRule("drop_sample", rate=0.3),))
    trace = poisson_trace(
        seed=7, n_requests=3, mean_interarrival_ticks=2,
        prompt_lens=(5, 12), vocab_size=cfg.vocab_size, max_new_tokens=4,
    )

    def run():
        eng = ServeEngine(cfg, params, serve=BASE, chaos=plan)
        replay_trace(eng, trace, max_ticks=500)
        return eng.finished, eng.chaos.injections

    (out_a, inj_a), (out_b, inj_b) = run(), run()
    assert out_a == out_b
    assert inj_a == inj_b and inj_a > 0


# ==========================================================================
# The chaos soak: seeds x fault plans
# ==========================================================================
SOAK = dataclasses.replace(
    BASE, prefix_cache=True, chunked_prefill=True, watchdog_ticks=16,
    telemetry=True,
)

PLANS = {
    "alloc": (FaultRule("alloc_fail", rate=0.15),
              FaultRule("fragment", rate=0.5)),
    "stall": (FaultRule("admission_stall", start_tick=3, end_tick=10),
              FaultRule("tick_delay", rate=0.2, param=1e-4)),
    "drop": (FaultRule("drop_sample", rate=0.1),),
    "cache": (FaultRule("hash_collision", rate=0.5),
              FaultRule("evict_storm", rate=0.25, param=2)),
}

SEEDS = tuple(range(int(os.environ.get("REPRO_CHAOS_SEEDS", "3"))))
_CLEAN: dict[int, dict] = {}  # seed -> fault-free outputs on that trace


def _soak_trace(cfg, seed):
    return poisson_trace(
        seed=seed, n_requests=6, mean_interarrival_ticks=2,
        prompt_lens=(5, 12, 21), vocab_size=cfg.vocab_size,
        max_new_tokens=6,
    )


def _clean_outputs(cfg, params, seed):
    if seed not in _CLEAN:
        eng = ServeEngine(cfg, params, serve=SOAK)
        replay_trace(eng, _soak_trace(cfg, seed), max_ticks=1500)
        assert eng.sched.idle
        _CLEAN[seed] = dict(eng.finished)
    return _CLEAN[seed]


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("plan_name", sorted(PLANS))
def test_chaos_soak(qwen, seed, plan_name):
    cfg, params = qwen
    plan = FaultPlan(seed=seed, rules=PLANS[plan_name])
    trace = _soak_trace(cfg, seed)
    eng = ServeEngine(cfg, params, serve=SOAK, chaos=plan)
    replay_trace(eng, trace, max_ticks=1500)
    try:
        # no livelock: the engine actually drained, not just ran out budget
        assert eng.sched.idle, "engine failed to drain within the budget"
        # terminal-outcome partition: nothing rejected/cancelled/expired in
        # the soak plans, so every uid must land in exactly "finished"
        _assert_outcomes(eng, [it.uid for it in trace], "finished")
        # zero leaked blocks
        _assert_no_leaks(eng)
        # performance faults never change greedy outputs
        assert eng.finished == _clean_outputs(cfg, params, seed)
    except AssertionError:
        RESULTS.mkdir(exist_ok=True)
        path = RESULTS / f"chaos_{plan_name}_seed{seed}.trace.json"
        write_chrome_trace(
            str(path), eng.telemetry,
            meta={"plan": plan_name, "seed": seed,
                  "injections": eng.chaos.injections},
        )
        raise
