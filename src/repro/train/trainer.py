"""Fault-tolerant training driver.

Wires together the substrate: sharded params/optimizer (distributed/
sharding.py), the jitted train step (train/train_step.py), the data pipeline
(data/pipeline.py), async checkpointing (checkpoint/checkpointer.py) and the
fault-tolerance control plane (distributed/fault_tolerance.py).

Lifecycle
---------
    trainer = Trainer(cfg, tcfg, shape, mesh=...)   # init or auto-restore
    trainer.run(num_steps)                          # step loop

Per step: build batch -> place sharded -> jitted step (donated state) ->
metrics; every ``checkpoint_every`` steps an async checkpoint is published
atomically. ``HeartbeatMonitor`` tracks per-host step times (this container
is single-host, so beats are synthesized for the mesh's logical hosts) and
a ``FailureInjector`` can kill hosts at chosen steps — the trainer then
checkpoints (if the failing step allows), re-plans the largest runnable mesh
(``ElasticPlan``: TP axis intact, DP shrunk to a power of two), rebuilds
shardings, restores the mesh-agnostic checkpoint onto the new mesh, re-jits
and continues. The elastic integration test exercises exactly this path.
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.configs.registry import batch_specs
from repro.data.pipeline import SyntheticLM, make_global_batch
from repro.distributed.fault_tolerance import (
    ElasticPlan,
    FailureInjector,
    HeartbeatMonitor,
)
from repro.distributed.sharding import (
    apply_seq_sharding_config,
    sharding_rules,
    shardings_for,
)
from repro.models.model import model_specs
from repro.models.params import abstract_params, init_params, logical_axes
from repro.optim.adamw import AdamWState, adamw_init
from repro.optim.schedules import warmup_cosine
from repro.train.train_step import make_train_step

log = logging.getLogger("repro.trainer")


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        shape: ShapeConfig,
        mesh: Mesh,
        *,
        data=None,
        rule_overrides: Optional[dict] = None,
        monitor: Optional[HeartbeatMonitor] = None,
        injector: Optional[FailureInjector] = None,
        lr_fn: Optional[Callable] = None,
        telemetry=None,
    ):
        # Telemetry is caller-owned and optional; default = no-op pair, so
        # the step loop's spans/metrics cost nothing unless a Telemetry
        # object is passed in (launch scripts, tests, benchmarks).
        from repro.telemetry import Telemetry

        if telemetry is None:
            telemetry = Telemetry(enabled=False)
        self.telemetry = telemetry
        if telemetry.enabled:
            from repro.kernels import dispatch
            from repro.telemetry.metrics import LATENCY_BUCKETS

            dispatch.set_metrics(telemetry.metrics)
            telemetry.stamp_provenance(cfg, tcfg)
            r = telemetry.metrics
            self._step_hist = r.histogram(
                "train_step_seconds", help="wall time per optimizer step",
                buckets=LATENCY_BUCKETS)
            self._gauges = {
                name: r.gauge(f"train_{name}", help=f"last step's {name}")
                for name in ("loss", "ce", "grad_norm", "lr")
            }
        self.rule_overrides = rule_overrides or {}
        cfg = apply_seq_sharding_config(cfg, mesh, self.rule_overrides, log=log)
        self.cfg, self.tcfg, self.shape = cfg, tcfg, shape
        self.data = data or SyntheticLM(
            vocab_size=cfg.vocab_size,
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            seed=tcfg.seed,
        )
        self.lr_fn = lr_fn or warmup_cosine(
            tcfg.learning_rate, tcfg.warmup_steps, tcfg.total_steps
        )
        self.ckpt = Checkpointer(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
        self.injector = injector
        self.step = 0
        self.metrics_history: list[dict] = []
        self._install_mesh(mesh, restore=True)
        hosts = [f"host{i}" for i in range(max(mesh.devices.size // 8, 1))]
        self.monitor = monitor or HeartbeatMonitor(hosts, timeout_s=600.0)

    # -- mesh / state installation -------------------------------------------
    def _install_mesh(self, mesh: Mesh, restore: bool) -> None:
        """(Re)build shardings + jitted step on ``mesh``; init or restore."""
        self.mesh = mesh
        cfg, tcfg = self.cfg, self.tcfg
        specs = model_specs(cfg)
        axes = logical_axes(specs)
        params_abs = abstract_params(specs, dtype=jnp.dtype(cfg.param_dtype))

        self._warm_attention_plans()
        with mesh, sharding_rules(mesh, self.rule_overrides):
            self.p_sh = shardings_for(mesh, axes, params_abs)
            bspecs, baxes = batch_specs(cfg, self.shape)
            self.b_sh = shardings_for(mesh, baxes, bspecs)
            self.o_sh = AdamWState(
                step=NamedSharding(mesh, P()), m=self.p_sh, v=self.p_sh
            )
            step_fn = make_train_step(cfg, tcfg, self.lr_fn)
            self.jitted = jax.jit(
                step_fn,
                in_shardings=(self.p_sh, self.o_sh, self.b_sh),
                out_shardings=(self.p_sh, self.o_sh, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            if self.telemetry.enabled:
                # xla_compiles_total{program="train_step"}: a steady run
                # compiles once; growth mid-run means a shape leak (batch /
                # mesh churn) — see telemetry/accounting.py.
                from repro.telemetry import accounting as acct

                acct.set_metrics(self.telemetry.metrics)
                acct.install_compile_listener()
                self.jitted = acct.XLAAccounting(self.telemetry.metrics).wrap(
                    self.jitted, "train_step"
                )

            latest = self.ckpt.latest_step() if restore else None
            if latest is not None:
                log.info("restoring step %d onto mesh %s", latest, mesh.shape)
                target = {
                    "params": params_abs,
                    "opt": AdamWState(
                        step=jax.ShapeDtypeStruct((), jnp.int32),
                        m=abstract_params(specs, dtype=jnp.float32),
                        v=abstract_params(specs, dtype=jnp.float32),
                    ),
                }
                sh = {"params": self.p_sh, "opt": self.o_sh}
                state = self.ckpt.restore(latest, target, sh)
                self.params, self.opt_state = state["params"], state["opt"]
                self.step = latest
            else:
                key = jax.random.PRNGKey(tcfg.seed)
                init = jax.jit(
                    lambda k: init_params(
                        specs, k, dtype=jnp.dtype(cfg.param_dtype)
                    ),
                    out_shardings=self.p_sh,
                )
                self.params = init(key)
                opt = jax.jit(adamw_init, out_shardings=self.o_sh)
                self.opt_state = opt(self.params)

    def _warm_attention_plans(self) -> None:
        """Measured-autotune warmup: resolve the train-shape kernel plan
        before the step is jitted, so trace-time dispatch (models/
        attention.py) hits the registry instead of tuning mid-trace. A
        previously measured plan (in-memory or on disk, including the
        ``autotune_cache`` override) short-circuits re-measurement — this
        runs again on every elastic mesh re-install."""
        cfg = self.cfg
        if (not cfg.autotune
                or cfg.attention_impl != "spectral_shift_fused"
                or cfg.attention_backend != "auto"):
            # A forced backend never consults the registry — measuring
            # would be pure wasted startup time.
            return
        from repro.distributed.sharding import seq_axis_sharded

        if seq_axis_sharded(self.mesh, self.rule_overrides):
            # Context-parallel cells resolve a seq_shards key and route
            # through the shard_map driver; the single-device autotune
            # harness cannot reproduce that program, so leave the heuristic
            # (or a pre-registered sharded plan) in charge.
            log.info("sequence axis is sharded: skipping autotune warmup")
            return
        from repro.kernels import dispatch

        if cfg.autotune_cache:
            dispatch.set_cache_path(cfg.autotune_cache)
            dispatch.load_cache()
        key = dispatch.make_key(
            self.shape.seq_len, cfg.num_landmarks, cfg.resolved_head_dim,
            cfg.compute_dtype, cfg.is_decoder_only,
        )
        with self.telemetry.span("plan_resolution", n=key.n):
            plan = dispatch.get_plan(key)
            if plan.source == "heuristic":  # nothing measured for this shape
                plan = dispatch.autotune(
                    self.shape.seq_len,
                    cfg.num_landmarks,
                    cfg.resolved_head_dim,
                    dtype=cfg.compute_dtype,
                    causal=cfg.is_decoder_only,
                )
        log.info(
            "attention plan for n=%d (%s): impl=%s block_n=%d",
            self.shape.seq_len, plan.source, plan.impl, plan.block_n,
        )

    # -- checkpoint ----------------------------------------------------------
    def save(self, blocking: bool = False) -> None:
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            blocking=blocking,
        )

    # -- failure handling ------------------------------------------------------
    def _handle_failure(self, dead: list[str]) -> None:
        """Simulated elastic recovery: drop dead hosts' chips, re-plan, restore."""
        log.warning("step %d: hosts failed: %s — elastic restart", self.step, dead)
        self.ckpt.wait()
        alive_hosts = [h for h in self.monitor.hosts if h not in dead]
        chips_per_host = max(self.mesh.devices.size // len(self.monitor.hosts), 1)
        alive_chips = chips_per_host * len(alive_hosts)
        model_par = self.mesh.shape.get("model", 1)
        plan = ElasticPlan.plan(
            alive_chips, model_par, max_data=self.mesh.shape.get("data", 1)
        )
        flat = sorted(self.mesh.devices.flat, key=lambda d: d.id)
        keep = np.array(flat[: plan.data * plan.model]).reshape(
            plan.data, plan.model
        )
        new_mesh = Mesh(keep, ("data", "model"))
        for h in dead:
            del self.monitor.hosts[h]
        # State on dead chips is lost: re-install from the last checkpoint.
        self._install_mesh(new_mesh, restore=True)

    # -- step loop -------------------------------------------------------------
    def run(self, num_steps: int, log_every: int = 10) -> list[dict]:
        cfg, tcfg = self.cfg, self.tcfg
        end = self.step + num_steps
        with self.mesh, sharding_rules(self.mesh, self.rule_overrides):
            while self.step < end:
                if self.injector:
                    dead = self.injector.failures_at(self.step)
                    if dead:
                        self._handle_failure(dead)
                t0 = time.time()
                with self.telemetry.step_span("train_step", self.step):
                    host_batch = self.data.batch(self.step)
                    batch = make_global_batch(host_batch, self.b_sh)
                    self.params, self.opt_state, metrics = self.jitted(
                        self.params, self.opt_state, batch
                    )
                    metrics = {
                        k: float(v)
                        for k, v in metrics.items() if jnp.ndim(v) == 0
                    }
                dt = time.time() - t0
                metrics["step"] = self.step
                metrics["step_time_s"] = dt
                self.metrics_history.append(metrics)
                if self.telemetry.enabled:
                    self._step_hist.observe(dt)
                    for name, g in self._gauges.items():
                        if name in metrics:
                            g.set(metrics[name])
                for h in self.monitor.hosts:
                    self.monitor.beat(h, dt)
                stragglers = self.monitor.stragglers()
                if stragglers:
                    log.warning("stragglers detected: %s", stragglers)
                self.step += 1
                if self.step % tcfg.checkpoint_every == 0:
                    self.save(blocking=False)
                if self.step % log_every == 0 or self.step == end:
                    log.info(
                        "step %d loss=%.4f ce=%.4f %.2fs",
                        self.step, metrics.get("loss", float("nan")),
                        metrics.get("ce", float("nan")), dt,
                    )
        self.ckpt.wait()
        return self.metrics_history
